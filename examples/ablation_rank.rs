//! Ablation driver: how the FINGER rank r trades approximation quality
//! (angle-estimate correlation, Supplementary E) against screening
//! effectiveness (effective distance calls) and recall. One shared HNSW
//! graph, many side-index variants, all searched through the borrowed
//! `FingerView` implementor of `AnnIndex`.
//!
//!   cargo run --release --example ablation_rank

use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::spec_by_name;
use finger_ann::eval::recall;
use finger_ann::finger::construct::{FingerIndex, FingerParams};
use finger_ann::finger::rplsh::build_rplsh_index;
use finger_ann::graph::hnsw::{Hnsw, HnswParams};
use finger_ann::index::impls::FingerView;
use finger_ann::index::{AnnIndex, SearchContext, SearchParams};

fn main() {
    let spec = spec_by_name("glove-sim-100", 0.2).unwrap();
    println!("dataset: {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let m = ds.data.cols();

    let store = finger_ann::core::store::VectorStore::from_matrix(&ds.data);
    let hnsw = Hnsw::build_with_store(
        &store,
        HnswParams { m: 16, ef_construction: 120, ..Default::default() },
    );

    let mut ctx = SearchContext::for_universe(ds.data.rows()).with_stats();
    let params = SearchParams::new(10).with_ef(80);
    println!(
        "{:<10} {:>6} {:>8} {:>10} {:>12} {:>10}",
        "scheme", "rank", "corr", "recall@10", "eff. calls", "QPS"
    );
    for rank in [8usize, 16, 24, 32, 48] {
        for scheme in ["finger", "rplsh"] {
            let fparams = FingerParams { rank, ..Default::default() };
            let idx = if scheme == "rplsh" {
                build_rplsh_index(&ds.data, &hnsw.base, fparams)
            } else {
                FingerIndex::build(&ds.data, &hnsw.base, fparams)
            };
            let corr = idx.matching.correlation;
            let view = FingerView {
                data: &ds.data,
                store: &store,
                hnsw: &hnsw,
                findex: &idx,
                label: scheme,
            };
            ctx.reset_stats();
            let t0 = std::time::Instant::now();
            let mut rec = 0.0;
            for qi in 0..ds.queries.rows() {
                let res = view.search(ds.queries.row(qi), &params, &mut ctx);
                rec += recall(&res, &gt[qi]);
            }
            let nq = ds.queries.rows() as f64;
            let stats = ctx.take_stats();
            println!(
                "{:<10} {:>6} {:>8.3} {:>10.4} {:>12.1} {:>10.0}",
                scheme,
                rank,
                corr,
                rec / nq,
                stats.effective_dist_calls(rank, m) / nq,
                nq / t0.elapsed().as_secs_f64()
            );
        }
    }
    println!("\n(paper: FINGER's SVD basis beats RPLSH at every rank; Supplementary E's");
    println!(" rule picks the smallest rank with correlation >= 0.7)");
}
