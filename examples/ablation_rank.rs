//! Ablation driver: how the FINGER rank r trades approximation quality
//! (angle-estimate correlation, Supplementary E) against screening
//! effectiveness (effective distance calls) and recall.
//!
//!   cargo run --release --example ablation_rank

use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::spec_by_name;
use finger_ann::eval::recall;
use finger_ann::finger::construct::{FingerIndex, FingerParams};
use finger_ann::finger::rplsh::build_rplsh_index;
use finger_ann::graph::hnsw::{Hnsw, HnswParams};
use finger_ann::graph::search::SearchStats;
use finger_ann::graph::visited::VisitedSet;

fn main() {
    let spec = spec_by_name("glove-sim-100", 0.2).unwrap();
    println!("dataset: {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let m = ds.data.cols();

    let hnsw = Hnsw::build(
        &ds.data,
        HnswParams { m: 16, ef_construction: 120, ..Default::default() },
    );

    println!(
        "{:<10} {:>6} {:>8} {:>10} {:>12} {:>10}",
        "scheme", "rank", "corr", "recall@10", "eff. calls", "QPS"
    );
    for rank in [8usize, 16, 24, 32, 48] {
        for scheme in ["finger", "rplsh"] {
            let params = FingerParams { rank, ..Default::default() };
            let idx = if scheme == "rplsh" {
                build_rplsh_index(&ds.data, &hnsw.base, params)
            } else {
                FingerIndex::build(&ds.data, &hnsw.base, params)
            };
            let corr = idx.matching.correlation;
            let mut vis = VisitedSet::new(ds.data.rows());
            let mut stats = SearchStats::default();
            let t0 = std::time::Instant::now();
            let mut rec = 0.0;
            for qi in 0..ds.queries.rows() {
                let res = finger_ann::finger::search::search_hnsw_with_index(
                    &hnsw, &idx, &ds.data, ds.queries.row(qi), 10, 80, &mut vis, Some(&mut stats),
                );
                rec += recall(&res, &gt[qi]);
            }
            let nq = ds.queries.rows() as f64;
            println!(
                "{:<10} {:>6} {:>8.3} {:>10.4} {:>12.1} {:>10.0}",
                scheme,
                rank,
                corr,
                rec / nq,
                stats.effective_dist_calls(rank, m) / nq,
                nq / t0.elapsed().as_secs_f64()
            );
        }
    }
    println!("\n(paper: FINGER's SVD basis beats RPLSH at every rank; Supplementary E's");
    println!(" rule picks the smallest rank with correlation >= 0.7)");
}
