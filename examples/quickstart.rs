//! Quickstart: build an HNSW-FINGER index over a synthetic dataset, search
//! it through the unified `AnnIndex` API, and compare against plain HNSW
//! and exact ground truth.
//!
//!   cargo run --release --example quickstart

use std::sync::Arc;
use std::time::Instant;

use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::spec_by_name;
use finger_ann::eval::recall;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::FingerHnswIndex;
use finger_ann::index::{AnnIndex, SearchContext, SearchParams};

fn main() {
    // 1. Data: a scaled-down SIFT-like benchmark (20k x 128 at scale 1.0).
    let spec = spec_by_name("sift-sim-128", 0.2).unwrap();
    println!("dataset: {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();
    let gt = exact_knn(&ds.data, &ds.queries, 10);

    // 2. Index: HNSW base graph + FINGER side index (Algorithm 2), behind
    //    the `AnnIndex` trait like every other family.
    let t0 = Instant::now();
    let index = FingerHnswIndex::build(
        Arc::clone(&ds.data),
        HnswParams { m: 16, ef_construction: 120, ..Default::default() },
        FingerParams { rank: 16, ..Default::default() },
    );
    println!(
        "index built in {:.1}s ({} MB, angle-estimate correlation {:.3})",
        t0.elapsed().as_secs_f64(),
        index.nbytes() as f64 / 1e6,
        index.inner.index.matching.correlation
    );

    // 3. Search (Algorithm 4) and evaluate. One pooled context; no
    //    per-query allocation in the hot loop.
    let mut ctx = SearchContext::for_universe(index.len()).with_stats();
    let params = SearchParams::new(10).with_ef(80);
    let t0 = Instant::now();
    let mut total_recall = 0.0;
    for qi in 0..ds.queries.rows() {
        let res = index.search(ds.queries.row(qi), &params, &mut ctx);
        total_recall += recall(&res, &gt[qi]);
    }
    let secs = t0.elapsed().as_secs_f64();
    let nq = ds.queries.rows() as f64;
    let stats = ctx.take_stats();
    println!(
        "hnsw-finger: recall@10 = {:.4}, QPS = {:.0}",
        total_recall / nq,
        nq / secs
    );
    println!(
        "  distance calls/query: {:.0} full + {:.0} approx (screened {:.0}%)",
        stats.dist_calls as f64 / nq,
        stats.approx_calls as f64 / nq,
        100.0 * (1.0 - stats.dist_calls as f64 / (stats.dist_calls + stats.approx_calls) as f64)
    );

    // 4. Plain HNSW on the same graph for comparison (family-level API).
    let t0 = Instant::now();
    let mut plain_recall = 0.0;
    for qi in 0..ds.queries.rows() {
        let res = index.inner.hnsw.search(index.store(), ds.queries.row(qi), &params, &mut ctx);
        plain_recall += recall(&res, &gt[qi]);
    }
    let plain_secs = t0.elapsed().as_secs_f64();
    let plain = ctx.take_stats();
    println!(
        "hnsw (same graph): recall@10 = {:.4}, QPS = {:.0}, {:.0} full dist calls/query",
        plain_recall / nq,
        nq / plain_secs,
        plain.dist_calls as f64 / nq
    );
    println!(
        "speedup at matched recall: {:.2}x",
        plain_secs / secs
    );
}
