//! End-to-end serving driver: build an HNSW-FINGER index, start the full
//! router (TCP, dynamic batcher, worker pool, PJRT exact re-rank through
//! the AOT JAX/Pallas artifact when available), fire batched requests from
//! concurrent clients, and report latency/throughput/recall.
//!
//!   make artifacts && cargo run --release --example serve_e2e

use std::sync::Arc;
use std::time::{Duration, Instant};

use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::spec_by_name;
use finger_ann::eval::recall_ids;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::FingerHnswIndex;
use finger_ann::router::{Client, QueryRequest, ServeIndex, Server, ServerConfig};
use finger_ann::runtime::{default_artifacts_dir, service::RerankService};

fn main() {
    // Dataset matching the AOT artifact dim (128) so PJRT re-rank engages.
    let spec = spec_by_name("sift-sim-128", 0.2).unwrap();
    println!("dataset: {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();
    let gt = exact_knn(&ds.data, &ds.queries, 10);

    let t0 = Instant::now();
    let fh = FingerHnswIndex::build(
        Arc::clone(&ds.data),
        HnswParams { m: 16, ef_construction: 120, ..Default::default() },
        FingerParams { rank: 16, ..Default::default() },
    );
    println!("index built in {:.1}s", t0.elapsed().as_secs_f64());

    let queries = ds.queries.clone();
    let dim = ds.data.cols();
    let index = Arc::new(ServeIndex::new(Box::new(fh), 80));

    // PJRT re-rank service: final distances come from the AOT-compiled
    // JAX/Pallas kernel, demonstrating the Python-free request path.
    let rerank = match RerankService::start(
        default_artifacts_dir(),
        dim,
        Arc::new(index.data_clone()),
    ) {
        Ok(svc) => {
            println!("PJRT rerank online (panel width {})", svc.max_cands);
            Some(Arc::new(svc))
        }
        Err(e) => {
            println!("PJRT rerank unavailable ({e:#}); run `make artifacts`. Serving without.");
            None
        }
    };
    let use_rerank = rerank.is_some();

    let server = Server::start(
        Arc::clone(&index),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            max_queue: 4096,
            use_pjrt_rerank: use_rerank,
            ..Default::default()
        },
        rerank,
    )
    .expect("server start");
    let addr = server.local_addr;
    println!("server on {addr} (4 workers, max_batch 8, pjrt_rerank={use_rerank})");

    // Fire all benchmark queries from 8 concurrent TCP clients.
    let n_clients = 8;
    let queries = Arc::new(queries);
    let gt = Arc::new(gt);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let queries = Arc::clone(&queries);
        let gt = Arc::clone(&gt);
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut rec_sum = 0.0;
            let mut latencies = Vec::new();
            let mut count = 0usize;
            for qi in (c..queries.rows()).step_by(n_clients) {
                let resp = client
                    .query(&QueryRequest {
                        id: qi as u64,
                        vector: queries.row(qi).to_vec(),
                        k: 10,
                    })
                    .expect("query");
                let ids: Vec<u32> = resp.hits.iter().map(|&(_, id)| id).collect();
                rec_sum += recall_ids(&ids, &gt[qi]);
                latencies.push(resp.latency_us);
                count += 1;
            }
            (rec_sum, latencies, count)
        }));
    }
    let mut total_recall = 0.0;
    let mut all_lat: Vec<u64> = Vec::new();
    let mut total = 0usize;
    for h in handles {
        let (r, lat, c) = h.join().unwrap();
        total_recall += r;
        all_lat.extend(lat);
        total += c;
    }
    let wall = t0.elapsed().as_secs_f64();
    all_lat.sort_unstable();
    let pct = |p: f64| all_lat[(p / 100.0 * (all_lat.len() - 1) as f64) as usize];

    println!("--- E2E results ---");
    println!("queries: {total}  wall: {wall:.2}s  throughput: {:.0} QPS", total as f64 / wall);
    println!(
        "latency: p50={}us p90={}us p99={}us",
        pct(50.0),
        pct(90.0),
        pct(99.0)
    );
    println!("recall@10: {:.4}", total_recall / total as f64);
    println!("server metrics: {}", server.metrics.summary());
    server.shutdown();
}
