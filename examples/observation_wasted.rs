//! Reproduces the paper's §3.1 observation (Figure 2): past the early
//! phase of a greedy graph search, most distance computations exceed the
//! current upper bound and therefore cannot change the result.
//!
//!   cargo run --release --example observation_wasted

use std::sync::Arc;

use finger_ann::data::spec_by_name;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::HnswIndex;
use finger_ann::index::{AnnIndex, SearchContext, SearchParams};

fn main() {
    for name in ["fashion-sim-784", "glove-sim-100"] {
        let spec = spec_by_name(name, 0.2).unwrap();
        println!("\ndataset: {} (n={}, dim={})", spec.name, spec.n, spec.dim);
        let ds = spec.generate();
        let h = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 16, ef_construction: 120, ..Default::default() },
        );

        let mut ctx = SearchContext::for_universe(h.len()).with_stats();
        let params = SearchParams::new(10).with_ef(128);
        for qi in 0..ds.queries.rows() {
            h.search(ds.queries.row(qi), &params, &mut ctx);
        }
        let agg = ctx.take_stats();

        let hops = agg.per_hop.len().max(1);
        println!("search phase (decile) -> fraction of distance computations > upper bound");
        for d in 0..10 {
            let (mut t, mut w) = (0u64, 0u64);
            for (h_idx, &(ht, hw)) in agg.per_hop.iter().enumerate() {
                if (h_idx * 10 / hops).min(9) == d {
                    t += ht;
                    w += hw;
                }
            }
            let frac = if t == 0 { 0.0 } else { w as f64 / t as f64 };
            let bar: String = std::iter::repeat('#').take((frac * 50.0) as usize).collect();
            println!("  {d}0-{}0%: {frac:5.3} {bar}", d + 1);
        }
        println!(
            "overall: {:.1}% of {} distance computations were non-influential",
            100.0 * agg.wasted as f64 / agg.dist_calls.max(1) as f64,
            agg.dist_calls
        );
    }
    println!("\n(paper Figure 2: >80% wasted from the mid-phase on — the headroom FINGER exploits)");
}
