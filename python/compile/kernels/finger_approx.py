"""L1 Pallas kernel: FINGER approximate squared-L2 distance panel.

Implements Algorithm 3 of the paper in batched form. With c the current
expansion node, the exact squared distance decomposes (Eq. 2) as

    ||q - d||^2 = ||q_proj - d_proj||^2 + ||q_res||^2 + ||d_res||^2
                  - 2 ||q_res|| ||d_res|| cos(q_res, d_res)

FINGER estimates the cosine in a rank-r SVD subspace and corrects the bias
by Gaussian distribution matching:

    t_hat = cos(P q_res, P d_res)
    t     = (t_hat - mu_hat) * sigma / sigma_hat + mu + eps

All per-point quantities are precomputed scalars:
    qp = (c.q / c.c) * ||c||   (signed length of q's projection onto c)
    dp = (c.d / c.c) * ||c||   (same for each neighbor d, stored in index)
so  ||q_proj - d_proj||^2 = (qp - dp)^2.

The kernel's hot op is the (Q_TILE, r) @ (r, C_TILE) projected-residual
panel - the paper's "r-dim instead of m-dim dot product" insight as a
narrow MXU matmul. Distribution parameters arrive as a (8,) f32 vector
broadcast to every tile: [mu, sigma, mu_hat, sigma_hat, eps, pad, pad, pad].
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_TILE = 8
C_TILE = 128
_DENOM_FLOOR = 1e-12

# params vector layout
P_MU, P_SIGMA, P_MU_HAT, P_SIGMA_HAT, P_EPS = 0, 1, 2, 3, 4
PARAMS_LEN = 8


def _finger_kernel(pq_ref, pd_ref, qn_ref, dn_ref, qp_ref, dp_ref, prm_ref, out_ref):
    """One (Q_TILE, C_TILE) approximate-distance panel.

    pq_ref: (Q_TILE, r)  projected query residuals P q_res
    pd_ref: (C_TILE, r)  projected data residuals P d_res (precomputed)
    qn_ref: (Q_TILE,)    ||q_res||
    dn_ref: (C_TILE,)    ||d_res||   (precomputed)
    qp_ref: (Q_TILE,)    signed projection length of q onto c
    dp_ref: (C_TILE,)    signed projection length of d onto c (precomputed)
    prm_ref: (8,)        [mu, sigma, mu_hat, sigma_hat, eps, ...]
    out_ref: (Q_TILE, C_TILE) approximate squared L2 distances
    """
    pq = pq_ref[...].astype(jnp.float32)
    pd = pd_ref[...].astype(jnp.float32)
    qn = qn_ref[...].astype(jnp.float32)
    dn = dn_ref[...].astype(jnp.float32)
    qp = qp_ref[...].astype(jnp.float32)
    dp = dp_ref[...].astype(jnp.float32)
    prm = prm_ref[...].astype(jnp.float32)

    # Narrow MXU panel over the rank-r subspace.
    dots = jax.lax.dot_general(
        pq, pd, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    pqn = jnp.sqrt(jnp.sum(pq * pq, axis=1))  # (Q_TILE,)
    pdn = jnp.sqrt(jnp.sum(pd * pd, axis=1))  # (C_TILE,)
    denom = jnp.maximum(pqn[:, None] * pdn[None, :], _DENOM_FLOOR)
    t_hat = dots / denom

    mu, sigma = prm[P_MU], prm[P_SIGMA]
    mu_hat, sigma_hat = prm[P_MU_HAT], prm[P_SIGMA_HAT]
    eps = prm[P_EPS]
    scale = sigma / jnp.maximum(sigma_hat, _DENOM_FLOOR)
    t = (t_hat - mu_hat) * scale + mu + eps

    proj = (qp[:, None] - dp[None, :]) ** 2
    out = proj + qn[:, None] ** 2 + dn[None, :] ** 2 - 2.0 * qn[:, None] * dn[None, :] * t
    out_ref[...] = out.astype(out_ref.dtype)


def _pad_to(x, axis, multiple):
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def finger_approx(pq, pd, q_res_norm, d_res_norm, q_proj, d_proj, params,
                  q_tile=Q_TILE, c_tile=C_TILE):
    """Batched FINGER approximate squared-L2 distances.

    pq: (B, r), pd: (C, r), q_res_norm: (B,), d_res_norm: (C,),
    q_proj: (B,), d_proj: (C,), params: (8,) - see module docstring.
    Returns (B, C) approximate squared distances.
    """
    B, r = pq.shape
    C, rd = pd.shape
    assert rd == r
    params = jnp.asarray(params, jnp.float32)
    assert params.shape == (PARAMS_LEN,)
    pqp = _pad_to(pq, 0, q_tile)
    pdp = _pad_to(pd, 0, c_tile)
    qnp_ = _pad_to(q_res_norm, 0, q_tile)
    dnp = _pad_to(d_res_norm, 0, c_tile)
    qpp = _pad_to(q_proj, 0, q_tile)
    dpp = _pad_to(d_proj, 0, c_tile)
    Bp, Cp = pqp.shape[0], pdp.shape[0]
    grid = (Bp // q_tile, Cp // c_tile)
    out = pl.pallas_call(
        _finger_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, r), lambda i, j: (i, 0)),
            pl.BlockSpec((c_tile, r), lambda i, j: (j, 0)),
            pl.BlockSpec((q_tile,), lambda i, j: (i,)),
            pl.BlockSpec((c_tile,), lambda i, j: (j,)),
            pl.BlockSpec((q_tile,), lambda i, j: (i,)),
            pl.BlockSpec((c_tile,), lambda i, j: (j,)),
            pl.BlockSpec((PARAMS_LEN,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((q_tile, c_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Cp), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(pqp, pdp, qnp_, dnp, qpp, dpp, params)
    return out[:B, :C]
