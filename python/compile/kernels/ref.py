"""Pure-jnp correctness oracles for the Pallas kernels.

Deliberately written in the most direct form possible (no tiling, no
identity tricks where avoidable) so the pytest comparison is a real
independent check, not a re-statement of the kernel.
"""

import jax.numpy as jnp

_DENOM_FLOOR = 1e-12


def batch_l2_ref(q, d, d_sqnorm=None):
    """(B, C) squared L2 distances, computed the naive way.

    d_sqnorm is accepted for signature parity with the kernel but the
    reference recomputes everything from q and d directly.
    """
    diff = q[:, None, :] - d[None, :, :]  # (B, C, m)
    return jnp.sum(diff * diff, axis=-1)


def finger_approx_ref(pq, pd, q_res_norm, d_res_norm, q_proj, d_proj, params):
    """(B, C) FINGER approximate squared distances (Algorithm 3), naive form."""
    params = jnp.asarray(params, jnp.float32)
    mu, sigma, mu_hat, sigma_hat, eps = (
        params[0], params[1], params[2], params[3], params[4],
    )
    pqn = jnp.linalg.norm(pq, axis=1)  # (B,)
    pdn = jnp.linalg.norm(pd, axis=1)  # (C,)
    dots = pq @ pd.T
    denom = jnp.maximum(pqn[:, None] * pdn[None, :], _DENOM_FLOOR)
    t_hat = dots / denom
    t = (t_hat - mu_hat) * (sigma / jnp.maximum(sigma_hat, _DENOM_FLOOR)) + mu + eps
    proj = (q_proj[:, None] - d_proj[None, :]) ** 2
    return (
        proj
        + q_res_norm[:, None] ** 2
        + d_res_norm[None, :] ** 2
        - 2.0 * q_res_norm[:, None] * d_res_norm[None, :] * t
    )


def rerank_topk_ref(q, cands, k):
    """Exact top-k (distances, indices) by full sort - oracle for the L2 graph."""
    dist = batch_l2_ref(q, cands)
    idx = jnp.argsort(dist, axis=1)[:, :k]
    vals = jnp.take_along_axis(dist, idx, axis=1)
    return vals, idx
