"""L1 Pallas kernel: tiled batched squared-L2 distance panel.

The hot operation of any graph ANN search is "distances from a query batch
to a candidate set". Expressed via the identity

    ||q - d||^2 = ||q||^2 + ||d||^2 - 2 q.d

the bulk of the work is the cross-term matmul Q @ D^T, which maps straight
onto the TPU MXU systolic array. ||d||^2 is precomputed at index-build time
and streamed in.

TPU adaptation (DESIGN.md section 4): the kernel tiles the (B queries x C
candidates) panel with BlockSpecs sized for VMEM residency - a (Q_TILE, m)
query block and a (C_TILE, m) candidate block are resident while the MXU
computes the Q_TILE x C_TILE panel. The paper's AVX2 inner loop becomes a
matmul panel; `interpret=True` is mandatory on the CPU PJRT plugin (real-TPU
lowering emits Mosaic custom-calls the CPU client cannot execute).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes chosen for VMEM residency on a real TPU core (16 MiB VMEM):
# f32 operands at m=960: (8+128)*960*4 B ~ 0.5 MiB per step plus the 8x128
# f32 output panel - comfortably double-bufferable. See EXPERIMENTS.md.
Q_TILE = 8
C_TILE = 128


def _l2_kernel(q_ref, d_ref, dsq_ref, out_ref):
    """One (Q_TILE, C_TILE) output panel.

    q_ref:   (Q_TILE, m)  query block
    d_ref:   (C_TILE, m)  candidate block
    dsq_ref: (C_TILE,)    precomputed ||d||^2 for the block
    out_ref: (Q_TILE, C_TILE) squared L2 distances
    """
    q = q_ref[...]
    d = d_ref[...]
    dsq = dsq_ref[...]
    qsq = jnp.sum(q * q, axis=1, keepdims=True)  # (Q_TILE, 1)
    # The MXU panel: contract over the feature dimension in f32.
    cross = jax.lax.dot_general(
        q, d, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out = qsq + dsq[None, :].astype(jnp.float32) - 2.0 * cross
    out_ref[...] = out.astype(out_ref.dtype)


def _pad_to(x, axis, multiple, value=0.0):
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def batch_l2(q, d, d_sqnorm, q_tile=Q_TILE, c_tile=C_TILE):
    """Squared L2 distance panel between query batch and candidate set.

    q:        (B, m) float queries
    d:        (C, m) float candidates
    d_sqnorm: (C,)   precomputed squared norms of the candidates
    returns   (B, C) squared L2 distances, dtype of q

    Shapes need not be tile-multiples; inputs are zero-padded and the output
    is sliced back (zero-padded candidates produce garbage rows that are
    discarded by the slice).
    """
    B, m = q.shape
    C, md = d.shape
    assert md == m, f"dim mismatch {m} vs {md}"
    assert d_sqnorm.shape == (C,)
    qp = _pad_to(q, 0, q_tile)
    dp = _pad_to(d, 0, c_tile)
    dsqp = _pad_to(d_sqnorm, 0, c_tile)
    Bp, Cp = qp.shape[0], dp.shape[0]
    grid = (Bp // q_tile, Cp // c_tile)
    out = pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_tile, m), lambda i, j: (i, 0)),
            pl.BlockSpec((c_tile, m), lambda i, j: (j, 0)),
            pl.BlockSpec((c_tile,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((q_tile, c_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Cp), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qp, dp, dsqp)
    return out[:B, :C]
