"""AOT lowering: jit the L2 entry points at fixed shapes, emit HLO TEXT.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects (`proto.id() <=
INT_MAX`). The text parser reassigns ids, so text round-trips cleanly.

Each artifact is one fully-static-shape HLO module; a ``manifest.json``
records names, shapes and tuple layouts so the Rust runtime can pad inputs
and unpack outputs without guessing.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def entries():
    """(name, fn, arg_specs, meta) for every artifact we ship.

    Shapes cover the serving path (batch 8, candidate panel 256) for the
    dataset dims the examples use, plus one small config for Rust unit
    tests. Wrap fns as 1-tuples where needed so the Rust side always sees a
    tuple root.
    """
    out = []

    def add(name, fn, specs, meta):
        out.append((name, fn, specs, meta))

    for dim in (96, 128):
        b, c = 8, 256
        add(
            f"score_l2_b{b}_c{c}_d{dim}",
            lambda q, d, dsq: (model.score_l2(q, d, dsq),),
            [_spec((b, dim)), _spec((c, dim)), _spec((c,))],
            {"kind": "score_l2", "batch": b, "cands": c, "dim": dim,
             "outputs": [{"shape": [b, c], "dtype": "f32"}]},
        )
        k = 10
        add(
            f"rerank_b{b}_c{c}_d{dim}_k{k}",
            functools.partial(model.rerank_topk, k=k),
            [_spec((b, dim)), _spec((c, dim)), _spec((c,))],
            {"kind": "rerank", "batch": b, "cands": c, "dim": dim, "k": k,
             "outputs": [{"shape": [b, k], "dtype": "f32"},
                         {"shape": [b, k], "dtype": "i32"}]},
        )

    for r in (16, 32):
        b, c = 8, 256
        add(
            f"finger_b{b}_c{c}_r{r}",
            lambda pq, pd, qn, dn, qp, dp, prm: (
                model.finger_score(pq, pd, qn, dn, qp, dp, prm),
            ),
            [_spec((b, r)), _spec((c, r)), _spec((b,)), _spec((c,)),
             _spec((b,)), _spec((c,)), _spec((8,))],
            {"kind": "finger", "batch": b, "cands": c, "rank": r,
             "outputs": [{"shape": [b, c], "dtype": "f32"}]},
        )

    # Small config exercised by Rust runtime unit tests (fast to execute).
    b, c, dim, k = 4, 64, 32, 5
    add(
        f"rerank_b{b}_c{c}_d{dim}_k{k}",
        functools.partial(model.rerank_topk, k=k),
        [_spec((b, dim)), _spec((c, dim)), _spec((c,))],
        {"kind": "rerank", "batch": b, "cands": c, "dim": dim, "k": k,
         "outputs": [{"shape": [b, k], "dtype": "f32"},
                     {"shape": [b, k], "dtype": "i32"}]},
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": {}}
    for name, fn, specs, meta in entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = fname
        meta["inputs"] = [
            {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
        ]
        manifest["artifacts"][name] = meta
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
