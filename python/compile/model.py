"""L2: the JAX compute graph the Rust runtime executes via PJRT.

Three jit-able entry points, each calling the L1 Pallas kernels:

* ``score_l2``     - raw squared-L2 distance panel (batch scoring).
* ``rerank_topk``  - exact re-rank: score the candidate panel and return the
                     top-k (distances, indices). This is the artifact the
                     serving path runs on every answered request.
* ``finger_score`` - batched FINGER approximate distances (Algorithm 3).

Everything here is build-time Python: ``aot.py`` lowers these functions once
to HLO text and the Rust coordinator loads the artifacts. Python is never on
the request path.
"""

import jax
import jax.numpy as jnp

from compile.kernels.batch_l2 import batch_l2
from compile.kernels.finger_approx import finger_approx


def score_l2(q, d, d_sqnorm):
    """(B, C) squared L2 distance panel. Thin wrapper over the L1 kernel."""
    return batch_l2(q, d, d_sqnorm)


def rerank_topk(q, cands, cands_sqnorm, k):
    """Exact top-k re-rank of a candidate panel.

    q:            (B, m) query batch
    cands:        (C, m) candidate vectors (gathered by the Rust router)
    cands_sqnorm: (C,)   precomputed squared norms
    k:            static int

    Returns (dist, idx): (B, k) squared distances ascending, (B, k) i32
    positions into the candidate panel. The Rust side maps positions back to
    global ids. Padded candidate slots should carry a large value in
    cands_sqnorm so they sort last.
    """
    dist = batch_l2(q, cands, cands_sqnorm)
    # NOTE: jax.lax.top_k lowers to the `topk` HLO instruction, which the
    # runtime's HLO text parser (xla_extension 0.5.1) does not know. A
    # variadic lax.sort lowers to the classic `sort` op instead.
    c = dist.shape[1]
    idx = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), dist.shape)
    sorted_dist, sorted_idx = jax.lax.sort((dist, idx), dimension=1, num_keys=1)
    return sorted_dist[:, :k], sorted_idx[:, :k]


def finger_score(pq, pd, q_res_norm, d_res_norm, q_proj, d_proj, params):
    """Batched FINGER approximate squared distances (Algorithm 3)."""
    return finger_approx(pq, pd, q_res_norm, d_res_norm, q_proj, d_proj, params)
