"""Pallas kernels vs pure-jnp oracle - the core L1 correctness signal.

hypothesis sweeps shapes (including non-tile-multiples, which exercise the
padding path) and dtypes; fixed-seed numpy draws keep cases reproducible.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.batch_l2 import batch_l2
from compile.kernels.finger_approx import finger_approx, PARAMS_LEN
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- batch_l2

@settings(**SETTINGS)
@given(
    b=st.integers(1, 33),
    c=st.integers(1, 300),
    m=st.sampled_from([3, 16, 96, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_batch_l2_matches_ref(b, c, m, seed):
    r = _rng(seed)
    q = r.standard_normal((b, m)).astype(np.float32)
    d = r.standard_normal((c, m)).astype(np.float32)
    dsq = np.sum(d * d, axis=1)
    got = np.asarray(batch_l2(jnp.asarray(q), jnp.asarray(d), jnp.asarray(dsq)))
    want = np.asarray(ref.batch_l2_ref(jnp.asarray(q), jnp.asarray(d)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(
    dtype=st.sampled_from([np.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_batch_l2_dtypes(dtype, seed):
    r = _rng(seed)
    q32 = r.standard_normal((8, 64)).astype(np.float32)
    d32 = r.standard_normal((128, 64)).astype(np.float32)
    q = jnp.asarray(q32, dtype)
    d = jnp.asarray(d32, dtype)
    dsq = jnp.sum(d.astype(jnp.float32) ** 2, axis=1)
    got = np.asarray(batch_l2(q, d, dsq), np.float32)
    want = np.asarray(ref.batch_l2_ref(jnp.asarray(q32), jnp.asarray(d32)))
    tol = 5e-4 if dtype == np.float32 else 0.35
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_batch_l2_zero_distance_on_identical_points():
    r = _rng(0)
    d = r.standard_normal((16, 32)).astype(np.float32)
    dsq = np.sum(d * d, axis=1)
    got = np.asarray(batch_l2(jnp.asarray(d[:4]), jnp.asarray(d), jnp.asarray(dsq)))
    # Diagonal entries are distances from a point to itself.
    diag = np.array([got[i, i] for i in range(4)])
    np.testing.assert_allclose(diag, np.zeros(4), atol=1e-3)


def test_batch_l2_exact_tile_shapes():
    """Shapes exactly at the tile boundary (no padding path)."""
    r = _rng(7)
    q = r.standard_normal((8, 128)).astype(np.float32)
    d = r.standard_normal((256, 128)).astype(np.float32)
    dsq = np.sum(d * d, axis=1)
    got = np.asarray(batch_l2(jnp.asarray(q), jnp.asarray(d), jnp.asarray(dsq)))
    want = np.asarray(ref.batch_l2_ref(jnp.asarray(q), jnp.asarray(d)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ finger_approx

def _finger_inputs(r, b, c, rank, params=None):
    pq = r.standard_normal((b, rank)).astype(np.float32)
    pd = r.standard_normal((c, rank)).astype(np.float32)
    qn = np.abs(r.standard_normal(b)).astype(np.float32)
    dn = np.abs(r.standard_normal(c)).astype(np.float32)
    qp = r.standard_normal(b).astype(np.float32)
    dp = r.standard_normal(c).astype(np.float32)
    if params is None:
        prm = np.zeros(PARAMS_LEN, np.float32)
        prm[:5] = [0.02, 0.3, -0.01, 0.35, 0.005]  # mu, sigma, mu_hat, sigma_hat, eps
    else:
        prm = params
    return pq, pd, qn, dn, qp, dp, prm


@settings(**SETTINGS)
@given(
    b=st.integers(1, 20),
    c=st.integers(1, 200),
    rank=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_finger_matches_ref(b, c, rank, seed):
    args = _finger_inputs(_rng(seed), b, c, rank)
    jargs = [jnp.asarray(a) for a in args]
    got = np.asarray(finger_approx(*jargs))
    want = np.asarray(ref.finger_approx_ref(*jargs))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_finger_identity_params_full_rank_recovers_exact_l2():
    """With P = I and identity distribution matching, Algorithm 3 reduces to
    Eq. 2 exactly, so the approx distance equals the true squared L2."""
    r = _rng(3)
    m = 16
    c_vec = r.standard_normal(m).astype(np.float32)
    c_sq = float(c_vec @ c_vec)
    q = r.standard_normal((6, m)).astype(np.float32)
    d = r.standard_normal((40, m)).astype(np.float32)

    def decompose(x):
        coef = (x @ c_vec) / c_sq              # (n,)
        proj = coef[:, None] * c_vec[None, :]  # (n, m)
        res = x - proj
        return coef * np.sqrt(c_sq), res       # signed proj length, residual

    qp, q_res = decompose(q)
    dp, d_res = decompose(d)
    qn = np.linalg.norm(q_res, axis=1)
    dn = np.linalg.norm(d_res, axis=1)
    prm = np.zeros(PARAMS_LEN, np.float32)
    prm[:5] = [0.0, 1.0, 0.0, 1.0, 0.0]  # identity matching
    got = np.asarray(finger_approx(
        jnp.asarray(q_res), jnp.asarray(d_res), jnp.asarray(qn), jnp.asarray(dn),
        jnp.asarray(qp.astype(np.float32)), jnp.asarray(dp.astype(np.float32)),
        jnp.asarray(prm)))
    want = np.asarray(ref.batch_l2_ref(jnp.asarray(q), jnp.asarray(d)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_finger_distribution_matching_shifts_values():
    """Changing (mu, sigma) must move the estimate in the documented
    direction: larger mu -> larger cosine estimate -> smaller distance."""
    r = _rng(11)
    pq, pd, qn, dn, qp, dp, prm = _finger_inputs(r, 4, 32, 16)
    lo = prm.copy(); lo[0] = -0.5
    hi = prm.copy(); hi[0] = 0.5
    d_lo = np.asarray(finger_approx(*[jnp.asarray(a) for a in (pq, pd, qn, dn, qp, dp, lo)]))
    d_hi = np.asarray(finger_approx(*[jnp.asarray(a) for a in (pq, pd, qn, dn, qp, dp, hi)]))
    # distance = ... - 2*qn*dn*t, and t is affine-increasing in mu
    assert np.all(d_hi <= d_lo + 1e-5)


def test_finger_zero_residual_query_is_stable():
    """A query lying exactly along the center (q_res = 0) must not NaN."""
    r = _rng(5)
    pq = np.zeros((2, 16), np.float32)
    pd = r.standard_normal((32, 16)).astype(np.float32)
    qn = np.zeros(2, np.float32)
    dn = np.abs(r.standard_normal(32)).astype(np.float32)
    qp = r.standard_normal(2).astype(np.float32)
    dp = r.standard_normal(32).astype(np.float32)
    prm = np.zeros(PARAMS_LEN, np.float32)
    prm[:5] = [0.0, 1.0, 0.0, 1.0, 0.0]
    got = np.asarray(finger_approx(*[jnp.asarray(a) for a in (pq, pd, qn, dn, qp, dp, prm)]))
    assert np.all(np.isfinite(got))
