"""L2 model graph tests: rerank shapes/semantics and AOT lowering."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref
from compile.aot import to_hlo_text, entries

import jax


def test_rerank_topk_matches_ref():
    r = np.random.default_rng(0)
    q = r.standard_normal((8, 64)).astype(np.float32)
    d = r.standard_normal((200, 64)).astype(np.float32)
    dsq = np.sum(d * d, axis=1)
    dist, idx = model.rerank_topk(jnp.asarray(q), jnp.asarray(d), jnp.asarray(dsq), k=10)
    _, want_idx = ref.rerank_topk_ref(jnp.asarray(q), jnp.asarray(d), 10)
    # Indices must match the oracle (distances are distinct w.p. 1).
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_idx))
    # Distances ascending within each row.
    dist = np.asarray(dist)
    assert np.all(np.diff(dist, axis=1) >= -1e-6)


def test_rerank_padded_slots_sort_last():
    """Slots padded with huge sqnorm (the runtime's padding convention) must
    never appear in the top-k when enough real candidates exist."""
    r = np.random.default_rng(1)
    q = r.standard_normal((4, 32)).astype(np.float32)
    d = np.zeros((64, 32), np.float32)
    d[:40] = r.standard_normal((40, 32)).astype(np.float32)
    dsq = np.full(64, 1e30, np.float32)
    dsq[:40] = np.sum(d[:40] * d[:40], axis=1)
    _, idx = model.rerank_topk(jnp.asarray(q), jnp.asarray(d), jnp.asarray(dsq), k=5)
    assert np.all(np.asarray(idx) < 40)


def test_rerank_i32_indices():
    r = np.random.default_rng(2)
    q = r.standard_normal((2, 16)).astype(np.float32)
    d = r.standard_normal((32, 16)).astype(np.float32)
    dsq = np.sum(d * d, axis=1)
    _, idx = model.rerank_topk(jnp.asarray(q), jnp.asarray(d), jnp.asarray(dsq), k=3)
    assert idx.dtype == jnp.int32


# ------------------------------------------------------------------- AOT

def test_aot_entries_lower_to_hlo_text():
    """Every shipped artifact must lower to parseable-looking HLO text."""
    for name, fn, specs, meta in entries():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # return_tuple=True: root must be a tuple
        assert "tuple(" in text or "(f32" in text, name


def test_aot_manifest_meta_consistent():
    for name, fn, specs, meta in entries():
        assert meta["kind"] in ("score_l2", "rerank", "finger")
        for o in meta["outputs"]:
            assert o["dtype"] in ("f32", "i32")
        assert len(specs) >= 3
