//! Golden-fixture persistence compatibility: checked-in v3, v4, and v5
//! index files must keep loading on v6 code, bitwise-identical to a
//! fresh build over the same data — and a corrupt or truncated mutation
//! section must be rejected with an error, never a panic.
//!
//! Fixture layout (all files share the 12x4 matrix with
//! `val(i, j) = 0.5 * (i*4 + j) - 3.0`, every value exactly representable
//! in f32 so bitwise comparison is meaningful):
//!
//! * `v3_bruteforce.idx` — magic | version 3 | tag 6 | matrix. The
//!   bruteforce payload was empty in v3.
//! * `v4_sharded.idx` — magic | version 4 | tag 7 | matrix | strategy 0
//!   (round-robin) | frac [1.0] | S=2 | per shard: even/odd row ids,
//!   centroid, sub tag 6, sub matrix. No mutation sections anywhere.
//! * `v5_bruteforce_mutable.idx` — magic | version 5 | tag 6 | 13x4
//!   matrix (fixture rows + inserted `[9,9,9,9]`) | watermark 13 |
//!   row ids 0..=12 | dead rows [5]. No quantized-tier section (pre-v6).
//! * `v6_bruteforce_sq8.idx` — magic | version 6 | tag 6 | the same 13x4
//!   matrix | precision 1 (sq8) | mins | maxs | [delta] | 52 code bytes |
//!   the same mutation section. The golden copy of the current format:
//!   the writer must keep producing exactly these bytes.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use finger_ann::core::matrix::Matrix;
use finger_ann::core::store::VectorStore;
use finger_ann::data::persist::{load_index, save_index};
use finger_ann::graph::bruteforce::scan;
use finger_ann::index::impls::BruteForce;
use finger_ann::index::{AnnIndex, MutableAnnIndex, SearchContext, SearchParams};
use finger_ann::quant::Precision;

const ROWS: usize = 12;
const COLS: usize = 4;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(name)
}

/// The exact matrix baked into the fixtures.
fn fixture_matrix() -> Matrix {
    let mut m = Matrix::zeros(0, COLS);
    for i in 0..ROWS {
        let row: Vec<f32> = (0..COLS)
            .map(|j| 0.5 * (i * COLS + j) as f32 - 3.0)
            .collect();
        m.push_row(&row);
    }
    m
}

fn probes() -> Vec<Vec<f32>> {
    (0..5)
        .map(|p| (0..COLS).map(|j| p as f32 * 1.3 + j as f32 * 0.1 - 2.0).collect())
        .collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("finger_compat_{}_{name}", std::process::id()))
}

fn assert_matrix_bitwise_equal(got: &Matrix, want: &Matrix) {
    assert_eq!(got.rows(), want.rows());
    assert_eq!(got.cols(), want.cols());
    for i in 0..got.rows() {
        for (a, b) in got.row(i).iter().zip(want.row(i)) {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i} diverges");
        }
    }
}

#[test]
fn v3_fixture_loads_identical_to_fresh_build() {
    let loaded = load_index(&fixture("v3_bruteforce.idx")).expect("v3 still loads");
    assert_eq!(loaded.name(), "bruteforce");
    assert_eq!(loaded.len(), ROWS);
    assert_eq!(loaded.dim(), COLS);
    let want = fixture_matrix();
    assert_matrix_bitwise_equal(loaded.data(), &want);

    let fresh = BruteForce::new(Arc::new(want));
    let mut ctx = SearchContext::new();
    let params = SearchParams::new(4);
    for (i, q) in probes().iter().enumerate() {
        let a = loaded.search(q, &params, &mut ctx);
        let b = fresh.search(q, &params, &mut ctx);
        assert_eq!(a, b, "probe {i}");
    }
    // Pre-v5 files load with identity mutation state and stay mutable.
    let view = loaded.as_mutable_view().expect("bruteforce is mutable");
    assert_eq!(view.live_len(), ROWS);
    assert_eq!(view.tombstone_fraction(), 0.0);
}

#[test]
fn v4_sharded_fixture_loads_identical_to_fresh_scan() {
    let loaded = load_index(&fixture("v4_sharded.idx")).expect("v4 still loads");
    assert_eq!(loaded.name(), "sharded-bruteforce");
    assert_eq!(loaded.len(), ROWS);
    let want = fixture_matrix();
    assert_matrix_bitwise_equal(loaded.data(), &want);

    let mut ctx = SearchContext::new();
    let params = SearchParams::new(4);
    let store = VectorStore::from_matrix(&want);
    for (i, q) in probes().iter().enumerate() {
        let got = loaded.search(q, &params, &mut ctx);
        let exact = scan(&store, q, 4);
        assert_eq!(got, exact, "probe {i}: full-probe sharded != exact scan");
    }
    let view = loaded.as_mutable_view().expect("sharded bruteforce is mutable");
    assert_eq!(view.live_len(), ROWS);
}

#[test]
fn resaving_a_v3_fixture_as_v6_preserves_results() {
    let loaded = load_index(&fixture("v3_bruteforce.idx")).unwrap();
    let path = tmp("resave_v6.idx");
    save_index(&path, loaded.as_ref()).unwrap();
    let resaved = load_index(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let mut ctx = SearchContext::new();
    let params = SearchParams::new(4);
    for q in probes() {
        let a = loaded.search(&q, &params, &mut ctx);
        let b = resaved.search(&q, &params, &mut ctx);
        assert_eq!(a, b);
    }
}

#[test]
fn v5_mutable_fixture_loads_on_v6_code_with_its_mutation_state() {
    // v5 -> v6 load compat: the checked-in v5 bundle (no quantized-tier
    // section) keeps loading, carrying its live mutation section, and a
    // replay of its history searches identically.
    let loaded = load_index(&fixture("v5_bruteforce_mutable.idx")).expect("v5 still loads");
    assert_eq!(loaded.name(), "bruteforce"); // no tier in a pre-v6 file
    assert_eq!(loaded.len(), ROWS + 1);
    let view = loaded.as_mutable_view().expect("bruteforce is mutable");
    assert_eq!(view.live_len(), ROWS); // 13 rows, one tombstoned
    assert!(!view.is_live(5));
    assert!(view.is_live(12));

    let mut idx = BruteForce::new(Arc::new(fixture_matrix()));
    let mut ctx = SearchContext::new();
    assert_eq!(idx.insert(&[9.0, 9.0, 9.0, 9.0], &mut ctx).unwrap(), 12);
    idx.remove(5).unwrap();
    let params = SearchParams::new(4);
    for (i, q) in probes().iter().enumerate() {
        let a = loaded.search(q, &params, &mut ctx);
        let b = idx.search(q, &params, &mut ctx);
        assert_eq!(a, b, "probe {i}: v5 load diverges from replayed history");
    }
}

#[test]
fn v6_quantized_fixture_is_byte_stable_and_loads_its_tier() {
    // Load side: the checked-in v6 bundle carries an sq8 tier (codec
    // frozen on the 12 build rows, codes in lockstep through the insert)
    // plus the same mutation section as the v5 fixture.
    let loaded = load_index(&fixture("v6_bruteforce_sq8.idx")).expect("v6 loads");
    assert_eq!(loaded.name(), "bruteforce-sq8");
    assert_eq!(loaded.len(), ROWS + 1);
    let view = loaded.as_mutable_view().expect("bruteforce-sq8 is mutable");
    assert_eq!(view.live_len(), ROWS);
    assert!(!view.is_live(5));
    assert!(view.is_live(12));

    // Replaying the same history on today's code must search identically
    // (same frozen codec, same codes, same exact re-rank).
    let mut idx = BruteForce::with_precision(Arc::new(fixture_matrix()), Precision::Sq8);
    let mut ctx = SearchContext::new();
    assert_eq!(idx.insert(&[9.0, 9.0, 9.0, 9.0], &mut ctx).unwrap(), 12);
    idx.remove(5).unwrap();
    let params = SearchParams::new(4);
    for (i, q) in probes().iter().enumerate() {
        let a = loaded.search(q, &params, &mut ctx);
        let b = idx.search(q, &params, &mut ctx);
        assert_eq!(a, b, "probe {i}: v6 load diverges from replayed history");
    }

    // Save side: the golden pin on the current writer. Replaying the
    // fixture's history must reproduce the checked-in bytes exactly —
    // codec ranges, delta, code rows, and mutation section included.
    let path = tmp("v6_golden_resave.idx");
    save_index(&path, &idx).unwrap();
    let fresh = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let golden = std::fs::read(fixture("v6_bruteforce_sq8.idx")).unwrap();
    assert_eq!(fresh, golden, "v6 writer no longer byte-matches the golden fixture");
}

#[test]
fn corrupt_or_truncated_tombstone_section_is_rejected() {
    // Build a current-format bundle with a non-trivial mutation section:
    // one insert, one delete. The bruteforce payload is the quant tag
    // (F32 here) followed by the live section, so the live state sits at
    // the tail of the file: ... | watermark u64 | row-id slice
    // | dead-row slice — whose final 4 bytes are the single dead entry.
    let mut idx = BruteForce::new(Arc::new(fixture_matrix()));
    let mut ctx = SearchContext::new();
    idx.insert(&[9.0, 9.0, 9.0, 9.0], &mut ctx).unwrap();
    idx.remove(5).unwrap();
    let path = tmp("v6_tomb.idx");
    save_index(&path, &idx).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // Sanity: the intact bytes load and preserve the mutation state.
    let p = tmp("v5_ok.idx");
    std::fs::write(&p, &bytes).unwrap();
    let ok = load_index(&p).unwrap();
    assert_eq!(ok.as_mutable_view().unwrap().live_len(), ROWS);
    assert!(!ok.as_mutable_view().unwrap().is_live(5));
    std::fs::remove_file(&p).ok();

    // Truncation anywhere in the tombstone section: clean error.
    for cut in [bytes.len() - 3, bytes.len() - 9, bytes.len() - 20] {
        let p = tmp(&format!("v5_trunc_{cut}.idx"));
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(load_index(&p).is_err(), "truncated at {cut} still loaded");
        std::fs::remove_file(&p).ok();
    }

    // Out-of-range tombstoned row: InvalidData, not a panic.
    let mut corrupt = bytes.clone();
    let n = corrupt.len();
    corrupt[n - 4..].copy_from_slice(&9999u32.to_le_bytes());
    let p = tmp("v5_badrow.idx");
    std::fs::write(&p, &corrupt).unwrap();
    let err = load_index(&p).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    std::fs::remove_file(&p).ok();

    // Watermark below an assigned id: InvalidData. The watermark is the
    // first u64 of the live section; for this bundle that is 8 (watermark)
    // + 8 + 13*4 (row ids) + 8 + 4 (dead list) = 80 bytes from the end.
    let mut corrupt = bytes;
    let off = n - 80;
    corrupt[off..off + 8].copy_from_slice(&1u64.to_le_bytes());
    let p = tmp("v5_badmark.idx");
    std::fs::write(&p, &corrupt).unwrap();
    let err = load_index(&p).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    std::fs::remove_file(&p).ok();
}
