//! Failover properties of the quorum replication plane, end to end:
//!
//! * **Kill the leader** — a three-node in-process cluster churns
//!   quorum-acked writes, loses its elected leader, elects a successor
//!   holding every acked op (log matching), resumes writes, and the
//!   survivors converge byte-identically to an uninterrupted control
//!   run.
//! * **Split brain** — a leader partitioned away from the election
//!   plane keeps serving reads but degrades writes to a structured
//!   `no-quorum` error; on healing it observes the newer term, steps
//!   down, fences stale writes with a redirect to the new leader, and
//!   re-converges (its divergent tail is wiped by a forced snapshot).
//! * **Flapping partitions** — the leader's replication stream runs
//!   through a fault proxy injecting symmetric partitions on a seeded
//!   budget; followers ride capped-backoff reconnects through the flaps
//!   and converge once the budget is spent.
//! * **Replica warm-up** — a `serve --replica-of` process binds its
//!   query listener *before* catch-up and answers a structured
//!   `{"state":"warming"}` until the readiness latch flips; session
//!   `min_seq` tokens are refused by a replica still behind them.
//! * **Process-level failover smoke** — three `serve --cluster`
//!   processes elect a leader, quorum-ack writes, survive a SIGKILL of
//!   the leader mid-churn with byte-fingerprint convergence, resume
//!   writes on the successor, and answer `repl leader` from any node.

use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use finger_ann::core::distance::Metric;
use finger_ann::core::json::Json;
use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::data::persist::{bundle_to_vec, save_index};
use finger_ann::data::synth::tiny;
use finger_ann::index::impls::BruteForce;
use finger_ann::index::{AnnIndex, SearchContext, SearchParams};
use finger_ann::repl::cluster::{ClusterNode, ClusterOpts};
use finger_ann::repl::election::{ElectionConfig, ElectionNode, PeerSpec, Role};
use finger_ann::repl::frame::Frame;
use finger_ann::repl::hub::HubOpts;
use finger_ann::repl::{fnv1a64, AckLevel};
use finger_ann::router::protocol::{FingerprintInfo, QueryRequest};
use finger_ann::router::{Client, MutOutcome, Request, ServeIndex};
use finger_ann::testutil::proxy::{FaultPlan, FaultProxy};
use finger_ann::wal::{FsyncPolicy, Wal};

const DIM: usize = 6;
const N0: usize = 24;

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("finger_failover_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn gauss(rng: &mut Pcg32) -> Vec<f32> {
    (0..DIM).map(|_| rng.next_gaussian()).collect()
}

/// One in-process cluster member: its serving index and supervisor.
struct Node {
    serve: Arc<ServeIndex>,
    cluster: Arc<ClusterNode>,
}

/// A three-node in-process cluster over a shared seed dataset. Every
/// node bootstraps its own WAL from the same deterministic index, so
/// the initial states are byte-identical. With `proxied`, each node
/// advertises a fault proxy (symmetric partitions, seeded budget) in
/// front of its replication listener — only the elected leader's proxy
/// ever carries traffic.
fn start_cluster(
    root: &Path,
    data: &Arc<Matrix>,
    proxied: bool,
    ack_timeout: Duration,
) -> (Vec<Node>, Vec<FaultProxy>) {
    let n = 3;
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind election")).collect();
    let eaddrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    let mut nodes = Vec::with_capacity(n);
    let mut proxies = Vec::new();
    for (i, listener) in listeners.into_iter().enumerate() {
        let dir = root.join(format!("node{}", i + 1));
        let index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::clone(data)));
        let wal = Arc::new(
            Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Always).expect("bootstrap"),
        );
        let serve = Arc::new(
            ServeIndex::with_params(index, SearchParams::new(10))
                .with_wal(Arc::clone(&wal))
                .in_cluster(),
        );
        let repl_listener = TcpListener::bind("127.0.0.1:0").expect("bind repl");
        let repl_local = repl_listener.local_addr().unwrap();
        let advert = if proxied {
            let proxy = FaultProxy::start(
                repl_local,
                FaultPlan::partitions_only(0xF1A9 ^ i as u64, 100, 2),
            )
            .expect("proxy start");
            let a = proxy.local_addr;
            proxies.push(proxy);
            a
        } else {
            repl_local
        };
        let peers = eaddrs
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != i)
            .map(|(j, a)| PeerSpec { id: (j + 1) as u64, addr: a.clone() })
            .collect();
        let election = ElectionNode::start_on(
            ElectionConfig {
                id: (i + 1) as u64,
                listen: String::new(),
                peers,
                election_timeout: Duration::from_millis(200),
                heartbeat_interval: Duration::from_millis(50),
                state_dir: Some(dir.clone()),
                seed: 0xE1EC + i as u64,
            },
            listener,
        )
        .expect("start election");
        let cluster = ClusterNode::start(
            election,
            repl_listener,
            Arc::clone(&wal),
            Arc::clone(&serve),
            ClusterOpts {
                hub: HubOpts {
                    level: AckLevel::Quorum,
                    expect: n,
                    ack_timeout,
                    ..HubOpts::default()
                },
                policy: FsyncPolicy::Always,
                repl_advertise: advert.to_string(),
                // Distinct fake query addresses so redirect errors are
                // attributable to a specific node.
                query_advertise: format!("127.0.0.1:{}", 7800 + i),
                seed: 0x5EED ^ i as u64,
            },
        )
        .expect("start cluster node");
        serve.set_cluster(Arc::clone(&cluster));
        nodes.push(Node { serve, cluster });
    }
    (nodes, proxies)
}

/// Poll until exactly one of the `alive` nodes leads and every other
/// alive node recognizes it at that term.
fn wait_leader(nodes: &[Node], alive: &[usize], budget: Duration) -> usize {
    let deadline = Instant::now() + budget;
    loop {
        let leaders: Vec<usize> = alive
            .iter()
            .copied()
            .filter(|&i| nodes[i].cluster.role() == Role::Leader)
            .collect();
        if leaders.len() == 1 {
            let li = leaders[0];
            let (lid, term) = (nodes[li].cluster.id(), nodes[li].cluster.term());
            let agree = alive.iter().all(|&i| {
                i == li
                    || nodes[i].cluster.leader().map(|l| l.id == lid && l.term == term)
                        == Some(true)
            });
            if agree {
                return li;
            }
        }
        assert!(Instant::now() < deadline, "no stable leader within {budget:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Drive one insert to a quorum ack against a *standing* leader. Every
/// attempt that reaches the log is recorded in `applied`: a `no-quorum`
/// error means the op is applied and logged locally and — while this
/// leader stands — will replicate once followers (re)attach, so the
/// next attempt uses a fresh vector instead of duplicating it.
fn insert_until_acked(
    serve: &ServeIndex,
    applied: &mut Vec<Vec<f32>>,
    rng: &mut Pcg32,
    budget: Duration,
) {
    let deadline = Instant::now() + budget;
    loop {
        let v = gauss(rng);
        match serve.mutate(&Request::Insert { id: applied.len() as u64, vector: v.clone() }) {
            Ok(resp) => {
                applied.push(v);
                assert_eq!(
                    resp.seq,
                    applied.len() as u64,
                    "a quorum ack carries the commit seq for read-your-writes sessions"
                );
                return;
            }
            // The hub's no-quorum errors mean the op reached the local
            // log; the leaderless `no leader elected` rejection means it
            // did not — only the former counts toward the control run.
            Err(e) if e.contains("may be superseded on failover") => applied.push(v),
            Err(e) if e.contains("no-quorum") => {}
            Err(e) => panic!("unexpected mutate error: {e}"),
        }
        assert!(Instant::now() < deadline, "writes never resumed within {budget:?}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The uninterrupted control run: the same seed data plus every applied
/// insert, hashed through the deterministic persistence path.
fn control_fingerprint(data: &Arc<Matrix>, applied: &[Vec<f32>]) -> u64 {
    let mut control: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::clone(data)));
    let mut ctx = SearchContext::new();
    let m = control.as_mutable().expect("bruteforce is mutable");
    for v in applied {
        m.insert(v, &mut ctx).expect("control insert");
    }
    fnv1a64(&bundle_to_vec(control.as_ref()).expect("control bundle"))
}

/// Poll until every `alive` node reports exactly the control state.
fn wait_converged(nodes: &[Node], alive: &[usize], want_fp: u64, want_seq: u64, budget: Duration) {
    let deadline = Instant::now() + budget;
    loop {
        let ok = alive.iter().all(|&i| {
            nodes[i]
                .serve
                .fingerprint(0)
                .map(|f| f.fingerprint == want_fp && f.seq == want_seq)
                .unwrap_or(false)
        });
        if ok {
            return;
        }
        let seen: Vec<Option<(u64, u64)>> = alive
            .iter()
            .map(|&i| nodes[i].serve.fingerprint(0).ok().map(|f| (f.fingerprint, f.seq)))
            .collect();
        assert!(
            Instant::now() < deadline,
            "nodes never converged to (fp {want_fp:#x}, seq {want_seq}); saw {seen:?}"
        );
        std::thread::sleep(Duration::from_millis(30));
    }
}

fn teardown(nodes: &[Node], root: &Path) {
    for n in nodes {
        n.cluster.shutdown();
    }
    std::fs::remove_dir_all(root).ok();
}

/// Kill the elected leader mid-churn: a successor holding every acked
/// op wins (log matching), writes resume, and the survivors converge
/// byte-identically to the control run — every quorum-acked vector is
/// queryable at distance ~0 on the new leader.
#[test]
fn kill_the_leader_and_the_cluster_fails_over() {
    let root = tmp_dir("kill");
    let ds = tiny(0xFA11, N0, DIM, Metric::L2);
    let (nodes, _proxies) = start_cluster(&root, &ds.data, false, Duration::from_secs(5));
    let all = [0usize, 1, 2];
    let li = wait_leader(&nodes, &all, Duration::from_secs(15));

    let mut rng = Pcg32::new(0xC0FFEE);
    let mut applied: Vec<Vec<f32>> = Vec::new();
    for _ in 0..10 {
        insert_until_acked(&nodes[li].serve, &mut applied, &mut rng, Duration::from_secs(20));
    }
    // The last ack proves a majority holds the whole prefix: the op
    // stream is ordered, so acking seq s implies holding every seq < s.
    nodes[li].cluster.shutdown();

    let survivors: Vec<usize> = all.iter().copied().filter(|&i| i != li).collect();
    let li2 = wait_leader(&nodes, &survivors, Duration::from_secs(30));
    assert_ne!(li2, li, "the dead leader cannot win its own succession");

    // Writes resume once the surviving follower re-attaches to the new
    // leader's hub.
    for _ in 0..5 {
        insert_until_acked(&nodes[li2].serve, &mut applied, &mut rng, Duration::from_secs(30));
    }

    let fp = control_fingerprint(&ds.data, &applied);
    wait_converged(&nodes, &survivors, fp, applied.len() as u64, Duration::from_secs(30));

    // Every applied vector answers at distance ~0 on the new leader.
    let mut ctx = SearchContext::new();
    for (i, v) in applied.iter().enumerate() {
        let hits = nodes[li2].serve.search(v, 1, &mut ctx);
        let (dist, _) = hits.first().copied().expect("one hit");
        assert!(dist.abs() < 1e-4, "acked insert {i} lost in failover (nearest dist {dist})");
    }
    teardown(&nodes, &root);
}

/// A leader cut off from the election plane keeps its role (it cannot
/// observe the newer term) but loses its followers: writes degrade to
/// a fast structured `no-quorum` error while reads keep serving. On
/// healing it steps down, fences writes with a redirect to the new
/// leader, and its divergent tail is wiped by the forced snapshot.
#[test]
fn a_partitioned_stale_leader_degrades_then_steps_down_on_heal() {
    let root = tmp_dir("split");
    let ds = tiny(0x5B1A, N0, DIM, Metric::L2);
    let (nodes, _proxies) = start_cluster(&root, &ds.data, false, Duration::from_secs(2));
    let all = [0usize, 1, 2];
    let li = wait_leader(&nodes, &all, Duration::from_secs(15));

    let mut rng = Pcg32::new(0xBEEF);
    let mut applied: Vec<Vec<f32>> = Vec::new();
    for _ in 0..3 {
        insert_until_acked(&nodes[li].serve, &mut applied, &mut rng, Duration::from_secs(20));
    }
    let old_term = nodes[li].cluster.term();

    nodes[li].cluster.election().set_partitioned(true);
    let survivors: Vec<usize> = all.iter().copied().filter(|&i| i != li).collect();
    let li2 = wait_leader(&nodes, &survivors, Duration::from_secs(30));
    assert!(nodes[li2].cluster.term() > old_term, "a new leadership means a newer term");

    // Give the survivors' reconcilers a few ticks to detach their
    // replica streams from the deposed leader.
    std::thread::sleep(Duration::from_millis(500));

    // The deposed side still believes it leads; its writes degrade to a
    // structured no-quorum error (fast, not a timeout burn) and reads
    // keep serving the installed state.
    let deadline = Instant::now() + Duration::from_secs(10);
    let noq = loop {
        match nodes[li].serve.mutate(&Request::Insert { id: 99, vector: gauss(&mut rng) }) {
            // A follower had not detached yet; the op lands on the
            // doomed divergent tail and is wiped below.
            Ok(_) => std::thread::sleep(Duration::from_millis(50)),
            Err(e) if e.contains("no-quorum") => break e,
            Err(e) => panic!("unexpected stale-leader error: {e}"),
        }
        assert!(Instant::now() < deadline, "stale leader never degraded to no-quorum");
    };
    assert!(noq.contains("may be superseded on failover"), "got: {noq}");
    let mut ctx = SearchContext::new();
    assert_eq!(
        nodes[li].serve.search(&applied[0], 1, &mut ctx).first().map(|h| h.0.abs() < 1e-4),
        Some(true),
        "reads must keep serving on the partitioned side"
    );

    // The healthy majority keeps taking writes.
    insert_until_acked(&nodes[li2].serve, &mut applied, &mut rng, Duration::from_secs(30));

    // Heal: the deposed leader hears the newer term, steps down, and
    // fences stale writes with a redirect to the new leader.
    nodes[li].cluster.election().set_partitioned(false);
    let deadline = Instant::now() + Duration::from_secs(15);
    let fence = loop {
        let err = nodes[li]
            .serve
            .mutate(&Request::Insert { id: 100, vector: gauss(&mut rng) })
            .map(|_| String::new());
        match err {
            Err(e) if e.contains("not the leader") => break e,
            // A brief leaderless / still-partitioned-view window is
            // fine; keep polling until the demotion lands.
            Ok(_) | Err(_) => std::thread::sleep(Duration::from_millis(30)),
        }
        assert!(Instant::now() < deadline, "deposed leader never stepped down");
    };
    assert_eq!(nodes[li].cluster.role(), Role::Follower);
    assert!(
        fence.contains(&format!("127.0.0.1:{}", 7800 + li2)),
        "the fence must redirect to the new leader's query address, got: {fence}"
    );

    // Convergence wipes the deposed leader's divergent tail: all three
    // nodes land on the control state (the probe inserts above vanish).
    let fp = control_fingerprint(&ds.data, &applied);
    wait_converged(&nodes, &all, fp, applied.len() as u64, Duration::from_secs(30));
    teardown(&nodes, &root);
}

/// Symmetric partitions on the leader's replication stream: followers
/// lose whole frames in both directions, reconnect with capped backoff,
/// and converge byte-identically once the seeded fault budget is spent.
/// Leadership is stable throughout (the election plane is not proxied),
/// so ops that missed their ack window replicate after the flaps.
#[test]
fn flapping_repl_partitions_heal_and_the_cluster_converges() {
    let root = tmp_dir("flap");
    let ds = tiny(0xF1A9, N0, DIM, Metric::L2);
    let (nodes, proxies) = start_cluster(&root, &ds.data, true, Duration::from_secs(2));
    let all = [0usize, 1, 2];
    let li = wait_leader(&nodes, &all, Duration::from_secs(15));

    let mut rng = Pcg32::new(0xF1AB);
    let mut applied: Vec<Vec<f32>> = Vec::new();
    for _ in 0..20 {
        let v = gauss(&mut rng);
        match nodes[li].serve.mutate(&Request::Insert { id: applied.len() as u64, vector: v.clone() })
        {
            Ok(_) => applied.push(v),
            // Applied and logged on the standing leader; replicates once
            // the partition budget is spent.
            Err(e) if e.contains("may be superseded on failover") => applied.push(v),
            Err(e) => panic!("unexpected error under partition flaps: {e}"),
        }
    }
    let injected: u64 = proxies.iter().map(|p| p.injected()).sum();
    assert!(injected > 0, "the partition plan never fired");

    let fp = control_fingerprint(&ds.data, &applied);
    wait_converged(&nodes, &all, fp, applied.len() as u64, Duration::from_secs(60));

    // The follower streams rode reconnect-with-backoff through the
    // flaps; the counters surface through the cluster supervisor.
    let reconnects: u64 = all
        .iter()
        .filter_map(|&i| nodes[i].cluster.replica_metrics())
        .map(|m| m.reconnect_attempts.load(Ordering::Relaxed))
        .sum();
    assert!(reconnects > 0, "partition cuts must surface as reconnect cycles");

    teardown(&nodes, &root);
    for p in proxies {
        p.stop();
    }
}

/// Kills the child process on every exit path so a failing assert does
/// not leak a serving `finger` process.
struct KillOnDrop(std::process::Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

/// Read the child's stdout until `pick` matches a line, returning the
/// match. Panics (with everything read so far) if the child closes
/// stdout first.
fn scan_stdout<T>(
    lines: &mut std::io::Lines<std::io::BufReader<std::process::ChildStdout>>,
    what: &str,
    pick: impl Fn(&str) -> Option<T>,
) -> T {
    let mut seen = String::new();
    for line in lines.by_ref() {
        let line = line.expect("read child stdout");
        seen.push_str(&line);
        seen.push('\n');
        if let Some(v) = pick(&line) {
            return v;
        }
    }
    panic!("child exited before printing {what}; stdout so far:\n{seen}");
}

fn addr_after_on(line: &str) -> Option<SocketAddr> {
    line.split(" on ").nth(1)?.split_whitespace().next()?.parse().ok()
}

/// Satellite regression: `serve --replica-of` binds its query listener
/// *before* the first byte of catch-up. Until the readiness latch
/// flips, queries answer a structured `{"state":"warming"}` (not a
/// connection refusal), REPL_STATUS reports the warming state plus the
/// reconnect counters, and once a snapshot + caught-up arrive the same
/// connection starts serving. A session `min_seq` token ahead of the
/// replica's position is refused with a structured stale error.
#[test]
fn replica_binds_before_catchup_and_answers_warming() {
    use std::io::BufRead as _;
    use std::process::{Command, Stdio};

    let ds = tiny(0x3A3, 16, DIM, Metric::L2);
    // The test plays the leader: accept the stream, answer nothing yet.
    let leader_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let leader_addr = leader_listener.local_addr().unwrap();

    let mut child = Command::new(env!("CARGO_BIN_EXE_finger"))
        .args([
            "serve",
            "--replica-of",
            &leader_addr.to_string(),
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "1",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn replica");
    let stdout = child.stdout.take().expect("piped stdout");
    let _child = KillOnDrop(child);
    let mut lines = std::io::BufReader::new(stdout).lines();
    let raddr = scan_stdout(&mut lines, "the replica banner", |l| {
        l.starts_with("serving replica").then(|| addr_after_on(l)).flatten()
    });

    let (mut stream, _) = leader_listener.accept().expect("replica dials the leader");
    let hello = Frame::read_from(&mut stream).expect("handshake").expect("a frame");
    assert_eq!(hello, Frame::Hello { last_seq: 0, need_snapshot: true });

    // The listener is up before any state arrived: structured warming.
    let mut client = Client::connect(&raddr).expect("listener must be bound before catch-up");
    let q = QueryRequest { id: 1, vector: vec![0.0; DIM], k: 1 };
    let line = client.send_raw(&q.to_json_line()).expect("warming answer");
    let v = Json::parse(line.trim()).expect("warming answer is JSON");
    assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("warming"), "got: {line}");

    let status_line =
        client.send_raw(&Request::ReplStatus { id: 0 }.to_json_line()).expect("repl status");
    let status = Json::parse(status_line.trim()).expect("status is JSON");
    assert_eq!(status.get("role").and_then(|s| s.as_str()), Some("replica"));
    assert_eq!(status.get("state").and_then(|s| s.as_str()), Some("warming"));
    assert!(
        status.get("replica_metrics").is_some(),
        "reconnect/backoff counters must surface in REPL_STATUS, got: {status_line}"
    );

    // Feed it state: snapshot + caught-up flips the readiness latch.
    let seed_index = BruteForce::new(Arc::clone(&ds.data));
    let bundle = bundle_to_vec(&seed_index).expect("seed bundle");
    Frame::Snapshot { snapshot_seq: 0, bundle }.write_to(&mut stream).expect("send snapshot");
    Frame::CaughtUp { seq: 0 }.write_to(&mut stream).expect("send caught-up");
    assert_eq!(
        Frame::read_from(&mut stream).expect("snapshot ack").expect("a frame"),
        Frame::Ack { seq: 0 }
    );

    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match client.query(&QueryRequest { id: 2, vector: vec![0.0; DIM], k: 1 }) {
            Ok(resp) => {
                assert!(!resp.hits.is_empty(), "caught-up replica must answer hits");
                break;
            }
            Err(e) => {
                assert!(e.contains("warming"), "unexpected error while warming: {e}");
                assert!(Instant::now() < deadline, "replica never left the warming state");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }

    // Read-your-writes: a session ahead of this replica is refused with
    // a structured stale answer, not silently served old data.
    let comps = vec!["0.0"; DIM].join(", ");
    let stale = client
        .send_raw(&format!("{{\"id\": 3, \"vector\": [{comps}], \"k\": 1, \"min_seq\": 7}}"))
        .expect("stale answer");
    let v = Json::parse(stale.trim()).expect("stale answer is JSON");
    assert_eq!(v.get("state").and_then(|s| s.as_str()), Some("stale"), "got: {stale}");
    assert!(stale.contains("stale-replica"), "got: {stale}");
}

/// Process-level acceptance smoke: three `serve --cluster` processes
/// elect a leader, quorum-ack inserts, survive a SIGKILL of the leader
/// mid-churn (every acked vector stays readable, survivors converge to
/// the same byte fingerprint), resume writes against the successor, and
/// `repl leader` discovers the new leader from any surviving node.
#[test]
fn kill_the_elected_leader_process_and_the_cluster_elects_a_successor() {
    use std::io::BufRead as _;
    use std::process::{Command, Stdio};

    let root = tmp_dir("proc");
    std::fs::create_dir_all(&root).unwrap();
    let bundle = root.join("seed.idx");
    let ds = tiny(0x9001, 40, DIM, Metric::L2);
    save_index(&bundle, &BruteForce::new(Arc::clone(&ds.data))).unwrap();

    // Reserve the election endpoints up front so every node can name
    // its peers before any of them runs.
    let eaddrs: Vec<String> = (0..3)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().to_string())
        .collect();
    let spec = eaddrs
        .iter()
        .enumerate()
        .map(|(i, a)| format!("{}@{a}", i + 1))
        .collect::<Vec<_>>()
        .join(",");

    let mut procs: Vec<Option<KillOnDrop>> = Vec::new();
    let mut readers = Vec::new(); // keep pipes open so children never hit EPIPE
    let mut qaddrs: Vec<SocketAddr> = Vec::new();
    for i in 1..=3usize {
        let wal_dir = root.join(format!("node{i}"));
        let mut child = Command::new(env!("CARGO_BIN_EXE_finger"))
            .args([
                "serve",
                "--cluster",
                &spec,
                "--cluster-id",
                &i.to_string(),
                "--index",
                bundle.to_str().unwrap(),
                "--addr",
                "127.0.0.1:0",
                "--workers",
                "1",
                "--wal-dir",
                wal_dir.to_str().unwrap(),
                "--fsync-policy",
                "always",
                "--election-timeout-ms",
                "250",
                "--heartbeat-ms",
                "60",
                "--repl-ack-timeout-ms",
                "15000",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn cluster node");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let qaddr = scan_stdout(&mut lines, "the serving banner", |l| {
            l.starts_with("serving ").then(|| addr_after_on(l)).flatten()
        });
        procs.push(Some(KillOnDrop(child)));
        readers.push(lines);
        qaddrs.push(qaddr);
    }

    let status = |addr: &SocketAddr| -> Option<Json> {
        let mut c = Client::connect(addr).ok()?;
        let line = c.send_raw(&Request::ReplStatus { id: 0 }.to_json_line()).ok()?;
        Json::parse(line.trim()).ok()
    };
    let replicas_attached = |v: &Json| match v.get("replicas") {
        Some(Json::Arr(a)) => a.len(),
        _ => 0,
    };
    // A leader with `want_replicas` attached followers can quorum-ack.
    let find_leader = |alive: &[usize], want_replicas: usize, budget: Duration| -> usize {
        let deadline = Instant::now() + budget;
        loop {
            for &i in alive {
                if let Some(v) = status(&qaddrs[i]) {
                    if v.get("role").and_then(|r| r.as_str()) == Some("leader")
                        && replicas_attached(&v) >= want_replicas
                    {
                        return i;
                    }
                }
            }
            assert!(
                Instant::now() < deadline,
                "no leader with {want_replicas} attached replica(s) within {budget:?}"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    };

    let all = [0usize, 1, 2];
    let li = find_leader(&all, 2, Duration::from_secs(45));

    let mut client = Client::connect(&qaddrs[li]).expect("connect leader");
    let mut rng = Pcg32::new(0x90F1);
    let mut acked: Vec<Vec<f32>> = Vec::new();
    for k in 0..5u64 {
        let vector = gauss(&mut rng);
        let resp = client
            .mutate(&Request::Insert { id: k, vector: vector.clone() })
            .expect("quorum-acked insert");
        assert!(matches!(resp.outcome, MutOutcome::Inserted(_)));
        assert_eq!(resp.seq, k + 1, "the ack carries the commit seq");
        acked.push(vector);
    }

    // SIGKILL the elected leader mid-churn. Quorum acks mean nothing
    // above may be lost: a majority holds every acked op durably.
    drop(client);
    procs[li] = None;

    let survivors: Vec<usize> = all.iter().copied().filter(|&i| i != li).collect();
    let li2 = find_leader(&survivors, 1, Duration::from_secs(60));

    // Writes resume against the successor.
    let mut client = Client::connect(&qaddrs[li2]).expect("connect new leader");
    for k in 5..8u64 {
        let vector = gauss(&mut rng);
        let resp = client
            .mutate(&Request::Insert { id: k, vector: vector.clone() })
            .expect("post-failover insert");
        assert!(matches!(resp.outcome, MutOutcome::Inserted(_)));
        acked.push(vector);
    }

    // Every quorum-acked vector survived the failover.
    for (i, vector) in acked.iter().enumerate() {
        let resp = client
            .query(&QueryRequest { id: i as u64, vector: vector.clone(), k: 1 })
            .expect("query acked vector");
        let (dist, _) = resp.hits.first().copied().expect("one hit");
        assert!(dist.abs() < 1e-4, "acked insert {i} lost in failover (nearest dist {dist})");
    }

    // Byte-fingerprint convergence across the survivors.
    let get_fp = |addr: &SocketAddr| -> Option<FingerprintInfo> {
        let mut c = Client::connect(addr).ok()?;
        let line = c.send_raw(&Request::Fingerprint { id: 0 }.to_json_line()).ok()?;
        FingerprintInfo::parse(line.trim()).ok()
    };
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let prints: Vec<Option<FingerprintInfo>> =
            survivors.iter().map(|&i| get_fp(&qaddrs[i])).collect();
        if let [Some(a), Some(b)] = &prints[..] {
            if a.fingerprint == b.fingerprint && a.seq == 8 && b.seq == 8 && a.live == 40 + 8 {
                break;
            }
        }
        assert!(Instant::now() < deadline, "survivors never converged: {prints:?}");
        std::thread::sleep(Duration::from_millis(100));
    }

    // Leader discovery works against any surviving node.
    let addrs_arg =
        survivors.iter().map(|&i| qaddrs[i].to_string()).collect::<Vec<_>>().join(",");
    let out = Command::new(env!("CARGO_BIN_EXE_finger"))
        .args(["repl", "leader", "--addrs", &addrs_arg])
        .output()
        .expect("run repl leader");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "repl leader failed: {stdout}");
    assert!(stdout.contains(&format!("leader: {}", qaddrs[li2])), "got: {stdout}");

    drop(procs);
    std::fs::remove_dir_all(&root).ok();
}
