//! Integration tests across the whole stack: graph + FINGER + router +
//! PJRT runtime, exercising the same composition as examples/serve_e2e.rs.

use std::sync::Arc;
use std::time::Duration;

use finger_ann::core::distance::Metric;
use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::synth::tiny;
use finger_ann::eval::recall_ids;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::FingerHnswIndex;
use finger_ann::index::SearchContext;
use finger_ann::router::{Client, QueryRequest, ServeIndex, Server, ServerConfig};
use finger_ann::runtime::{default_artifacts_dir, service::RerankService};

fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn build_index(n: usize, dim: usize, seed: u64) -> Arc<ServeIndex> {
    let ds = tiny(seed, n, dim, Metric::L2);
    let fh = FingerHnswIndex::build(
        Arc::clone(&ds.data),
        HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        FingerParams { rank: 8, ..Default::default() },
    );
    Arc::new(ServeIndex::new(Box::new(fh), 64))
}

#[test]
fn served_results_match_direct_search() {
    let index = build_index(500, 24, 301);
    let server = Server::start(
        Arc::clone(&index),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            max_queue: 256,
            use_pjrt_rerank: false,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let mut client = Client::connect(&server.local_addr).unwrap();

    let mut ctx = SearchContext::new();
    for qi in [0usize, 7, 42] {
        let q = index.row(qi);
        let served = client
            .query(&QueryRequest { id: qi as u64, vector: q.clone(), k: 5 })
            .unwrap();
        let direct = index.search(&q, 5, &mut ctx);
        let served_ids: Vec<u32> = served.hits.iter().map(|&(_, id)| id).collect();
        let direct_ids: Vec<u32> = direct.iter().map(|&(_, id)| id).collect();
        assert_eq!(served_ids, direct_ids, "query {qi}");
    }
    server.shutdown();
}

#[test]
fn served_recall_matches_offline_recall() {
    let ds = tiny(302, 600, 16, Metric::L2);
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let fh = FingerHnswIndex::build(
        Arc::clone(&ds.data),
        HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        FingerParams { rank: 8, ..Default::default() },
    );
    let queries = ds.queries.clone();
    let index = Arc::new(ServeIndex::new(Box::new(fh), 64));
    let server = Server::start(Arc::clone(&index), ServerConfig {
        addr: "127.0.0.1:0".into(),
        workers: 4,
        max_batch: 8,
        max_wait: Duration::from_micros(100),
        max_queue: 1024,
        use_pjrt_rerank: false,
        ..Default::default()
    }, None).unwrap();

    let mut total = 0.0;
    for qi in 0..queries.rows() {
        let rx = server
            .submit_local(QueryRequest {
                id: qi as u64,
                vector: queries.row(qi).to_vec(),
                k: 10,
            })
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let ids: Vec<u32> = resp.hits.iter().map(|&(_, id)| id).collect();
        total += recall_ids(&ids, &gt[qi]);
    }
    let avg = total / queries.rows() as f64;
    assert!(avg > 0.85, "served recall@10 = {avg}");
    server.shutdown();
}

#[test]
fn pjrt_rerank_returns_exact_distances() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    // dim must match an AOT rerank artifact (32).
    let index = build_index(400, 32, 303);
    let svc = RerankService::start(
        default_artifacts_dir(),
        32,
        Arc::new(index.data_clone()),
    )
    .unwrap();
    let server = Server::start(
        Arc::clone(&index),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 2,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            max_queue: 256,
            use_pjrt_rerank: true,
            ..Default::default()
        },
        Some(Arc::new(svc)),
    )
    .unwrap();

    let q = index.row(9);
    let rx = server
        .submit_local(QueryRequest { id: 1, vector: q.clone(), k: 5 })
        .unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(resp.hits[0].1, 9, "self-query top hit");
    // Distances must be the exact L2 values computed by the Pallas kernel.
    for &(d, id) in &resp.hits {
        let want = finger_ann::core::distance::l2_sq(&q, &index.row(id as usize));
        assert!((d - want).abs() < 1e-2 * (1.0 + want), "{d} vs {want}");
    }
    server.shutdown();
}

#[test]
fn overload_rejections_are_reported() {
    let index = build_index(300, 16, 304);
    let server = Server::start(
        Arc::clone(&index),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            max_batch: 1,
            max_wait: Duration::from_millis(20),
            max_queue: 1, // absurdly small: force rejections
            use_pjrt_rerank: false,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let mut rejected = 0;
    let mut accepted_rx = Vec::new();
    for i in 0..50u64 {
        match server.submit_local(QueryRequest {
            id: i,
            vector: index.row(0),
            k: 3,
        }) {
            Ok(rx) => accepted_rx.push(rx),
            Err(_) => rejected += 1,
        }
    }
    // Every accepted request must still be answered.
    for rx in accepted_rx {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    assert!(rejected > 0, "tiny queue must reject under burst");
    server.shutdown();
}
