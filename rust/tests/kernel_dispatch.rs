//! Property suites for the two determinism contracts this repo's strict
//! equality tests stand on:
//!
//! 1. **Kernel dispatch** — whatever backend `core::simd::kernels()`
//!    selected (AVX2+FMA, NEON, or scalar) returns bitwise-identical
//!    results to the portable scalar reference for every kernel, across
//!    the full length zoo (empty / sub-lane / exact-lane / lane+1 / odd
//!    multi-chunk / real dims), NaN rows, zero-padded tails, and batch4
//!    remainder handling. Running under `FINGER_KERNEL=scalar` makes
//!    these trivially true — CI runs the suite in both configurations.
//!
//! 2. **Parallel build determinism** — building any graph family with
//!    `threads ∈ {1, 2, 8}` persists byte-identical index bundles
//!    (adjacency, levels, entry, FINGER tables — everything), because
//!    the batched build plans in parallel against a frozen prefix and
//!    commits serially in a fixed order.

use std::path::PathBuf;
use std::sync::Arc;

use finger_ann::core::distance::{self, Metric};
use finger_ann::core::rng::Pcg32;
use finger_ann::core::simd::{kernels, scalar};
use finger_ann::core::store::VectorStore;
use finger_ann::data::persist::save_index;
use finger_ann::data::synth::tiny;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::{Hnsw, HnswParams};
use finger_ann::graph::nndescent::NnDescentParams;
use finger_ann::graph::vamana::VamanaParams;
use finger_ann::index::impls::{FingerHnswIndex, HnswIndex, NnDescentIndex, VamanaIndex};
use finger_ann::index::{AnnIndex, SearchContext, SearchParams};
use finger_ann::quant::Precision;
use finger_ann::testutil::forall;

/// Empty, sub-lane, exact-lane, lane+1, odd multi-chunk, and real dims.
const LENS: &[usize] = &[0, 1, 7, 8, 9, 17, 100, 784];

fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gaussian()).collect()
}

fn pad_to_lanes(v: &[f32]) -> Vec<f32> {
    let mut p = v.to_vec();
    p.resize(v.len().div_ceil(distance::LANES) * distance::LANES, 0.0);
    p
}

#[test]
fn dispatched_kernels_bitwise_equal_scalar_across_lengths() {
    let ks = kernels();
    println!("active backend: {}", ks.backend.name());
    forall("kernel-dispatch-bitwise", 200, |rng| {
        for &n in LENS {
            let a = randv(rng, n);
            let b = randv(rng, n);
            if (ks.l2_sq)(&a, &b).to_bits() != scalar::l2_sq(&a, &b).to_bits() {
                return false;
            }
            if (ks.dot)(&a, &b).to_bits() != scalar::dot(&a, &b).to_bits() {
                return false;
            }
            let rows: Vec<Vec<f32>> = (0..4).map(|_| randv(rng, n)).collect();
            let gl = (ks.l2_sq_batch4)(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            let sl = scalar::l2_sq_batch4(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            let gd = (ks.dot_batch4)(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            let sd = scalar::dot_batch4(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            for t in 0..4 {
                if gl[t].to_bits() != sl[t].to_bits() || gd[t].to_bits() != sd[t].to_bits() {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn dispatched_u8_kernel_bitwise_equal_scalar_across_lengths() {
    // The quantized-tier kernel under the same contract: integer result,
    // so "bitwise" is plain u32 equality — but it must hold for every
    // backend across the same length zoo.
    let ks = kernels();
    forall("u8-kernel-dispatch-bitwise", 200, |rng| {
        for &n in LENS {
            let a: Vec<u8> = (0..n).map(|_| (rng.gen_range(256)) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| (rng.gen_range(256)) as u8).collect();
            if (ks.u8_l2_sq)(&a, &b) != scalar::u8_l2_sq(&a, &b) {
                return false;
            }
        }
        true
    });
}

#[test]
fn u8_kernel_saturation_and_codec_rounding_edges() {
    // Saturation: the worst-case per-lane diff is 255, whose square
    // (65025) overflows i16 — the widened accumulation must not saturate.
    let ks = kernels();
    for &n in LENS {
        let hi = vec![255u8; n];
        let lo = vec![0u8; n];
        let want = n as u32 * 255 * 255;
        assert_eq!((ks.u8_l2_sq)(&hi, &lo), want, "dispatch saturation n={n}");
        assert_eq!(scalar::u8_l2_sq(&hi, &lo), want, "scalar saturation n={n}");
        assert_eq!(distance::u8_l2_sq(&hi, &lo), distance::u8_l2_sq_scalar(&hi, &lo));
    }

    // Rounding: encode points sitting exactly between two codes —
    // f32::round ties away from zero, byte edges clamp, NaN pins to 0.
    let m = finger_ann::core::matrix::Matrix::from_rows(&[vec![0.0f32, 0.0], vec![255.0, 255.0]]);
    let codec = finger_ann::quant::Sq8Codec::train(&m);
    assert_eq!(codec.delta, 1.0);
    assert_eq!(codec.encode(&[0.49, 0.5]), vec![0, 1], "half rounds away from zero");
    assert_eq!(codec.encode(&[254.5, 1e30]), vec![255, 255], "upper edge clamps");
    assert_eq!(codec.encode(&[-7.0, f32::NAN]), vec![0, 0], "lower edge and NaN clamp to 0");
}

#[test]
fn dispatched_kernels_propagate_nan_like_scalar() {
    let mut r = Pcg32::new(0xA11);
    for &n in &[1usize, 7, 8, 17, 100] {
        let q = randv(&mut r, n);
        let mut rows: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut r, n)).collect();
        rows[1][0] = f32::NAN;
        rows[3][n - 1] = f32::NAN; // NaN in the lane-folded tail position
        let got = distance::l2_sq_batch4(&q, &rows[0], &rows[1], &rows[2], &rows[3]);
        let want = scalar::l2_sq_batch4(&q, &rows[0], &rows[1], &rows[2], &rows[3]);
        for t in 0..4 {
            assert_eq!(got[t].to_bits(), want[t].to_bits(), "n={n} row {t}");
        }
        assert!(got[1].is_nan() && got[3].is_nan());
        assert!(!got[0].is_nan() && !got[2].is_nan());
    }
}

#[test]
fn dispatched_kernels_keep_zero_padding_invisible() {
    // The VectorStore contract must hold under every backend: padded
    // inputs score bitwise-identically to logical ones.
    let mut r = Pcg32::new(0xB22);
    for &n in LENS {
        let a = randv(&mut r, n);
        let b = randv(&mut r, n);
        assert_eq!(
            distance::l2_sq(&a, &b).to_bits(),
            distance::l2_sq(&pad_to_lanes(&a), &pad_to_lanes(&b)).to_bits(),
            "l2 n={n}"
        );
        assert_eq!(
            distance::dot(&a, &b).to_bits(),
            distance::dot(&pad_to_lanes(&a), &pad_to_lanes(&b)).to_bits(),
            "dot n={n}"
        );
    }
}

#[test]
fn batch4_remainders_compose_with_single_row_kernel() {
    // Call sites batch blocks in fours and score the remainder with the
    // single-row kernel; the composition must equal all-single scoring.
    let mut r = Pcg32::new(0xC33);
    for &blocklen in &[1usize, 2, 3, 4, 5, 6, 7, 9] {
        let n = 13; // non-lane-multiple dim
        let q = randv(&mut r, n);
        let rows: Vec<Vec<f32>> = (0..blocklen).map(|_| randv(&mut r, n)).collect();
        let mut mixed = Vec::new();
        let mut i = 0;
        while i + 4 <= blocklen {
            let d4 = distance::l2_sq_batch4(&q, &rows[i], &rows[i + 1], &rows[i + 2], &rows[i + 3]);
            mixed.extend_from_slice(&d4);
            i += 4;
        }
        for row in &rows[i..] {
            mixed.push(distance::l2_sq(&q, row));
        }
        for (t, row) in rows.iter().enumerate() {
            assert_eq!(
                mixed[t].to_bits(),
                distance::l2_sq(&q, row).to_bits(),
                "blocklen={blocklen} row {t}"
            );
        }
    }
}

#[test]
fn search_streams_identical_under_dispatch_and_forced_scalar() {
    // End-to-end: the dispatched-kernel batched search and the forced
    // scalar-kernel search return bitwise-identical (dist, id) streams.
    let ds = tiny(907, 400, 28, Metric::L2);
    let store = VectorStore::from_matrix(&ds.data);
    let h = Hnsw::build_with_store(
        &store,
        HnswParams { m: 10, ef_construction: 60, ..Default::default() },
    );
    let mut ctx = SearchContext::new();
    let batched = SearchParams::new(10).with_ef(60);
    let scalar_mode = SearchParams::new(10).with_ef(60).with_scalar_kernels(true);
    for qi in 0..ds.queries.rows().min(20) {
        let q = ds.queries.row(qi);
        let a = h.search(&store, q, &batched, &mut ctx);
        let b = h.search(&store, q, &scalar_mode, &mut ctx);
        assert_eq!(a, b, "query {qi}");
    }
}

// ---------------------------------------------------------------- builds

fn tmp(name: &str) -> PathBuf {
    // Unique per call: tests run on parallel harness threads, and two of
    // them build the same (family, threads) combination — a (pid, name)
    // key alone would collide.
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("finger_dispatch_{}_{seq}_{name}", std::process::id()))
}

/// Build one family at the given thread count and return its persisted
/// bundle bytes.
fn build_bytes(family: &str, threads: usize) -> Vec<u8> {
    let ds = tiny(911, 230, 12, Metric::L2);
    let data = Arc::clone(&ds.data);
    let index: Box<dyn AnnIndex> = match family {
        "hnsw" => Box::new(HnswIndex::build(
            data,
            HnswParams { m: 8, ef_construction: 60, threads, ..Default::default() },
        )),
        "hnsw-finger" => Box::new(FingerHnswIndex::build(
            data,
            HnswParams { m: 8, ef_construction: 60, threads, ..Default::default() },
            FingerParams { rank: 8, threads, ..Default::default() },
        )),
        "hnsw-sq8" => Box::new(HnswIndex::build_with_precision(
            data,
            HnswParams { m: 8, ef_construction: 60, threads, ..Default::default() },
            Precision::Sq8,
        )),
        "hnsw-pq" => Box::new(HnswIndex::build_with_precision(
            data,
            HnswParams { m: 8, ef_construction: 60, threads, ..Default::default() },
            Precision::Pq,
        )),
        "hnsw-finger-sq8" => Box::new(FingerHnswIndex::build_with_precision(
            data,
            HnswParams { m: 8, ef_construction: 60, threads, ..Default::default() },
            FingerParams { rank: 8, threads, ..Default::default() },
            Precision::Sq8,
        )),
        "vamana" => Box::new(VamanaIndex::build(
            data,
            VamanaParams { r: 16, l: 40, threads, ..Default::default() },
        )),
        "nndescent" => Box::new(NnDescentIndex::build(
            data,
            NnDescentParams {
                k: 10,
                sample: 6,
                iters: 3,
                degree: 12,
                threads,
                ..Default::default()
            },
        )),
        other => panic!("unknown family {other}"),
    };
    let path = tmp(&format!("{family}_{threads}.idx"));
    save_index(&path, index.as_ref()).expect("save index");
    let bytes = std::fs::read(&path).expect("read bundle");
    std::fs::remove_file(&path).ok();
    bytes
}

/// The tentpole acceptance property: a parallel build persists the exact
/// bytes of the single-threaded build, for every graph family.
#[test]
fn parallel_builds_persist_identical_bytes() {
    for family in [
        "hnsw",
        "hnsw-finger",
        "vamana",
        "nndescent",
        "hnsw-sq8",
        "hnsw-pq",
        "hnsw-finger-sq8",
    ] {
        let reference = build_bytes(family, 1);
        assert!(!reference.is_empty());
        for threads in [2usize, 8] {
            let got = build_bytes(family, threads);
            let first_diff = got
                .iter()
                .zip(&reference)
                .position(|(a, b)| a != b)
                .unwrap_or(got.len().min(reference.len()));
            assert!(
                got == reference,
                "{family}: T={threads} bundle differs from T=1 \
                 ({} vs {} bytes, first diff at byte {first_diff})",
                got.len(),
                reference.len()
            );
        }
    }
}

/// `threads = 0` (auto) must match any explicit thread count too — the
/// knob only changes scheduling, never the result.
#[test]
fn auto_threads_build_matches_explicit() {
    let auto = build_bytes("hnsw", 0);
    let one = build_bytes("hnsw", 1);
    assert!(auto == one, "auto-thread build differs from T=1");
}
