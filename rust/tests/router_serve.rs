//! Integration tests for the serving plane, end to end over real TCP:
//!
//! * **Pipelining** — N frames written in one segment come back as N
//!   responses in request order, even though the worker pool completes
//!   them out of order.
//! * **Slow clients** — a frame dripped a few bytes per write (each
//!   chunk its own epoll wakeup) is reassembled and answered.
//! * **Soak** — ~2k concurrent connections against one event loop and a
//!   fixed worker pool: the server's thread count must not grow with the
//!   connection count, and every request gets exactly one answer.
//! * **Accept-loop survival** — a `finger serve` child capped at 64 fds
//!   is flooded past EMFILE; once the flood drops, a fresh connection
//!   must still be served (the pre-fix accept loop died permanently on
//!   the first transient error, in both modes).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use finger_ann::core::distance::Metric;
use finger_ann::data::persist::save_index;
use finger_ann::data::synth::tiny;
use finger_ann::index::impls::BruteForce;
use finger_ann::router::poll;
use finger_ann::router::{
    Client, MutOutcome, QueryRequest, QueryResponse, Request, ServeIndex, ServeMode, Server,
    ServerConfig,
};

const DIM: usize = 8;

fn serve_index(n: usize, seed: u64) -> Arc<ServeIndex> {
    let ds = tiny(seed, n, DIM, Metric::L2);
    Arc::new(ServeIndex::new(Box::new(BruteForce::new(Arc::clone(&ds.data))), 32))
}

fn start(mode: ServeMode, workers: usize) -> (Arc<ServeIndex>, Server) {
    let index = serve_index(240, 901);
    let server = Server::start(
        Arc::clone(&index),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            max_queue: 4096,
            mode,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    (index, server)
}

/// Worker completions land out of order (4 workers, shuffled batches);
/// the per-connection reorder stage must still write responses in
/// request order.
#[test]
fn pipelined_requests_answered_in_order_over_tcp() {
    if !poll::SUPPORTED {
        eprintln!("skipping: epoll unsupported on this target");
        return;
    }
    let (index, server) = start(ServeMode::Epoll, 4);
    let stream = TcpStream::connect(server.local_addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    let mut batch = String::new();
    for i in 0..32u64 {
        let row = (i as usize * 7) % index.len();
        batch.push_str(&QueryRequest { id: i, vector: index.row(row), k: 3 }.to_json_line());
        batch.push('\n');
    }
    (&stream).write_all(batch.as_bytes()).unwrap();

    let mut reader = BufReader::new(&stream);
    for i in 0..32u64 {
        let mut line = String::new();
        reader.read_line(&mut line).expect("response within timeout");
        let resp = QueryResponse::parse(line.trim()).expect("parse response");
        assert_eq!(resp.id, i, "responses must come back in request order");
        let row = (i as usize * 7) % index.len();
        assert_eq!(resp.hits[0].1 as usize, row, "self-query top hit");
    }
    server.shutdown();
}

/// A frame arriving three bytes at a time spans many epoll wakeups; the
/// connection buffers until the newline and then answers normally.
#[test]
fn slow_client_partial_frames_assemble_across_wakeups() {
    if !poll::SUPPORTED {
        eprintln!("skipping: epoll unsupported on this target");
        return;
    }
    let (index, server) = start(ServeMode::Epoll, 2);
    let stream = TcpStream::connect(server.local_addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    let frame = format!("{}\n", QueryRequest { id: 7, vector: index.row(5), k: 2 }.to_json_line());
    for chunk in frame.as_bytes().chunks(3) {
        (&stream).write_all(chunk).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut line = String::new();
    BufReader::new(&stream).read_line(&mut line).expect("response within timeout");
    let resp = QueryResponse::parse(line.trim()).expect("parse response");
    assert_eq!(resp.id, 7);
    assert_eq!(resp.hits[0].1, 5, "self-query top hit");
    server.shutdown();
}

fn current_threads() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("read /proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .expect("Threads: line")
        .trim()
        .parse()
        .expect("parse thread count")
}

/// The headline scaling property: thousands of concurrent connections on
/// one event loop + a fixed worker pool, zero per-connection threads,
/// zero dropped requests.
#[test]
fn soak_thousands_of_connections_fixed_thread_budget() {
    if !poll::SUPPORTED {
        eprintln!("skipping: epoll unsupported on this target");
        return;
    }
    let limit = poll::raise_nofile_limit().unwrap_or(1024);
    // Each held connection costs two fds in this process (client end +
    // server end); leave headroom for the harness, stdio, and the index.
    let target = ((limit.saturating_sub(256) / 2) as usize).min(2048);
    if target < 64 {
        eprintln!("skipping: nofile limit {limit} too low for a soak");
        return;
    }

    let (index, server) = start(ServeMode::Epoll, 4);
    let before = current_threads();

    let mut conns = Vec::with_capacity(target);
    for _ in 0..target {
        let s = TcpStream::connect(server.local_addr).expect("connect");
        s.set_nodelay(true).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        conns.push(s);
    }

    // The fixed pool was fully spawned before `before` was sampled, so
    // the delta across 2k accepted connections must be ~zero.
    let after = current_threads();
    assert!(
        after <= before + 2,
        "thread count grew with connections: {before} -> {after} for {target} conns"
    );

    for (ci, s) in conns.iter_mut().enumerate() {
        let frame = QueryRequest { id: ci as u64, vector: index.row(ci % index.len()), k: 1 }
            .to_json_line();
        s.write_all(frame.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
    }
    let mut answered = 0usize;
    for (ci, s) in conns.iter().enumerate() {
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).expect("response within timeout");
        let resp = QueryResponse::parse(line.trim()).expect("parse response");
        assert_eq!(resp.id, ci as u64);
        answered += 1;
    }
    assert_eq!(answered, target, "every request answered, zero drops");
    server.shutdown();
}

/// The portable fallback still serves both planes over real TCP.
#[test]
fn threads_fallback_serves_queries_and_mutations() {
    let (index, server) = start(ServeMode::Threads, 2);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let resp = client.query(&QueryRequest { id: 1, vector: index.row(3), k: 2 }).unwrap();
    assert_eq!(resp.hits[0].1, 3, "self-query top hit");
    let ack = client.mutate(&Request::Insert { id: 2, vector: vec![0.25; DIM] }).unwrap();
    assert!(matches!(ack.outcome, MutOutcome::Inserted(_)));
    server.shutdown();
}

/// Mutations route through the verb executor off the event loop, and a
/// frame without `k` gets a structured in-band error while the
/// connection keeps serving.
#[test]
fn epoll_mode_serves_mutations_and_rejects_missing_k() {
    if !poll::SUPPORTED {
        eprintln!("skipping: epoll unsupported on this target");
        return;
    }
    let (index, server) = start(ServeMode::Epoll, 2);
    let mut client = Client::connect(&server.local_addr).unwrap();
    let ack = client.mutate(&Request::Insert { id: 1, vector: vec![0.5; DIM] }).unwrap();
    assert!(matches!(ack.outcome, MutOutcome::Inserted(_)));

    let raw = client.send_raw(r#"{"id":5,"vector":[0,0,0,0,0,0,0,0]}"#).unwrap();
    assert!(raw.contains("error") && raw.contains('k'), "missing k must be rejected: {raw}");
    assert!(raw.contains("\"id\":5"), "error echoes the request id: {raw}");

    let resp = client.query(&QueryRequest { id: 6, vector: index.row(0), k: 1 }).unwrap();
    assert_eq!(resp.hits[0].1, 0, "connection keeps serving after the bad frame");
    server.shutdown();
}

/// Kills the child process on every exit path so a failing assert does
/// not leak a serving `finger` process.
struct KillOnDrop(std::process::Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("finger_routerserve_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Spawn `finger serve` under `ulimit -n 64` and return its bound addr.
fn spawn_capped_server(mode: &str, root: &std::path::Path) -> (KillOnDrop, SocketAddr) {
    use std::process::{Command, Stdio};
    let bundle = root.join("seed.idx");
    let ds = tiny(88, 40, DIM, Metric::L2);
    save_index(&bundle, &BruteForce::new(Arc::clone(&ds.data))).unwrap();

    let cmd = format!(
        "ulimit -n 64; exec {} serve --index {} --addr 127.0.0.1:0 --workers 1 --serve-mode {}",
        env!("CARGO_BIN_EXE_finger"),
        bundle.display(),
        mode
    );
    let mut child = Command::new("sh")
        .args(["-c", &cmd])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn capped finger serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let child = KillOnDrop(child);

    // The banner line carries the OS-assigned port; serve flushes stdout
    // right after printing it.
    let mut addr = None;
    for line in BufReader::new(stdout).lines() {
        let line = line.expect("read child stdout");
        if line.starts_with("serving ") {
            if let Some(rest) = line.split(" on ").nth(1) {
                addr = rest.split_whitespace().next().map(str::to_string);
                break;
            }
        }
    }
    let addr = addr.expect("server banner").parse().expect("parse bound addr");
    (child, addr)
}

/// Flood a 64-fd server past EMFILE, release the flood, and require a
/// fresh connection to be served. The pre-fix accept path exited on the
/// first `accept(2)` error, leaving the process alive but deaf.
fn accept_survives_fd_exhaustion(mode: &str) {
    let root = tmp_dir(&format!("exhaust_{mode}"));
    std::fs::create_dir_all(&root).unwrap();
    let (child, addr) = spawn_capped_server(mode, &root);

    // The kernel completes handshakes into the listen backlog even while
    // accept(2) is failing with EMFILE, so most of these "succeed" from
    // our side; the server side runs out of fds well before 80.
    let mut flood = Vec::new();
    for _ in 0..80 {
        match TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
            Ok(s) => flood.push(s),
            Err(_) => break,
        }
    }
    assert!(flood.len() >= 40, "flood only opened {} conns", flood.len());
    std::thread::sleep(Duration::from_millis(200));
    drop(flood);

    let deadline = Instant::now() + Duration::from_secs(10);
    let mut served = false;
    while Instant::now() < deadline {
        if let Ok(stream) = TcpStream::connect_timeout(&addr, Duration::from_millis(500)) {
            stream.set_nodelay(true).ok();
            stream.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let frame = QueryRequest { id: 9, vector: vec![0.0; DIM], k: 1 }.to_json_line();
            let mut w = &stream;
            if w.write_all(frame.as_bytes()).is_ok() && w.write_all(b"\n").is_ok() {
                let mut line = String::new();
                if BufReader::new(&stream).read_line(&mut line).is_ok()
                    && line.contains("\"id\"")
                    && !line.contains("error")
                {
                    served = true;
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(200));
    }
    assert!(served, "server stopped serving after fd exhaustion ({mode} mode)");
    drop(child);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn accept_survives_fd_exhaustion_epoll() {
    if !poll::SUPPORTED {
        eprintln!("skipping: epoll unsupported on this target");
        return;
    }
    accept_survives_fd_exhaustion("epoll");
}

#[test]
fn accept_survives_fd_exhaustion_threads() {
    if !cfg!(target_os = "linux") {
        eprintln!("skipping: ulimit child harness is linux-only");
        return;
    }
    accept_survives_fd_exhaustion("threads");
}
