//! Durability properties of the WAL subsystem, end to end:
//!
//! * **Replay byte-identity** — for every mutable family, a run that
//!   bootstraps a WAL, checkpoints mid-schedule, and is then "crashed"
//!   and recovered must persist to a bundle byte-identical to an
//!   uninterrupted run of the same ops (the PR 5 determinism contract
//!   upgraded to a durability guarantee).
//! * **Crash injection** — torn tails and bit flips recover to the last
//!   durable prefix with a structured report, never a panic, and the
//!   repaired log accepts resumed appends.
//! * **Group commit** — fsync policies gate physical syncs through the
//!   `Wal` handle exactly as they do on a bare `WalWriter`.
//! * **Process-level smoke** — a served index with `--wal-dir` killed
//!   (SIGKILL) mid-churn recovers every acknowledged mutation.

use std::sync::Arc;

use finger_ann::core::distance::Metric;
use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::data::persist::{load_index, save_index};
use finger_ann::data::synth::tiny;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::{BruteForce, FingerHnswIndex, HnswIndex};
use finger_ann::index::sharded::{ShardSpec, ShardedIndex};
use finger_ann::index::{AnnIndex, MutableAnnIndex, SearchContext};
use finger_ann::router::{Client, MutOutcome, Request};
use finger_ann::wal::{log_path, snapshot_path, FsyncPolicy, Wal, WalOp};

const N0: usize = 24;
const DIM: usize = 6;

/// Same sizing rationale as `mutation_props.rs`: base-layer capacity
/// `2m >= N0 + ops - 1` keeps the graph complete so replay equality is
/// structural, not a recall bet.
fn graph_params() -> HnswParams {
    HnswParams { m: 32, ef_construction: 128, ..Default::default() }
}

const FAMILIES: &[&str] = &[
    "bruteforce",
    "hnsw",
    "hnsw-finger",
    "sharded-bruteforce",
    "sharded-hnsw",
];

fn build_family(name: &str, data: &Arc<Matrix>) -> Box<dyn AnnIndex> {
    let spec = ShardSpec { n_shards: 3, ..Default::default() };
    match name {
        "bruteforce" => Box::new(BruteForce::new(Arc::clone(data))),
        "hnsw" => Box::new(HnswIndex::build(Arc::clone(data), graph_params())),
        "hnsw-finger" => Box::new(FingerHnswIndex::build(
            Arc::clone(data),
            graph_params(),
            FingerParams { rank: 4, ..Default::default() },
        )),
        "sharded-bruteforce" => Box::new(ShardedIndex::build(
            Arc::clone(data),
            &spec,
            |sub| -> Box<dyn AnnIndex> { Box::new(BruteForce::new(sub)) },
        )),
        "sharded-hnsw" => Box::new(ShardedIndex::build(
            Arc::clone(data),
            &spec,
            |sub| -> Box<dyn AnnIndex> { Box::new(HnswIndex::build(sub, graph_params())) },
        )),
        other => panic!("unknown family {other}"),
    }
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("finger_walprops_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A seeded schedule of ops that is valid to apply in order from `n0`
/// initial rows: deletes always target a live id, the id watermark is
/// mirrored so inserts line up with the index's own allocation.
fn gen_ops(seed: u64, n0: usize, count: usize) -> Vec<WalOp> {
    let mut rng = Pcg32::new(seed);
    let mut live: Vec<u32> = (0..n0 as u32).collect();
    let mut next = n0 as u32;
    let mut ops = Vec::with_capacity(count);
    for _ in 0..count {
        match rng.gen_range(10) {
            0..=4 => {
                let vector: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
                ops.push(WalOp::Insert { vector });
                live.push(next);
                next += 1;
            }
            5..=7 if !live.is_empty() => {
                let at = rng.gen_range(live.len());
                ops.push(WalOp::Delete { key: live.swap_remove(at) });
            }
            _ => ops.push(WalOp::Compact),
        }
    }
    ops
}

fn apply(m: &mut dyn MutableAnnIndex, ctx: &mut SearchContext, op: &WalOp) {
    match op {
        WalOp::Insert { vector } => {
            m.insert(vector, ctx).expect("insert");
        }
        WalOp::Delete { key } => m.remove(*key).expect("remove live id"),
        WalOp::Compact => {
            // Threshold-gated; logged regardless — the gate is
            // deterministic, so replay takes the same branch.
            m.compact(ctx).expect("compact");
        }
        WalOp::SetThreshold { frac } => m.set_compact_threshold(*frac),
    }
}

/// The v5 bundle bytes of `index` (what `save_index` would persist).
fn bundle_bytes(index: &dyn AnnIndex, tag: &str) -> Vec<u8> {
    let p = std::env::temp_dir().join(format!("finger_walprops_b_{}_{tag}.idx", std::process::id()));
    save_index(&p, index).expect("save bundle");
    let bytes = std::fs::read(&p).expect("read bundle back");
    std::fs::remove_file(&p).ok();
    bytes
}

/// The acceptance property: for every mutable family, crash-and-recover
/// persists the exact bytes an uninterrupted run would have — including
/// across a mid-schedule checkpoint rotation.
#[test]
fn prop_recovered_bundle_is_byte_identical_for_every_family() {
    for (fi, family) in FAMILIES.iter().enumerate() {
        let seed = 0xD0_0D ^ ((fi as u64) << 8);
        let ds = tiny(seed, N0, DIM, Metric::L2);
        let ops = gen_ops(seed ^ 1, N0, 30);
        let dir = tmp_dir(&format!("ident_{family}"));

        // Uninterrupted control run: same ops, no WAL. The compaction
        // threshold stays at its default here because this test rotates
        // the log with a bare `Wal::checkpoint`, which does not re-log a
        // custom threshold into the fresh generation (the serving path,
        // `ServeIndex::save`, does — see `repl_props.rs` for schedules
        // that exercise `SetThreshold` across rotations).
        let mut plain = build_family(family, &ds.data);
        {
            let mut ctx = SearchContext::new();
            let m = plain.as_mutable().expect(family);
            for op in &ops {
                apply(m, &mut ctx, op);
            }
        }

        // Durable run: group-committed WAL, checkpoint halfway through.
        let mid = ops.len() / 2;
        let mut durable = build_family(family, &ds.data);
        let wal = Wal::bootstrap(&dir, durable.as_ref(), FsyncPolicy::EveryN(3)).expect("bootstrap");
        {
            let mut ctx = SearchContext::new();
            for (i, op) in ops.iter().enumerate() {
                apply(durable.as_mutable().unwrap(), &mut ctx, op);
                let (w, seq) = wal.append(op).expect("append");
                w.commit(seq).expect("commit");
                assert_eq!(seq, i as u64 + 1, "{family}: log seq mirrors op order");
                if i == mid {
                    assert_eq!(wal.checkpoint(durable.as_ref()).unwrap(), i as u64 + 1);
                }
            }
        }
        wal.sync().expect("final sync");
        drop(wal);
        drop(durable); // "crash": nothing survives but the files

        let (recovered, _wal2, report) =
            Wal::recover(&dir, FsyncPolicy::EveryN(3)).expect("recover");
        assert!(report.corruption.is_none(), "{family}: {:?}", report.corruption);
        assert_eq!(report.snapshot_seq, mid as u64 + 1, "{family}");
        assert_eq!(report.replayed, ops.len() - mid - 1, "{family}");
        assert_eq!(report.last_seq, ops.len() as u64, "{family}");

        let a = bundle_bytes(plain.as_ref(), &format!("plain_{family}"));
        let b = bundle_bytes(recovered.as_ref(), &format!("rec_{family}"));
        assert_eq!(a, b, "{family}: recovered bundle != uninterrupted bundle");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Crash injection: a torn append (partial record at the tail) is cut
/// back to the durable prefix with a structured report, and the repaired
/// log accepts resumed appends that a second recovery replays cleanly.
#[test]
fn torn_tail_recovers_the_durable_prefix_and_resumes() {
    let ds = tiny(31, N0, DIM, Metric::L2);
    let ops = gen_ops(32, N0, 8);
    let dir = tmp_dir("torn");
    let mut idx = build_family("bruteforce", &ds.data);
    let wal = Wal::bootstrap(&dir, idx.as_ref(), FsyncPolicy::Always).unwrap();
    let mut ctx = SearchContext::new();
    for op in &ops {
        apply(idx.as_mutable().unwrap(), &mut ctx, op);
        let (w, seq) = wal.append(op).unwrap();
        w.commit(seq).unwrap();
    }
    drop(wal);

    // A crash mid-append leaves fewer bytes than a record header.
    let lp = log_path(&dir, 0);
    let clean_len = std::fs::metadata(&lp).unwrap().len();
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&lp).unwrap();
        f.write_all(&[0x5A, 0x5A, 0x5A]).unwrap();
    }

    let (mut rec, wal2, report) = Wal::recover(&dir, FsyncPolicy::Always).expect("recover");
    assert_eq!(report.replayed, ops.len());
    assert!(report.corruption.is_some(), "torn tail must be reported");
    assert_eq!(report.dropped_bytes, 3);
    assert_eq!(
        std::fs::metadata(&lp).unwrap().len(),
        clean_len,
        "repair truncates exactly the torn bytes"
    );

    // Appends resume on the repaired log with the next sequence number.
    apply(rec.as_mutable().unwrap(), &mut ctx, &WalOp::Compact);
    let (w, seq) = wal2.append(&WalOp::Compact).unwrap();
    assert_eq!(seq, ops.len() as u64 + 1);
    w.sync().unwrap();
    drop(wal2);

    let (_rec2, _wal3, r2) = Wal::recover(&dir, FsyncPolicy::Always).expect("second recover");
    assert!(r2.corruption.is_none(), "{:?}", r2.corruption);
    assert_eq!(r2.replayed, ops.len() + 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Bit flips anywhere in the log are caught by the record CRC: recovery
/// stops at the last intact record, reports the corruption, and the
/// recovered state byte-matches a run of exactly that surviving prefix.
#[test]
fn bit_flips_recover_to_a_verified_prefix_never_panic() {
    let ds = tiny(57, N0, DIM, Metric::L2);
    let ops = gen_ops(58, N0, 10);
    let dir = tmp_dir("flip");
    let mut idx = build_family("bruteforce", &ds.data);
    let wal = Wal::bootstrap(&dir, idx.as_ref(), FsyncPolicy::Always).unwrap();
    let mut ctx = SearchContext::new();
    for op in &ops {
        apply(idx.as_mutable().unwrap(), &mut ctx, op);
        let (w, seq) = wal.append(op).unwrap();
        w.commit(seq).unwrap();
    }
    drop(wal);
    let lp = log_path(&dir, 0);
    let clean = std::fs::read(&lp).unwrap();

    for flip in [10, clean.len() / 2, clean.len() - 5] {
        let mut bytes = clean.clone();
        bytes[flip] ^= 0x10;
        std::fs::write(&lp, &bytes).unwrap();

        let (rec, _w, report) = Wal::recover(&dir, FsyncPolicy::Always)
            .unwrap_or_else(|e| panic!("flip at {flip}: recovery errored: {e}"));
        assert!(report.corruption.is_some(), "flip at {flip} went undetected");
        assert!(report.replayed < ops.len(), "flip at {flip} dropped nothing");
        assert_eq!(report.last_seq, report.replayed as u64);

        // The recovered index == a fresh run of exactly the prefix.
        let mut want = build_family("bruteforce", &ds.data);
        for op in &ops[..report.replayed] {
            apply(want.as_mutable().unwrap(), &mut ctx, op);
        }
        let a = bundle_bytes(rec.as_ref(), &format!("flip_{flip}"));
        let b = bundle_bytes(want.as_ref(), &format!("flipwant_{flip}"));
        assert_eq!(a, b, "flip at {flip}: prefix state diverged");

        // Recovery repaired the file in place; restore the clean copy for
        // the next injection.
        std::fs::write(&lp, &clean).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Fsync policies gate physical syncs through the `Wal` handle: `every_n`
/// batches, `never` defers entirely, and an explicit `sync()` always
/// catches the log up.
#[test]
fn commit_policies_gate_fsyncs_through_the_wal_handle() {
    for (policy, want_synced) in [(FsyncPolicy::EveryN(4), 8), (FsyncPolicy::Never, 0)] {
        let ds = tiny(91, N0, DIM, Metric::L2);
        let dir = tmp_dir(&format!("policy_{}", policy.name().replace(':', "_")));
        let mut idx = build_family("bruteforce", &ds.data);
        let wal = Wal::bootstrap(&dir, idx.as_ref(), policy).unwrap();
        let mut ctx = SearchContext::new();
        let mut rng = Pcg32::new(92);
        for _ in 0..10 {
            let vector: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
            apply(idx.as_mutable().unwrap(), &mut ctx, &WalOp::Insert { vector: vector.clone() });
            let (w, seq) = wal.append(&WalOp::Insert { vector }).unwrap();
            w.commit(seq).unwrap();
        }
        let w = wal.writer();
        assert_eq!(w.appended_seq(), 10);
        assert_eq!(w.synced_seq(), want_synced, "policy {}", policy.name());
        wal.sync().unwrap();
        assert_eq!(w.synced_seq(), 10);
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// A logical record bigger than one 32 KiB block fragments across block
/// boundaries and still replays to identical bytes.
#[test]
fn a_record_larger_than_one_block_survives_recovery() {
    let wide = 9_000; // 36 KB payload > BLOCK_SIZE
    let mut m = Matrix::zeros(0, wide);
    let mut rng = Pcg32::new(5);
    for _ in 0..2 {
        let row: Vec<f32> = (0..wide).map(|_| rng.next_gaussian()).collect();
        m.push_row(&row);
    }
    let data = Arc::new(m);
    let dir = tmp_dir("bigrec");

    let mut plain: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::clone(&data)));
    let mut durable: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::clone(&data)));
    let wal = Wal::bootstrap(&dir, durable.as_ref(), FsyncPolicy::Always).unwrap();
    let mut ctx = SearchContext::new();
    let vector: Vec<f32> = (0..wide).map(|_| rng.next_gaussian()).collect();
    let op = WalOp::Insert { vector };
    apply(plain.as_mutable().unwrap(), &mut ctx, &op);
    apply(durable.as_mutable().unwrap(), &mut ctx, &op);
    let (w, seq) = wal.append(&op).unwrap();
    w.commit(seq).unwrap();
    drop(wal);
    drop(durable);

    let (rec, _w, report) = Wal::recover(&dir, FsyncPolicy::Always).unwrap();
    assert!(report.corruption.is_none());
    assert_eq!(report.replayed, 1);
    let a = bundle_bytes(plain.as_ref(), "big_plain");
    let b = bundle_bytes(rec.as_ref(), "big_rec");
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).ok();
}

/// Kills the child process on every exit path so a failing assert does
/// not leak a serving `finger` process.
struct KillOnDrop(std::process::Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

/// Process-level smoke: serve with `--wal-dir --fsync-policy always`,
/// churn acknowledged mutations over TCP, SIGKILL the server, and recover
/// in-process. Every acked op must be durable: the recovered bundle
/// byte-matches the bootstrap snapshot plus exactly the acked ops.
#[test]
fn recovery_smoke_kills_a_serving_process_mid_churn() {
    use std::io::BufRead as _;
    use std::process::{Command, Stdio};

    let root = tmp_dir("smoke");
    std::fs::create_dir_all(&root).unwrap();
    let wal_dir = root.join("wal");
    let bundle = root.join("seed.idx");

    let ds = tiny(77, 40, DIM, Metric::L2);
    let seed_index = BruteForce::new(Arc::clone(&ds.data));
    save_index(&bundle, &seed_index).unwrap();

    let mut child = Command::new(env!("CARGO_BIN_EXE_finger"))
        .args([
            "serve",
            "--index",
            bundle.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--wal-dir",
            wal_dir.to_str().unwrap(),
            "--fsync-policy",
            "always",
            "--workers",
            "1",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn finger serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let child = KillOnDrop(child);

    // The banner line carries the OS-assigned port; serve flushes stdout
    // right after printing it.
    let mut addr = None;
    for line in std::io::BufReader::new(stdout).lines() {
        let line = line.expect("read child stdout");
        if let Some(rest) = line.split(" on ").nth(1) {
            if line.starts_with("serving ") {
                addr = rest.split_whitespace().next().map(str::to_string);
                break;
            }
        }
    }
    let addr: std::net::SocketAddr =
        addr.expect("server banner").parse().expect("parse bound addr");

    let mut client = Client::connect(&addr).expect("connect");
    let mut acked: Vec<WalOp> = Vec::new();
    let mut rng = Pcg32::new(4242);
    for i in 0..12u64 {
        let vector: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
        let resp = client
            .mutate(&Request::Insert { id: i, vector: vector.clone() })
            .expect("insert acked");
        assert!(matches!(resp.outcome, MutOutcome::Inserted(_)));
        acked.push(WalOp::Insert { vector });
    }
    let resp = client.mutate(&Request::Delete { id: 99, key: 3 }).expect("delete acked");
    assert!(matches!(resp.outcome, MutOutcome::Deleted(3)));
    acked.push(WalOp::Delete { key: 3 });

    // SIGKILL, not shutdown: fsync=always means every ack above is
    // already durable, so nothing may be lost.
    drop(client);
    drop(child);

    let (recovered, _wal, report) =
        Wal::recover(&wal_dir, FsyncPolicy::Always).expect("recover after kill");
    assert!(report.corruption.is_none(), "{:?}", report.corruption);
    assert_eq!(report.replayed, acked.len(), "every acked op is durable");

    // Baseline: the bootstrap snapshot plus the acked ops, applied
    // in-process.
    let mut baseline = load_index(&snapshot_path(&wal_dir, 0)).expect("load snapshot");
    let mut ctx = SearchContext::new();
    for op in &acked {
        apply(baseline.as_mutable().unwrap(), &mut ctx, op);
    }
    let a = bundle_bytes(recovered.as_ref(), "smoke_rec");
    let b = bundle_bytes(baseline.as_ref(), "smoke_base");
    assert_eq!(a, b, "recovered state != snapshot + acked ops");
    std::fs::remove_dir_all(&root).ok();
}
