//! Trait-conformance suite: every `AnnIndex` implementor must satisfy the
//! same contract — exactness against brute force on an easy instance,
//! ascending unique results, honest metadata, batch == sequential, and
//! sane stats bookkeeping — all through `&dyn AnnIndex` with one shared
//! pooled `SearchContext`. The sharded wrapper of every family runs the
//! same checks as the flat families.

use std::sync::Arc;

use finger_ann::core::distance::{l2_sq, Metric};
use finger_ann::core::matrix::Matrix;
use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::synth::{tiny, Dataset};
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::graph::nndescent::NnDescentParams;
use finger_ann::graph::search::Neighbor;
use finger_ann::graph::vamana::VamanaParams;
use finger_ann::index::impls::{BruteForce, FingerHnswIndex, HnswIndex, NnDescentIndex, VamanaIndex};
use finger_ann::index::{
    build_all_families, build_all_families_sharded, AnnIndex, MutateError, SearchContext,
    SearchParams,
};
use finger_ann::quant::Precision;

/// All ten flat families (six f32 + four quantized-tier variants) plus
/// their sharded wrappers over one dataset — the single registry shared
/// with the persistence-roundtrip suite.
fn all_indexes(ds: &Dataset) -> Vec<Box<dyn AnnIndex>> {
    let mut v = build_all_families(Arc::clone(&ds.data));
    v.extend(build_all_families_sharded(Arc::clone(&ds.data), 3));
    v
}

/// Generous per-family search settings: wide beams / many probes, so every
/// family is operating in its high-recall regime.
fn conformance_params() -> SearchParams {
    SearchParams::new(10).with_ef(120).with_probes(16)
}

#[test]
fn names_and_metadata_are_honest() {
    let ds = tiny(601, 400, 16, Metric::L2);
    let indexes = all_indexes(&ds);
    let names: Vec<&str> = indexes.iter().map(|i| i.name()).collect();
    assert_eq!(
        names,
        vec![
            "bruteforce",
            "hnsw",
            "hnsw-finger",
            "vamana",
            "nndescent",
            "ivfpq",
            "bruteforce-sq8",
            "hnsw-sq8",
            "hnsw-pq",
            "hnsw-finger-sq8",
            "sharded-bruteforce",
            "sharded-hnsw",
            "sharded-hnsw-finger",
            "sharded-vamana",
            "sharded-nndescent",
            "sharded-ivfpq",
            "sharded-bruteforce-sq8",
            "sharded-hnsw-sq8",
            "sharded-hnsw-pq",
            "sharded-hnsw-finger-sq8",
        ]
    );
    for index in &indexes {
        assert_eq!(index.len(), 400, "{}", index.name());
        assert_eq!(index.dim(), 16, "{}", index.name());
        assert!(!index.is_empty());
        assert_eq!(index.data().rows(), 400);
        if index.name() == "bruteforce" {
            assert_eq!(index.nbytes(), 0);
            assert_eq!(index.approx_rank(), 0);
        } else {
            assert!(index.nbytes() > 0, "{}", index.name());
        }
        if index.name().contains("hnsw-finger") {
            assert_eq!(index.approx_rank(), 8, "{}", index.name());
        }
    }
}

#[test]
fn every_family_finds_nearest_neighbors() {
    let ds = tiny(602, 500, 16, Metric::L2);
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let params = conformance_params();
    let mut ctx = SearchContext::new();
    for index in all_indexes(&ds) {
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = index.search(ds.queries.row(qi), &params, &mut ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        let avg = total / ds.queries.rows() as f64;
        let exact = index.name() == "bruteforce" || index.name() == "sharded-bruteforce";
        let floor = if exact { 0.999 } else { 0.7 };
        assert!(avg > floor, "{}: recall@10 = {avg}", index.name());
    }
}

#[test]
fn results_ascending_unique_and_k_bounded() {
    let ds = tiny(603, 300, 12, Metric::L2);
    let params = conformance_params();
    let mut ctx = SearchContext::new();
    for index in all_indexes(&ds) {
        for qi in 0..4 {
            let res = index.search(ds.queries.row(qi), &params, &mut ctx);
            assert!(res.len() <= params.k, "{}", index.name());
            assert!(!res.is_empty(), "{}", index.name());
            for w in res.windows(2) {
                assert!(w[0].dist <= w[1].dist, "{}: not ascending", index.name());
            }
            let mut ids: Vec<u32> = res.iter().map(|n| n.id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), res.len(), "{}: duplicate ids", index.name());
            assert!(ids.iter().all(|&id| (id as usize) < index.len()));
        }
    }
}

#[test]
fn batch_search_matches_sequential() {
    let ds = tiny(604, 300, 12, Metric::L2);
    let params = conformance_params();
    let mut ctx = SearchContext::new();
    for index in all_indexes(&ds) {
        let batched = index.batch_search(&ds.queries, &params, &mut ctx);
        assert_eq!(batched.len(), ds.queries.rows());
        for qi in 0..ds.queries.rows() {
            let single = index.search(ds.queries.row(qi), &params, &mut ctx);
            let a: Vec<u32> = batched[qi].iter().map(|n| n.id).collect();
            let b: Vec<u32> = single.iter().map(|n| n.id).collect();
            assert_eq!(a, b, "{} query {qi}", index.name());
        }
    }
}

#[test]
fn stats_invariants_hold_for_every_family() {
    let ds = tiny(605, 300, 12, Metric::L2);
    let params = conformance_params();
    let mut ctx = SearchContext::new().with_stats();
    for index in all_indexes(&ds) {
        ctx.reset_stats();
        index.search(ds.queries.row(0), &params, &mut ctx);
        let stats = ctx.take_stats();
        let name = index.name();
        assert!(
            stats.dist_calls > 0 || stats.approx_calls > 0,
            "{name}: no work recorded"
        );
        assert!(stats.wasted <= stats.dist_calls, "{name}");
        if name == "bruteforce" || name == "sharded-bruteforce" {
            // Full-probe scatter over brute-force shards sums to one scan.
            assert_eq!(stats.dist_calls, index.len() as u64, "{name}");
        }
        if name == "bruteforce-sq8" || name == "sharded-bruteforce-sq8" {
            // Quantized scan scores every live row approximately, then
            // re-ranks only a shortlist exactly (per shard, so the sharded
            // sum can reach the full scan when shards fit the shortlist).
            assert_eq!(stats.approx_calls, index.len() as u64, "{name}");
            assert!(stats.dist_calls <= index.len() as u64, "{name}");
        }
        if name == "bruteforce-sq8" {
            assert!(stats.dist_calls < index.len() as u64, "{name}: shortlist not truncated");
        }
        if name == "hnsw-finger" || name == "ivfpq" || name == "sharded-ivfpq" {
            assert!(stats.approx_calls > 0, "{name}: approximate path unused");
        }
        if name.ends_with("-sq8") || name.ends_with("-pq") {
            // Quantized traversal drives the beam (approx_calls) and the
            // exact re-rank of the final pool records dist_calls.
            assert!(stats.approx_calls > 0, "{name}: quantized loop unused");
            assert!(stats.dist_calls > 0, "{name}: exact re-rank unused");
        }
        // Disabled stats must record nothing.
        ctx.stats_enabled = false;
        index.search(ds.queries.row(0), &params, &mut ctx);
        assert_eq!(ctx.stats.dist_calls, 0, "{name}: wrote stats while disabled");
        ctx.stats_enabled = true;
    }
}

#[test]
fn one_context_serves_indexes_of_different_sizes() {
    let small = tiny(606, 120, 8, Metric::L2);
    let large = tiny(607, 900, 8, Metric::L2);
    let params = conformance_params();
    let mut ctx = SearchContext::new();
    // Alternate between universes; the pooled visited set must grow and
    // stay correct in both directions.
    let a = BruteForce::new(Arc::clone(&small.data));
    let b = HnswIndex::build(
        Arc::clone(&large.data),
        HnswParams { m: 8, ef_construction: 60, ..Default::default() },
    );
    for round in 0..3 {
        let ra = a.search(small.queries.row(round), &params, &mut ctx);
        assert!(ra.iter().all(|n| (n.id as usize) < small.data.rows()));
        let rb = b.search(large.queries.row(round), &params, &mut ctx);
        assert!(rb.iter().all(|n| (n.id as usize) < large.data.rows()));
    }
    // Exactness survives the round trips.
    let gt = exact_knn(&small.data, &small.queries, 10);
    let res = a.search(small.queries.row(0), &params, &mut ctx);
    let ids: Vec<u32> = res.iter().map(|n| n.id).collect();
    assert_eq!(ids, gt[0]);
}

/// Mutation lifecycle, run against every implementor: the mutable
/// families must find a fresh insert, never return a removed id, and
/// keep recall within 2 points across a forced compaction — while the
/// non-mutable families cleanly report unsupported instead of panicking.
#[test]
fn mutation_lifecycle_conformance() {
    let ds = tiny(609, 400, 16, Metric::L2);
    let params = conformance_params();
    let mut ctx = SearchContext::new();
    let expect_mutable = [
        "bruteforce",
        "hnsw",
        "hnsw-finger",
        "bruteforce-sq8",
        "hnsw-sq8",
        "hnsw-pq",
        "hnsw-finger-sq8",
        "sharded-bruteforce",
        "sharded-hnsw",
        "sharded-hnsw-finger",
        "sharded-bruteforce-sq8",
        "sharded-hnsw-sq8",
        "sharded-hnsw-pq",
        "sharded-hnsw-finger-sq8",
    ];
    let mut seen_mutable = Vec::new();

    for mut index in all_indexes(&ds) {
        let name = index.name();
        let Some(m) = index.as_mutable() else {
            assert!(
                !expect_mutable.contains(&name),
                "{name}: expected to support mutation"
            );
            continue;
        };
        assert!(expect_mutable.contains(&name), "{name}: unexpectedly mutable");
        seen_mutable.push(name);

        // Insert-then-search finds the new vector (placed far from the
        // data cloud so any sane index returns it first).
        let v: Vec<f32> = (0..16).map(|j| 40.0 + j as f32).collect();
        let id = m.insert(&v, &mut ctx).unwrap();
        assert_eq!(id, 400, "{name}: watermark starts past the build");
        assert_eq!(m.live_len(), 401, "{name}");
        let got = m.search(&v, &params, &mut ctx);
        assert_eq!(got[0].id, id, "{name}: inserted vector not found");

        // Remove-then-search never returns it.
        m.remove(id).unwrap();
        let got = m.search(&v, &params, &mut ctx);
        assert!(got.iter().all(|n| n.id != id), "{name}: removed id emitted");

        // Build tombstone pressure, then force a compaction and require
        // recall within 2 points of the pre-compaction index.
        let dead: Vec<u32> = (0..50).collect();
        for &d in &dead {
            m.remove(d).unwrap();
        }
        let truth = |q: &[f32]| -> Vec<u32> {
            let mut all: Vec<Neighbor> = (50..400u32)
                .map(|i| Neighbor { dist: l2_sq(q, ds.data.row(i as usize)), id: i })
                .collect();
            all.sort();
            all.truncate(params.k);
            all.into_iter().map(|n| n.id).collect()
        };
        let mean_recall = |m: &mut dyn finger_ann::index::MutableAnnIndex,
                           ctx: &mut SearchContext| {
            let mut total = 0.0;
            for qi in 0..ds.queries.rows() {
                let q = ds.queries.row(qi);
                let got = m.search(q, &params, ctx);
                let want = truth(q);
                let hits = got.iter().filter(|n| want.contains(&n.id)).count();
                total += hits as f64 / want.len() as f64;
            }
            total / ds.queries.rows() as f64
        };
        let before = mean_recall(m, &mut ctx);
        m.set_compact_threshold(0.0);
        assert!(m.compact(&mut ctx).unwrap(), "{name}: forced compaction must rebuild");
        assert_eq!(m.live_len(), 350, "{name}");
        assert_eq!(m.tombstone_fraction(), 0.0, "{name}");
        assert_eq!(m.remove(400), Err(MutateError::UnknownId(400)), "{name}: id reclaimed");
        let after = mean_recall(m, &mut ctx);
        assert!(
            after >= before - 0.02,
            "{name}: compaction dropped recall {before:.4} -> {after:.4}"
        );
        assert!(before > 0.7, "{name}: pre-compaction recall {before:.4}");
    }
    let mut expect = expect_mutable.to_vec();
    expect.sort_unstable();
    seen_mutable.sort_unstable();
    assert_eq!(seen_mutable, expect, "mutable family set drifted");
}

/// The batched-data-plane acceptance criterion, end to end through the
/// public `AnnIndex` API: plain beam search and FINGER-screened search
/// return bitwise-identical (dist, id) streams under batched vs scalar
/// scoring — on seeded datasets with a non-lane-multiple dimension, a NaN
/// row (ties and NaN ordering included), and across the tombstone-aware
/// live paths after online mutation.
#[test]
fn batched_and_scalar_search_streams_bitwise_identical() {
    let ds = tiny(610, 500, 12, Metric::L2); // dim 12: lane-folded tail in play
    let mut poisoned: Matrix = (*ds.data).clone();
    poisoned.row_mut(123)[7] = f32::NAN; // corrupt row must order identically
    let data = Arc::new(poisoned);

    let mut indexes: Vec<Box<dyn AnnIndex>> = vec![
        Box::new(HnswIndex::build(
            Arc::clone(&data),
            HnswParams { m: 10, ef_construction: 70, ..Default::default() },
        )),
        Box::new(FingerHnswIndex::build(
            Arc::clone(&data),
            HnswParams { m: 10, ef_construction: 70, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        )),
        // Quantized tiers ride the same contract: the u8 beam loop is
        // kernel-dispatch-invariant and the re-rank honors the flag.
        Box::new(HnswIndex::build_with_precision(
            Arc::clone(&data),
            HnswParams { m: 10, ef_construction: 70, ..Default::default() },
            Precision::Sq8,
        )),
        Box::new(FingerHnswIndex::build_with_precision(
            Arc::clone(&data),
            HnswParams { m: 10, ef_construction: 70, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
            Precision::Sq8,
        )),
        Box::new(VamanaIndex::build(
            Arc::clone(&data),
            VamanaParams { r: 16, ..Default::default() },
        )),
        Box::new(NnDescentIndex::build(Arc::clone(&data), NnDescentParams::default())),
    ];

    let mut ctx = SearchContext::new();
    let compare_all = |index: &dyn AnnIndex, ctx: &mut SearchContext, phase: &str| {
        for ef in [5usize, 30, 120] {
            let batched = SearchParams::new(10).with_ef(ef);
            let scalar = SearchParams::new(10).with_ef(ef).with_scalar_kernels(true);
            for qi in 0..ds.queries.rows() {
                let q = ds.queries.row(qi);
                let a = index.search(q, &batched, ctx);
                let b = index.search(q, &scalar, ctx);
                // Neighbor equality goes through f32::total_cmp, so equal
                // streams mean bitwise-equal distances and ids.
                assert_eq!(a, b, "{} [{phase}] ef={ef} query {qi}", index.name());
            }
        }
    };

    for index in &indexes {
        compare_all(index.as_ref(), &mut ctx, "static");
    }

    // Mutate the mutable families (tombstones + an append) and compare the
    // live search paths too.
    for index in indexes.iter_mut() {
        let name = index.name();
        let Some(m) = index.as_mutable() else { continue };
        let v: Vec<f32> = (0..12).map(|j| 30.0 + j as f32).collect();
        m.insert(&v, &mut ctx).unwrap();
        for dead in [0u32, 7, 123, 250] {
            m.remove(dead).unwrap();
        }
        assert_eq!(m.live_len(), 497, "{name}");
    }
    for index in &indexes {
        if index.as_mutable_view().is_some() {
            compare_all(index.as_ref(), &mut ctx, "live");
        }
    }
}

/// The quantized-tier acceptance criterion: SQ8/PQ traversal with exact
/// re-rank stays within 2 recall points of the f32 family it shadows,
/// and the sq8 tier is at least 2x smaller than the f32 vectors it
/// replaces in the hot loop.
#[test]
fn quantized_families_within_two_points_of_f32() {
    let ds = tiny(611, 500, 16, Metric::L2);
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let params = SearchParams::new(10).with_ef(200);
    let mut ctx = SearchContext::new();
    let mean_recall = |index: &dyn AnnIndex, ctx: &mut SearchContext| {
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = index.search(ds.queries.row(qi), &params, ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        total / ds.queries.rows() as f64
    };

    let hp = HnswParams { m: 12, ef_construction: 80, ..Default::default() };
    let fp = FingerParams { rank: 8, ..Default::default() };
    let pairs: Vec<(Box<dyn AnnIndex>, Box<dyn AnnIndex>)> = vec![
        (
            Box::new(BruteForce::new(Arc::clone(&ds.data))),
            Box::new(BruteForce::with_precision(Arc::clone(&ds.data), Precision::Sq8)),
        ),
        (
            Box::new(HnswIndex::build(Arc::clone(&ds.data), hp.clone())),
            Box::new(HnswIndex::build_with_precision(
                Arc::clone(&ds.data),
                hp.clone(),
                Precision::Sq8,
            )),
        ),
        (
            Box::new(HnswIndex::build(Arc::clone(&ds.data), hp.clone())),
            Box::new(HnswIndex::build_with_precision(
                Arc::clone(&ds.data),
                hp.clone(),
                Precision::Pq,
            )),
        ),
        (
            Box::new(FingerHnswIndex::build(Arc::clone(&ds.data), hp.clone(), fp.clone())),
            Box::new(FingerHnswIndex::build_with_precision(
                Arc::clone(&ds.data),
                hp.clone(),
                fp,
                Precision::Sq8,
            )),
        ),
    ];
    for (exact, quant) in &pairs {
        let base = mean_recall(exact.as_ref(), &mut ctx);
        let q = mean_recall(quant.as_ref(), &mut ctx);
        assert!(
            q >= base - 0.02,
            "{}: recall {q:.4} more than 2pts under {} ({base:.4})",
            quant.name(),
            exact.name()
        );
    }

    // sq8 codes are 1 byte/lane vs 4 for f32 — even with codec overhead
    // the traversal tier must be >= 2x smaller than the raw f32 vectors.
    let sq8 = HnswIndex::build_with_precision(Arc::clone(&ds.data), hp, Precision::Sq8);
    let tier = sq8.quant().expect("sq8 tier").nbytes();
    let f32_bytes = ds.data.rows() * ds.data.cols() * std::mem::size_of::<f32>();
    assert!(tier * 2 <= f32_bytes, "sq8 tier {tier} B vs f32 {f32_bytes} B");
}

#[test]
fn early_termination_budget_reduces_work_uniformly() {
    let ds = tiny(608, 600, 16, Metric::L2);
    let mut ctx = SearchContext::new().with_stats();
    // Graph families accept the patience knob through the same params.
    let graphs: Vec<Box<dyn AnnIndex>> = vec![
        Box::new(HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        )),
        Box::new(VamanaIndex::build(Arc::clone(&ds.data), VamanaParams::default())),
        Box::new(NnDescentIndex::build(
            Arc::clone(&ds.data),
            NnDescentParams::default(),
        )),
    ];
    for index in graphs {
        let wide = SearchParams::new(10).with_ef(160);
        let budgeted = SearchParams::new(10).with_ef(160).with_patience(1);
        ctx.reset_stats();
        for qi in 0..ds.queries.rows() {
            index.search(ds.queries.row(qi), &wide, &mut ctx);
        }
        let full = ctx.take_stats().dist_calls;
        for qi in 0..ds.queries.rows() {
            index.search(ds.queries.row(qi), &budgeted, &mut ctx);
        }
        let cut = ctx.take_stats().dist_calls;
        assert!(cut < full, "{}: {cut} !< {full}", index.name());
    }
}
