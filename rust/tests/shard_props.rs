//! Deterministic test harness for the sharded scatter-gather engine.
//!
//! Properties (randomized over seeds via `testutil::forall`):
//!  * merged top-k over shards == brute-force top-k over the union, for
//!    random datasets, shard counts, ks, and both assignment strategies;
//!  * returned ids survive local→global remapping (the distance reported
//!    for an id equals the distance recomputed from the global matrix);
//!  * the merge is stable under NaN-free ties (duplicated points resolve
//!    by ascending global id, exactly like the unsharded scan).
//!
//! Plus the recall-preservation, determinism, and persistence round-trip
//! suites for graph-family shards.

use std::sync::Arc;

use finger_ann::core::distance::{l2_sq, Metric};
use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::core::store::VectorStore;
use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::persist::{load_index, save_index};
use finger_ann::data::synth::tiny;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::bruteforce::scan;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::{BruteForce, FingerHnswIndex, HnswIndex};
use finger_ann::index::{
    AnnIndex, SearchContext, SearchParams, ShardSpec, ShardStrategy, ShardedIndex,
};
use finger_ann::testutil::{forall, vec_f32};

fn random_matrix(rng: &mut Pcg32, n: usize, dim: usize) -> Arc<Matrix> {
    let mut m = Matrix::zeros(0, dim);
    for _ in 0..n {
        m.push_row(&vec_f32(rng, dim));
    }
    Arc::new(m)
}

fn sharded_bruteforce(data: &Arc<Matrix>, spec: &ShardSpec) -> ShardedIndex {
    ShardedIndex::build(Arc::clone(data), spec, |sub| -> Box<dyn AnnIndex> {
        Box::new(BruteForce::new(sub))
    })
}

/// Merged shard top-k equals brute force over the union — exactly, ids
/// and distances, for random (n, dim, S, k, strategy).
#[test]
fn merged_topk_equals_bruteforce_over_union() {
    forall("sharded top-k == union top-k", 12, |rng| {
        let n = 50 + rng.gen_range(250);
        let dim = 2 + rng.gen_range(14);
        let s = 1 + rng.gen_range(9);
        let k = 1 + rng.gen_range(15);
        let strategy = if rng.gen_range(2) == 0 {
            ShardStrategy::RoundRobin
        } else {
            ShardStrategy::KMeans
        };
        let data = random_matrix(rng, n, dim);
        let spec = ShardSpec { n_shards: s, strategy, ..Default::default() };
        let idx = sharded_bruteforce(&data, &spec);
        let store = VectorStore::from_matrix(&data);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(k);
        for _ in 0..4 {
            let q = vec_f32(rng, dim);
            let got = idx.search(&q, &params, &mut ctx);
            let want = scan(&store, &q, k);
            if got != want {
                return false;
            }
        }
        true
    });
}

/// Every returned id is a valid global id whose recomputed distance from
/// the *global* matrix matches the reported distance bit-for-bit — i.e.
/// local ids never leak through the remap.
#[test]
fn ids_survive_local_to_global_remap() {
    forall("remapped ids are global", 10, |rng| {
        let n = 60 + rng.gen_range(200);
        let dim = 4 + rng.gen_range(12);
        let s = 2 + rng.gen_range(6);
        let data = random_matrix(rng, n, dim);
        let spec = ShardSpec {
            n_shards: s,
            strategy: ShardStrategy::KMeans,
            ..Default::default()
        };
        let idx = sharded_bruteforce(&data, &spec);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10);
        for _ in 0..4 {
            let q = vec_f32(rng, dim);
            for nb in idx.search(&q, &params, &mut ctx) {
                if nb.id as usize >= n {
                    return false;
                }
                if nb.dist.to_bits() != l2_sq(&q, data.row(nb.id as usize)).to_bits() {
                    return false;
                }
            }
        }
        true
    });
}

/// NaN-free ties: with every point duplicated several times, distances
/// collide massively across shards; the merge must still reproduce the
/// unsharded scan's deterministic (distance, ascending id) order.
#[test]
fn merge_is_stable_under_ties() {
    forall("tie-stable merge", 8, |rng| {
        let dim = 3 + rng.gen_range(6);
        let distinct = 20 + rng.gen_range(20);
        let copies = 4;
        let protos: Vec<Vec<f32>> = (0..distinct).map(|_| vec_f32(rng, dim)).collect();
        let mut m = Matrix::zeros(0, dim);
        // Interleave the copies so duplicates land in different shards.
        for _copy in 0..copies {
            for p in &protos {
                m.push_row(p);
            }
        }
        let data = Arc::new(m);
        let s = 2 + rng.gen_range(5);
        let spec = ShardSpec { n_shards: s, ..Default::default() };
        let idx = sharded_bruteforce(&data, &spec);
        let store = VectorStore::from_matrix(&data);
        let mut ctx = SearchContext::new();
        let k = copies * 2 + 1; // forces tie groups to be split at k
        let params = SearchParams::new(k);
        for p in protos.iter().take(4) {
            let got = idx.search(p, &params, &mut ctx);
            let want = scan(&store, p, k);
            if got != want {
                return false;
            }
            // The duplicates of the query point itself must come first, in
            // ascending global-id order.
            let lead: Vec<u32> = got.iter().take(copies).map(|nb| nb.id).collect();
            if lead.windows(2).any(|w| w[0] >= w[1]) {
                return false;
            }
        }
        true
    });
}

/// Recall preservation: sharding an HNSW / HNSW-FINGER index across 4
/// shards at equal ef keeps recall@10 within 2 points of the flat index.
#[test]
fn sharded_graph_recall_within_two_points_of_flat() {
    let ds = tiny(901, 1200, 24, Metric::L2);
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let hnsw_params = HnswParams { m: 12, ef_construction: 80, ..Default::default() };
    let finger_params = FingerParams { rank: 8, ..Default::default() };
    let params = SearchParams::new(10).with_ef(64);
    let spec = ShardSpec { n_shards: 4, ..Default::default() };
    let mut ctx = SearchContext::new();

    let mut recall_of = |index: &dyn AnnIndex| -> f64 {
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = index.search(ds.queries.row(qi), &params, &mut ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        total / ds.queries.rows() as f64
    };

    let flat_hnsw = HnswIndex::build(Arc::clone(&ds.data), hnsw_params.clone());
    let sharded_hnsw = ShardedIndex::build(Arc::clone(&ds.data), &spec, {
        let hp = hnsw_params.clone();
        move |sub| -> Box<dyn AnnIndex> { Box::new(HnswIndex::build(sub, hp.clone())) }
    });
    let r_flat = recall_of(&flat_hnsw);
    let r_sharded = recall_of(&sharded_hnsw);
    assert!(
        r_sharded >= r_flat - 0.02,
        "sharded hnsw recall {r_sharded} vs flat {r_flat}"
    );

    let flat_finger =
        FingerHnswIndex::build(Arc::clone(&ds.data), hnsw_params.clone(), finger_params.clone());
    let sharded_finger = ShardedIndex::build(Arc::clone(&ds.data), &spec, {
        let (hp, fp) = (hnsw_params.clone(), finger_params.clone());
        move |sub| -> Box<dyn AnnIndex> {
            Box::new(FingerHnswIndex::build(sub, hp.clone(), fp.clone()))
        }
    });
    let r_flat = recall_of(&flat_finger);
    let r_sharded = recall_of(&sharded_finger);
    assert!(
        r_sharded >= r_flat - 0.02,
        "sharded hnsw-finger recall {r_sharded} vs flat {r_flat}"
    );
}

/// Fixed seeds ⇒ two builds produce identical shard assignments and
/// identical search results, for both strategies, sequential and batched.
#[test]
fn builds_are_deterministic() {
    let ds = tiny(902, 500, 12, Metric::L2);
    for strategy in [ShardStrategy::RoundRobin, ShardStrategy::KMeans] {
        let spec = ShardSpec { n_shards: 5, strategy, seed: 7, ..Default::default() };
        let build = || {
            ShardedIndex::build(Arc::clone(&ds.data), &spec, |sub| -> Box<dyn AnnIndex> {
                Box::new(HnswIndex::build(
                    sub,
                    HnswParams { m: 8, ef_construction: 60, ..Default::default() },
                ))
            })
        };
        let a = build();
        let b = build();
        assert_eq!(a.assignment(), b.assignment(), "{strategy:?} assignment");
        let params = SearchParams::new(10).with_ef(50);
        let mut ctx = SearchContext::new();
        let batched_a = a.batch_search(&ds.queries, &params, &mut ctx);
        for qi in 0..ds.queries.rows() {
            let ra = a.search(ds.queries.row(qi), &params, &mut ctx);
            let rb = b.search(ds.queries.row(qi), &params, &mut ctx);
            assert_eq!(ra, rb, "{strategy:?} query {qi}");
            assert_eq!(batched_a[qi], ra, "{strategy:?} batch vs single, query {qi}");
        }
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("finger_shard_props_{}_{name}", std::process::id()))
}

/// v4 persistence round-trip: identical post-load results (including a
/// partial-probe configuration, proving the manifest carries centroids
/// and `min_shard_frac`), and clean rejection of truncated files.
#[test]
fn persistence_roundtrip_and_truncation() {
    let ds = tiny(903, 400, 10, Metric::L2);
    let spec = ShardSpec {
        n_shards: 4,
        strategy: ShardStrategy::KMeans,
        ..Default::default()
    };
    let idx = ShardedIndex::build(Arc::clone(&ds.data), &spec, |sub| -> Box<dyn AnnIndex> {
        Box::new(HnswIndex::build(
            sub,
            HnswParams { m: 8, ef_construction: 60, ..Default::default() },
        ))
    })
    .with_min_shard_frac(0.5);
    assert_eq!(idx.probe_count(), 2);

    let path = tmp("roundtrip.idx");
    save_index(&path, &idx).unwrap();
    let loaded = load_index(&path).unwrap();
    assert_eq!(loaded.name(), "sharded-hnsw");
    assert_eq!(loaded.len(), 400);
    assert_eq!(loaded.dim(), 10);

    let params = SearchParams::new(10).with_ef(50);
    let mut ctx = SearchContext::new();
    for qi in 0..ds.queries.rows() {
        let a = idx.search(ds.queries.row(qi), &params, &mut ctx);
        let b = loaded.search(ds.queries.row(qi), &params, &mut ctx);
        assert_eq!(a, b, "query {qi} diverged after round-trip");
    }

    // Any truncation must be rejected, never half-loaded.
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    for frac in [0.1, 0.5, 0.9, 0.999] {
        let cut = ((bytes.len() as f64) * frac) as usize;
        let p = tmp(&format!("trunc_{cut}.idx"));
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(load_index(&p).is_err(), "truncated to {cut} bytes still loaded");
        std::fs::remove_file(&p).ok();
    }
}

/// Stats flow through the scatter-gather paths: work is recorded when
/// enabled (both sequential and batched) and never when disabled.
#[test]
fn stats_propagate_through_shards() {
    let ds = tiny(904, 300, 8, Metric::L2);
    let spec = ShardSpec { n_shards: 3, ..Default::default() };
    let idx = sharded_bruteforce(&ds.data, &spec);
    let params = SearchParams::new(5);
    let mut ctx = SearchContext::new().with_stats();
    idx.search(ds.queries.row(0), &params, &mut ctx);
    assert_eq!(ctx.take_stats().dist_calls, 300, "sequential scatter");
    idx.batch_search(&ds.queries, &params, &mut ctx);
    assert_eq!(
        ctx.take_stats().dist_calls,
        300 * ds.queries.rows() as u64,
        "batched scatter"
    );
    ctx.stats_enabled = false;
    idx.search(ds.queries.row(0), &params, &mut ctx);
    idx.batch_search(&ds.queries, &params, &mut ctx);
    assert_eq!(ctx.stats.dist_calls, 0, "disabled stats must stay silent");
}
