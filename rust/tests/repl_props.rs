//! Replication properties of the primary/backup plane, end to end:
//!
//! * **Wire golden fixture** — the frame encoding is pinned byte for byte
//!   by `fixtures/repl_frame_v1.bin`; any drift in the header layout, the
//!   CRC, or the shared `WalOp` record serialization fails here first.
//! * **Convergence byte-identity** — for every mutable family, a replica
//!   that restarts mid-stream (forcing both catch-up modes: log tail and
//!   snapshot reinstall across a primary-side rotation) converges to
//!   state byte-identical to an uninterrupted in-process control run,
//!   both in memory and in its own durable WAL.
//! * **Ack levels** — a mutation acked at level `all` is already applied
//!   and durable on the replica when the client ack returns; with no
//!   replica connected the ack times out with a structured error and the
//!   op stays applied + logged locally.
//! * **Fault injection** — a seeded fault proxy drops, duplicates,
//!   delays, and truncates stream frames; the replica never applies a
//!   torn or replayed record (CRC + seq discipline) and converges
//!   byte-identically once the fault budget is spent.
//! * **Kill-the-primary smoke** — a real primary process serving with
//!   `--repl-listen --ack-level all` is SIGKILLed after acking inserts;
//!   every acked vector is readable from the surviving replica process.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use finger_ann::core::distance::Metric;
use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::data::persist::{bundle_to_vec, save_index};
use finger_ann::data::synth::tiny;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::{BruteForce, FingerHnswIndex, HnswIndex};
use finger_ann::index::sharded::{ShardSpec, ShardedIndex};
use finger_ann::index::{AnnIndex, MutableAnnIndex, SearchContext, SearchParams};
use finger_ann::repl::frame::Frame;
use finger_ann::repl::hub::{HubOpts, ReplHub};
use finger_ann::repl::replica::{Replica, ReplicaOpts, ReplicaStore};
use finger_ann::repl::{fnv1a64, AckLevel};
use finger_ann::router::protocol::FingerprintInfo;
use finger_ann::router::{Client, MutOutcome, Request, ServeIndex};
use finger_ann::testutil::proxy::{FaultPlan, FaultProxy};
use finger_ann::wal::{FsyncPolicy, Wal, WalOp};

const N0: usize = 24;
const DIM: usize = 6;

/// Same sizing rationale as `wal_props.rs`: base-layer capacity
/// `2m >= N0 + ops - 1` keeps the graph complete so replay equality is
/// structural, not a recall bet.
fn graph_params() -> HnswParams {
    HnswParams { m: 32, ef_construction: 128, ..Default::default() }
}

const FAMILIES: &[&str] = &[
    "bruteforce",
    "hnsw",
    "hnsw-finger",
    "sharded-bruteforce",
    "sharded-hnsw",
];

fn build_family(name: &str, data: &Arc<Matrix>) -> Box<dyn AnnIndex> {
    let spec = ShardSpec { n_shards: 3, ..Default::default() };
    match name {
        "bruteforce" => Box::new(BruteForce::new(Arc::clone(data))),
        "hnsw" => Box::new(HnswIndex::build(Arc::clone(data), graph_params())),
        "hnsw-finger" => Box::new(FingerHnswIndex::build(
            Arc::clone(data),
            graph_params(),
            FingerParams { rank: 4, ..Default::default() },
        )),
        "sharded-bruteforce" => Box::new(ShardedIndex::build(
            Arc::clone(data),
            &spec,
            |sub| -> Box<dyn AnnIndex> { Box::new(BruteForce::new(sub)) },
        )),
        "sharded-hnsw" => Box::new(ShardedIndex::build(
            Arc::clone(data),
            &spec,
            |sub| -> Box<dyn AnnIndex> { Box::new(HnswIndex::build(sub, graph_params())) },
        )),
        other => panic!("unknown family {other}"),
    }
}

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("finger_replprops_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A seeded schedule valid to apply in order from `n0` initial rows
/// (deletes target live ids, inserts mirror the id watermark), covering
/// all four replicated verbs. A `SetThreshold` is spliced in early so the
/// primary-side checkpoint re-log path always fires.
fn gen_ops(seed: u64, n0: usize, count: usize) -> Vec<WalOp> {
    let mut rng = Pcg32::new(seed);
    let mut live: Vec<u32> = (0..n0 as u32).collect();
    let mut next = n0 as u32;
    let mut ops = Vec::with_capacity(count + 1);
    for _ in 0..count {
        match rng.gen_range(10) {
            0..=4 => {
                let vector: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
                ops.push(WalOp::Insert { vector });
                live.push(next);
                next += 1;
            }
            5..=6 if !live.is_empty() => {
                let at = rng.gen_range(live.len());
                ops.push(WalOp::Delete { key: live.swap_remove(at) });
            }
            7 => {
                let frac = (rng.gen_range(9) + 1) as f64 / 10.0;
                ops.push(WalOp::SetThreshold { frac });
            }
            _ => ops.push(WalOp::Compact),
        }
    }
    // Splicing a threshold change shifts no ids, so the schedule stays
    // valid; 0.5 != the 0.3 default, so `save()` must re-log it.
    ops.insert(count.min(5), WalOp::SetThreshold { frac: 0.5 });
    ops
}

/// Apply an op directly (the uninterrupted control run).
fn apply_plain(m: &mut dyn MutableAnnIndex, ctx: &mut SearchContext, op: &WalOp) {
    match op {
        WalOp::Insert { vector } => {
            m.insert(vector, ctx).expect("insert");
        }
        WalOp::Delete { key } => m.remove(*key).expect("remove live id"),
        WalOp::Compact => {
            m.compact(ctx).expect("compact");
        }
        WalOp::SetThreshold { frac } => m.set_compact_threshold(*frac),
    }
}

/// The protocol request that produces `op` on a serving primary.
fn op_request(id: u64, op: &WalOp) -> Request {
    match op {
        WalOp::Insert { vector } => Request::Insert { id, vector: vector.clone() },
        WalOp::Delete { key } => Request::Delete { id, key: *key },
        WalOp::Compact => Request::Compact { id },
        WalOp::SetThreshold { frac } => Request::SetThreshold { id, frac: *frac },
    }
}

/// An in-process primary: index + WAL + replication hub, no TCP query
/// listener (tests drive `ServeIndex::mutate` directly).
fn start_primary(
    family: &str,
    data: &Arc<Matrix>,
    dir: &std::path::Path,
    level: AckLevel,
    expect: usize,
    ack_timeout: Duration,
) -> (Arc<ServeIndex>, Arc<ReplHub>) {
    let index = build_family(family, data);
    let wal =
        Arc::new(Wal::bootstrap(dir, index.as_ref(), FsyncPolicy::EveryN(3)).expect("bootstrap"));
    let hub = ReplHub::start(
        "127.0.0.1:0",
        Arc::clone(&wal),
        HubOpts { level, expect, ack_timeout, ..HubOpts::default() },
    )
    .expect("bind repl hub");
    let primary = Arc::new(
        ServeIndex::with_params(index, SearchParams::new(10))
            .with_wal(wal)
            .with_repl(Arc::clone(&hub)),
    );
    (primary, hub)
}

/// A fresh replica-side `ServeIndex` (placeholder index until the stream
/// installs real state).
fn replica_serve() -> Arc<ServeIndex> {
    let placeholder: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(Matrix::zeros(0, 1))));
    Arc::new(ServeIndex::with_params(placeholder, SearchParams::new(10)).as_replica())
}

fn replica_opts(dir: &std::path::Path) -> ReplicaOpts {
    ReplicaOpts {
        store: ReplicaStore::Dir(dir.to_path_buf()),
        policy: FsyncPolicy::Always,
        backoff_base: Duration::from_millis(20),
        ..ReplicaOpts::default()
    }
}

/// The replication wire format is pinned byte for byte: re-encoding the
/// canonical frame set must reproduce `fixtures/repl_frame_v1.bin`
/// exactly, and the fixture must parse back to the same frames.
#[test]
fn golden_fixture_pins_the_wire_encoding() {
    let frames = vec![
        Frame::Hello { last_seq: 7, need_snapshot: true },
        Frame::Hello { last_seq: 0, need_snapshot: false },
        Frame::Snapshot { snapshot_seq: 3, bundle: vec![0xDE, 0xAD, 0xBE, 0xEF] },
        Frame::Snapshot { snapshot_seq: 0, bundle: Vec::new() },
        Frame::op(9, &WalOp::Insert { vector: vec![1.5, -2.0] }),
        Frame::op(10, &WalOp::SetThreshold { frac: 0.25 }),
        Frame::op(11, &WalOp::Delete { key: 42 }),
        Frame::op(12, &WalOp::Compact),
        Frame::Ack { seq: 12 },
        Frame::CaughtUp { seq: 12 },
    ];
    let mut wire = Vec::new();
    for f in &frames {
        wire.extend_from_slice(&f.encode());
    }
    let golden: &[u8] = include_bytes!("fixtures/repl_frame_v1.bin");
    assert_eq!(
        wire, golden,
        "replication wire encoding drifted from the v1 golden fixture"
    );
    let mut r = std::io::Cursor::new(golden);
    for want in &frames {
        let got = Frame::read_from(&mut r).expect("fixture frame").expect("not EOF");
        assert_eq!(&got, want);
    }
    assert_eq!(Frame::read_from(&mut r).unwrap(), None, "clean EOF after the fixture");
}

/// The acceptance property: for every mutable family, a replica that is
/// stopped mid-stream (while the primary keeps mutating and rotates its
/// log with a checkpoint) and restarted from its own durable state
/// converges to bytes identical to an uninterrupted control run — in
/// memory (fingerprint) and in its local WAL (offline recovery).
#[test]
fn prop_replica_converges_byte_identically_for_every_family() {
    for (fi, family) in FAMILIES.iter().enumerate() {
        let seed = 0x5EED ^ ((fi as u64) << 8);
        let ds = tiny(seed, N0, DIM, Metric::L2);
        let ops = gen_ops(seed ^ 1, N0, 30);
        let pdir = tmp_dir(&format!("ident_p_{family}"));
        let rdir = tmp_dir(&format!("ident_r_{family}"));

        // Uninterrupted control run: same ops, no WAL, no network.
        let mut control = build_family(family, &ds.data);
        {
            let mut ctx = SearchContext::new();
            let m = control.as_mutable().expect(family);
            for op in &ops {
                apply_plain(m, &mut ctx, op);
            }
        }

        let (primary, hub) =
            start_primary(family, &ds.data, &pdir, AckLevel::None, 1, Duration::from_secs(2));
        let mut rserve = replica_serve();
        let mut replica =
            Some(Replica::start(hub.local_addr(), Arc::clone(&rserve), replica_opts(&rdir))
                .expect("replica start"));

        for (i, op) in ops.iter().enumerate() {
            if i == 10 {
                // Replica goes away mid-stream; its durable position is
                // whatever it had committed.
                replica.take().unwrap().stop();
            }
            if i == 15 {
                // Checkpoint + rotation on the primary: the restarted
                // replica's position now predates the log base, forcing
                // the snapshot-reinstall catch-up path (and the
                // threshold re-log, since the 0.5 splice already ran).
                let resp = primary.mutate(&Request::Save { id: 0 }).expect("save");
                assert!(matches!(resp.outcome, MutOutcome::Saved(_)));
            }
            if i == 20 {
                rserve = replica_serve();
                replica = Some(Replica::start(
                    hub.local_addr(),
                    Arc::clone(&rserve),
                    replica_opts(&rdir),
                )
                .expect("replica restart"));
            }
            primary
                .mutate(&op_request(i as u64, op))
                .unwrap_or_else(|e| panic!("{family}: op {i} rejected: {e}"));
        }

        let last = primary.applied_seq();
        let rep = replica.take().unwrap();
        assert!(
            rep.wait_applied(last, Duration::from_secs(20)),
            "{family}: replica stalled at seq {} (want {last})",
            rep.applied()
        );

        let control_bytes = bundle_to_vec(control.as_ref()).expect("control bundle");
        let pfp = primary.fingerprint(0).expect("primary fingerprint");
        assert_eq!(
            pfp.fingerprint,
            fnv1a64(&control_bytes),
            "{family}: primary state != uninterrupted control run"
        );
        let rfp = rserve.fingerprint(0).expect("replica fingerprint");
        assert_eq!(rfp.fingerprint, pfp.fingerprint, "{family}: replica diverged from primary");
        assert_eq!(rfp.seq, last, "{family}: replica applied seq");

        rep.stop();
        hub.shutdown();

        // The replica's own durable state recovers offline to the same
        // bytes — acked-and-applied implies durable-and-identical.
        let (rrec, _rwal, rreport) =
            Wal::recover(&rdir, FsyncPolicy::Always).expect("replica offline recovery");
        assert!(rreport.corruption.is_none(), "{family}: {:?}", rreport.corruption);
        assert_eq!(rreport.last_seq, last, "{family}: replica durable seq");
        assert_eq!(
            bundle_to_vec(rrec.as_ref()).expect("recovered bundle"),
            control_bytes,
            "{family}: replica durable bytes != control run"
        );

        std::fs::remove_dir_all(&pdir).ok();
        std::fs::remove_dir_all(&rdir).ok();
    }
}

/// Level `all`: when the client ack returns, the op is already applied
/// and durable on every expected replica — no wait, no grace period.
#[test]
fn level_all_ack_means_the_replica_already_has_the_op() {
    let ds = tiny(1201, N0, DIM, Metric::L2);
    let pdir = tmp_dir("all_p");
    let rdir = tmp_dir("all_r");
    let (primary, hub) =
        start_primary("bruteforce", &ds.data, &pdir, AckLevel::All, 1, Duration::from_secs(10));
    let rserve = replica_serve();
    let replica = Replica::start(hub.local_addr(), Arc::clone(&rserve), replica_opts(&rdir))
        .expect("replica start");
    assert!(replica.wait_ready(Duration::from_secs(10)), "replica never caught up");

    let mut rng = Pcg32::new(7);
    for i in 0..5u64 {
        let vector: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
        primary.mutate(&Request::Insert { id: i, vector }).expect("acked insert");
        // The ack gate ran: the replica has applied and locally committed
        // this exact seq before mutate() returned.
        assert!(
            replica.applied() >= i + 1,
            "insert {i} acked at level all but replica is at {}",
            replica.applied()
        );
    }
    let pfp = primary.fingerprint(0).unwrap();
    let rfp = rserve.fingerprint(0).unwrap();
    assert_eq!(rfp.fingerprint, pfp.fingerprint, "synchronous divergence");
    assert_eq!(rfp.live, (N0 + 5) as u64);

    replica.stop();
    hub.shutdown();
    std::fs::remove_dir_all(&pdir).ok();
    std::fs::remove_dir_all(&rdir).ok();
}

/// Level `all` with no replica connected: the ack times out with a
/// structured error that states the op is applied and logged locally —
/// and it is.
#[test]
fn ack_timeout_is_structured_and_the_op_stays_local() {
    let ds = tiny(1301, N0, DIM, Metric::L2);
    let pdir = tmp_dir("timeout_p");
    let (primary, hub) = start_primary(
        "bruteforce",
        &ds.data,
        &pdir,
        AckLevel::All,
        1,
        Duration::from_millis(150),
    );
    let vector = vec![0.5f32; DIM];
    let err = primary
        .mutate(&Request::Insert { id: 0, vector })
        .expect_err("no replica is connected; level all must time out");
    assert!(err.contains("replication ack timeout"), "got: {err}");
    assert!(err.contains("applied and logged locally"), "got: {err}");
    // The ambiguity is one-sided: the op is durable on the primary.
    assert_eq!(primary.applied_seq(), 1);
    assert_eq!(primary.fingerprint(0).unwrap().live, (N0 + 1) as u64);

    hub.shutdown();
    std::fs::remove_dir_all(&pdir).ok();
}

/// Fault injection: the stream runs through a proxy that drops,
/// duplicates, delays, and truncates frames on a seeded budget. The
/// replica must never apply a torn or replayed record (it drops the
/// connection instead) and must converge byte-identically once the
/// budget is spent and the tail runs clean.
#[test]
fn faulted_stream_converges_byte_identically() {
    let ds = tiny(1401, N0, DIM, Metric::L2);
    let ops = gen_ops(1402, N0, 40);
    let pdir = tmp_dir("fault_p");
    let rdir = tmp_dir("fault_r");
    let (primary, hub) =
        start_primary("bruteforce", &ds.data, &pdir, AckLevel::None, 1, Duration::from_secs(2));
    // Every one of the first 8 downstream frames draws a fault, then the
    // plan is spent and the stream runs clean forever.
    let proxy = FaultProxy::start(hub.local_addr(), FaultPlan::new(0xFA17, 100, 8))
        .expect("proxy start");
    let rserve = replica_serve();
    let replica = Replica::start(proxy.local_addr, Arc::clone(&rserve), replica_opts(&rdir))
        .expect("replica start");

    for (i, op) in ops.iter().enumerate() {
        primary
            .mutate(&op_request(i as u64, op))
            .unwrap_or_else(|e| panic!("op {i} rejected: {e}"));
    }
    let last = primary.applied_seq();
    assert!(
        replica.wait_applied(last, Duration::from_secs(30)),
        "replica stalled at {} (want {last}) after {} fault(s), {} violation(s), {} reconnect(s)",
        replica.applied(),
        proxy.injected(),
        replica.violations(),
        replica.reconnects()
    );
    assert!(proxy.injected() > 0, "the fault plan never fired");

    let pfp = primary.fingerprint(0).unwrap();
    let rfp = rserve.fingerprint(0).unwrap();
    assert_eq!(
        rfp.fingerprint, pfp.fingerprint,
        "replica diverged under faults ({} injected, {} violation(s), {} reconnect(s))",
        proxy.injected(),
        replica.violations(),
        replica.reconnects()
    );

    // Shutdown order matters: stop the replica (its conn socket is shut
    // down), then the hub (unblocks the proxy's upstream read), then the
    // proxy's accept loop.
    replica.stop();
    hub.shutdown();
    proxy.stop();
    std::fs::remove_dir_all(&pdir).ok();
    std::fs::remove_dir_all(&rdir).ok();
}

/// Kills the child process on every exit path so a failing assert does
/// not leak a serving `finger` process.
struct KillOnDrop(std::process::Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        self.0.kill().ok();
        self.0.wait().ok();
    }
}

/// Read the child's stdout until `pick` matches a line, returning the
/// match. Panics (with everything read so far) if the child closes
/// stdout first.
fn scan_stdout<T>(
    lines: &mut std::io::Lines<std::io::BufReader<std::process::ChildStdout>>,
    what: &str,
    pick: impl Fn(&str) -> Option<T>,
) -> T {
    let mut seen = String::new();
    for line in lines.by_ref() {
        let line = line.expect("read child stdout");
        seen.push_str(&line);
        seen.push('\n');
        if let Some(v) = pick(&line) {
            return v;
        }
    }
    panic!("child exited before printing {what}; stdout so far:\n{seen}");
}

fn addr_after_on(line: &str) -> Option<std::net::SocketAddr> {
    line.split(" on ").nth(1)?.split_whitespace().next()?.parse().ok()
}

/// Process-level smoke: a primary serving with `--repl-listen --ack-level
/// all` and a replica process with `--replica-of --fsync-policy always`.
/// Inserts acked by the primary are durable on the replica by definition
/// of level `all`; SIGKILL the primary and every acked vector must be
/// readable (distance ~0 at k=1) from the surviving replica.
#[test]
fn kill_the_primary_and_read_acked_ops_from_the_replica() {
    use std::io::BufRead as _;
    use std::process::{Command, Stdio};

    let root = tmp_dir("smoke");
    std::fs::create_dir_all(&root).unwrap();
    let p_wal = root.join("p_wal");
    let r_wal = root.join("r_wal");
    let bundle = root.join("seed.idx");

    let ds = tiny(1501, 40, DIM, Metric::L2);
    save_index(&bundle, &BruteForce::new(Arc::clone(&ds.data))).unwrap();

    let mut primary = Command::new(env!("CARGO_BIN_EXE_finger"))
        .args([
            "serve",
            "--index",
            bundle.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "1",
            "--wal-dir",
            p_wal.to_str().unwrap(),
            "--fsync-policy",
            "always",
            "--repl-listen",
            "127.0.0.1:0",
            "--ack-level",
            "all",
            "--repl-expect",
            "1",
            "--repl-ack-timeout-ms",
            "20000",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn primary");
    let p_stdout = primary.stdout.take().expect("piped stdout");
    let primary = KillOnDrop(primary);
    let mut p_lines = std::io::BufReader::new(p_stdout).lines();
    // The replication banner prints before the serving banner.
    let repl_addr = scan_stdout(&mut p_lines, "the replication banner", |l| {
        l.starts_with("replication listener on ").then(|| addr_after_on(l)).flatten()
    });
    let query_addr = scan_stdout(&mut p_lines, "the serving banner", |l| {
        l.starts_with("serving ").then(|| addr_after_on(l)).flatten()
    });

    let mut replica = Command::new(env!("CARGO_BIN_EXE_finger"))
        .args([
            "serve",
            "--replica-of",
            &repl_addr.to_string(),
            "--addr",
            "127.0.0.1:0",
            "--workers",
            "1",
            "--wal-dir",
            r_wal.to_str().unwrap(),
            "--fsync-policy",
            "always",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn replica");
    let r_stdout = replica.stdout.take().expect("piped stdout");
    let _replica = KillOnDrop(replica);
    let mut r_lines = std::io::BufReader::new(r_stdout).lines();
    let replica_addr = scan_stdout(&mut r_lines, "the replica banner", |l| {
        l.starts_with("serving replica").then(|| addr_after_on(l)).flatten()
    });

    // Acked at level all: durable on the replica before each ack.
    let mut client = Client::connect(&query_addr).expect("connect primary");
    let mut rng = Pcg32::new(9);
    let mut acked: Vec<Vec<f32>> = Vec::new();
    for i in 0..8u64 {
        let vector: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
        let resp = client
            .mutate(&Request::Insert { id: i, vector: vector.clone() })
            .expect("insert acked at level all");
        assert!(matches!(resp.outcome, MutOutcome::Inserted(_)));
        acked.push(vector);
    }

    // SIGKILL the primary. Level-all acks mean nothing above may be lost.
    drop(client);
    drop(primary);

    let mut rclient = Client::connect(&replica_addr).expect("connect replica");
    for (i, vector) in acked.iter().enumerate() {
        let resp = rclient
            .query(&finger_ann::router::protocol::QueryRequest {
                id: i as u64,
                vector: vector.clone(),
                k: 1,
            })
            .expect("replica serves reads after the primary dies");
        let (dist, _key) = resp.hits.first().copied().expect("one hit");
        assert!(
            dist.abs() < 1e-5,
            "acked insert {i} is not on the replica (nearest dist {dist})"
        );
    }
    // The replica's state hash covers the seed rows plus every acked op.
    let line = rclient
        .send_raw(&Request::Fingerprint { id: 0 }.to_json_line())
        .expect("fingerprint verb");
    let info = FingerprintInfo::parse(&line).expect("fingerprint response");
    assert_eq!(info.live, 40 + 8, "replica live count");
    assert_eq!(info.seq, 8, "replica applied seq");

    // Writes must be refused with a pointer to the primary.
    let err = rclient
        .mutate(&Request::Insert { id: 99, vector: vec![0.0; DIM] })
        .expect_err("replica is read-only");
    assert!(err.contains("read-only"), "got: {err}");

    std::fs::remove_dir_all(&root).ok();
}
