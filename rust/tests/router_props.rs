//! Property tests on the serving coordinator's invariants (DESIGN.md §6):
//! exactly-once delivery, bounded batches, FIFO within a window, and
//! backpressure behavior — run over randomized schedules via the in-tree
//! property harness (no proptest in the offline environment).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use finger_ann::core::distance::Metric;
use finger_ann::core::rng::Pcg32;
use finger_ann::data::synth::tiny;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::HnswIndex;
use finger_ann::router::batcher::{Batcher, SubmitError};
use finger_ann::router::{
    Client, MutOutcome, QueryRequest, Request, ServeIndex, Server, ServerConfig,
};
use finger_ann::testutil::forall;

#[test]
fn prop_every_request_in_exactly_one_batch() {
    forall("exactly-once delivery", 10, |rng: &mut Pcg32| {
        let max_batch = 1 + rng.gen_range(8);
        let n_items = 50 + rng.gen_range(200);
        let b: Arc<Batcher<u64>> = Arc::new(Batcher::new(
            max_batch,
            Duration::from_micros(200),
            10_000,
        ));
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n_items as u64 {
                    b.submit(i).unwrap();
                    if i % 7 == 0 {
                        std::thread::yield_now();
                    }
                }
                b.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= max_batch, "batch size bound violated");
            seen.extend(batch);
        }
        producer.join().unwrap();
        seen.sort_unstable();
        let expect: Vec<u64> = (0..n_items as u64).collect();
        seen == expect
    });
}

#[test]
fn prop_fifo_order_single_producer() {
    forall("FIFO within single producer", 10, |rng: &mut Pcg32| {
        let max_batch = 1 + rng.gen_range(6);
        let n = 100 + rng.gen_range(100);
        let b: Batcher<u64> = Batcher::new(max_batch, Duration::from_micros(100), 10_000);
        for i in 0..n as u64 {
            b.submit(i).unwrap();
        }
        b.close();
        let mut last = None;
        while let Some(batch) = b.next_batch() {
            for x in batch {
                if let Some(prev) = last {
                    assert!(x > prev, "out of order: {x} after {prev}");
                }
                last = Some(x);
            }
        }
        last == Some(n as u64 - 1)
    });
}

#[test]
fn prop_backpressure_rejects_never_loses() {
    forall("backpressure accounting", 8, |rng: &mut Pcg32| {
        let cap = 4 + rng.gen_range(12);
        let b: Arc<Batcher<u64>> = Arc::new(Batcher::new(cap, Duration::from_millis(50), cap));
        let accepted = Arc::new(AtomicU64::new(0));
        let rejected = Arc::new(AtomicU64::new(0));
        let mut producers = Vec::new();
        for t in 0..3u64 {
            let b = Arc::clone(&b);
            let accepted = Arc::clone(&accepted);
            let rejected = Arc::clone(&rejected);
            producers.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    match b.submit(t * 1000 + i) {
                        Ok(()) => {
                            accepted.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(SubmitError::Full) => {
                            rejected.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(SubmitError::Closed) => unreachable!(),
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut count = 0u64;
                while let Some(batch) = b.next_batch() {
                    count += batch.len() as u64;
                    std::thread::sleep(Duration::from_micros(50));
                }
                count
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        b.close();
        let delivered = consumer.join().unwrap();
        // Conservation: accepted == delivered, accepted + rejected == offered.
        let acc = accepted.load(Ordering::SeqCst);
        let rej = rejected.load(Ordering::SeqCst);
        assert_eq!(acc + rej, 300, "offered requests accounted");
        delivered == acc
    });
}

/// A deterministic client interleaves INSERT/DELETE verbs with search
/// requests over one TCP connection while a background thread keeps the
/// worker pool busy with search batches: no search response issued after
/// a delete acknowledgement may ever contain that deleted id, inserted
/// ids follow the watermark exactly, and malformed mutation frames get
/// structured in-band errors — the connection is never dropped.
#[test]
fn mutation_verbs_interleave_with_search_batches() {
    let ds = tiny(310, 150, 8, Metric::L2);
    let idx = HnswIndex::build(
        Arc::clone(&ds.data),
        HnswParams { m: 12, ef_construction: 80, ..Default::default() },
    );
    let serve = Arc::new(ServeIndex::new(Box::new(idx), 256));
    let server = Arc::new(
        Server::start(
            Arc::clone(&serve),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers: 2,
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                max_queue: 1024,
                use_pjrt_rerank: false,
                ..Default::default()
            },
            None,
        )
        .unwrap(),
    );

    // Concurrent search pressure through the batcher (not assertion-bearing
    // beyond well-formedness — it exists so mutations really do interleave
    // with in-flight search batches).
    let bg = {
        let server = Arc::clone(&server);
        let probes: Vec<Vec<f32>> = (0..8).map(|i| serve.row(i * 7)).collect();
        std::thread::spawn(move || {
            for round in 0..120u64 {
                let q = probes[(round % 8) as usize].clone();
                let rx = server
                    .submit_local(QueryRequest { id: 10_000 + round, vector: q, k: 10 })
                    .unwrap();
                let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                assert!(resp.hits.len() <= 10);
                assert!(!resp.hits.is_empty());
            }
        })
    };

    let mut client = Client::connect(&server.local_addr).unwrap();
    let mut rng = Pcg32::new(99);
    let mut live: Vec<u32> = (0..150u32).collect();
    let mut deleted: Vec<u32> = Vec::new();
    let mut next = 150u32;
    for step in 0..60u64 {
        match rng.gen_range(3) {
            0 => {
                let v: Vec<f32> = (0..8).map(|_| rng.next_gaussian()).collect();
                let ack = client.mutate(&Request::Insert { id: step, vector: v }).unwrap();
                assert_eq!(ack.outcome, MutOutcome::Inserted(next), "watermark order");
                assert_eq!(ack.live, live.len() as u64 + 1);
                live.push(next);
                next += 1;
            }
            1 if live.len() > 10 => {
                let victim = live.swap_remove(rng.gen_range(live.len()));
                let ack = client.mutate(&Request::Delete { id: step, key: victim }).unwrap();
                assert_eq!(ack.outcome, MutOutcome::Deleted(victim));
                deleted.push(victim);
            }
            _ => {
                let q: Vec<f32> = (0..8).map(|_| rng.next_gaussian()).collect();
                let resp = client.query(&QueryRequest { id: step, vector: q, k: 10 }).unwrap();
                for &(_, id) in &resp.hits {
                    assert!(
                        !deleted.contains(&id),
                        "step {step}: deleted id {id} in a search response"
                    );
                }
            }
        }
    }

    // Malformed mutation frames: structured error lines, same connection.
    for frame in [
        r#"{"id":1,"op":"insert"}"#,
        r#"{"id":2,"op":"insert","vector":[]}"#,
        r#"{"id":3,"op":"delete","key":"x"}"#,
        r#"{"id":4,"op":"warp"}"#,
        "not json at all",
    ] {
        let raw = client.send_raw(frame).unwrap();
        assert!(
            raw.contains("\"error\""),
            "malformed frame {frame:?} answered with {raw:?}"
        );
    }
    // ... and the stream still serves all verbs afterwards.
    let resp = client
        .query(&QueryRequest { id: 777, vector: serve.row(0), k: 1 })
        .unwrap();
    assert_eq!(resp.id, 777);
    let ack = client.mutate(&Request::Compact { id: 778 }).unwrap();
    assert!(matches!(ack.outcome, MutOutcome::Compacted(_)));

    bg.join().unwrap();
    Arc::try_unwrap(server).ok().unwrap().shutdown();
}

#[test]
fn prop_batch_never_mixes_after_close_drain() {
    // After close(), all remaining items must still drain in order.
    let b: Batcher<u32> = Batcher::new(3, Duration::from_secs(1), 100);
    for i in 0..10 {
        b.submit(i).unwrap();
    }
    b.close();
    let mut all = Vec::new();
    while let Some(batch) = b.next_batch() {
        all.extend(batch);
    }
    assert_eq!(all, (0..10).collect::<Vec<_>>());
}
