//! Property tests on the serving coordinator's invariants (DESIGN.md §6):
//! exactly-once delivery, bounded batches, FIFO within a window, and
//! backpressure behavior — run over randomized schedules via the in-tree
//! property harness (no proptest in the offline environment).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use finger_ann::core::rng::Pcg32;
use finger_ann::router::batcher::{Batcher, SubmitError};
use finger_ann::testutil::forall;

#[test]
fn prop_every_request_in_exactly_one_batch() {
    forall("exactly-once delivery", 10, |rng: &mut Pcg32| {
        let max_batch = 1 + rng.gen_range(8);
        let n_items = 50 + rng.gen_range(200);
        let b: Arc<Batcher<u64>> = Arc::new(Batcher::new(
            max_batch,
            Duration::from_micros(200),
            10_000,
        ));
        let producer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                for i in 0..n_items as u64 {
                    b.submit(i).unwrap();
                    if i % 7 == 0 {
                        std::thread::yield_now();
                    }
                }
                b.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= max_batch, "batch size bound violated");
            seen.extend(batch);
        }
        producer.join().unwrap();
        seen.sort_unstable();
        let expect: Vec<u64> = (0..n_items as u64).collect();
        seen == expect
    });
}

#[test]
fn prop_fifo_order_single_producer() {
    forall("FIFO within single producer", 10, |rng: &mut Pcg32| {
        let max_batch = 1 + rng.gen_range(6);
        let n = 100 + rng.gen_range(100);
        let b: Batcher<u64> = Batcher::new(max_batch, Duration::from_micros(100), 10_000);
        for i in 0..n as u64 {
            b.submit(i).unwrap();
        }
        b.close();
        let mut last = None;
        while let Some(batch) = b.next_batch() {
            for x in batch {
                if let Some(prev) = last {
                    assert!(x > prev, "out of order: {x} after {prev}");
                }
                last = Some(x);
            }
        }
        last == Some(n as u64 - 1)
    });
}

#[test]
fn prop_backpressure_rejects_never_loses() {
    forall("backpressure accounting", 8, |rng: &mut Pcg32| {
        let cap = 4 + rng.gen_range(12);
        let b: Arc<Batcher<u64>> = Arc::new(Batcher::new(cap, Duration::from_millis(50), cap));
        let accepted = Arc::new(AtomicU64::new(0));
        let rejected = Arc::new(AtomicU64::new(0));
        let mut producers = Vec::new();
        for t in 0..3u64 {
            let b = Arc::clone(&b);
            let accepted = Arc::clone(&accepted);
            let rejected = Arc::clone(&rejected);
            producers.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    match b.submit(t * 1000 + i) {
                        Ok(()) => {
                            accepted.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(SubmitError::Full) => {
                            rejected.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(SubmitError::Closed) => unreachable!(),
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || {
                let mut count = 0u64;
                while let Some(batch) = b.next_batch() {
                    count += batch.len() as u64;
                    std::thread::sleep(Duration::from_micros(50));
                }
                count
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        b.close();
        let delivered = consumer.join().unwrap();
        // Conservation: accepted == delivered, accepted + rejected == offered.
        let acc = accepted.load(Ordering::SeqCst);
        let rej = rejected.load(Ordering::SeqCst);
        assert_eq!(acc + rej, 300, "offered requests accounted");
        delivered == acc
    });
}

#[test]
fn prop_batch_never_mixes_after_close_drain() {
    // After close(), all remaining items must still drain in order.
    let b: Batcher<u32> = Batcher::new(3, Duration::from_secs(1), 100);
    for i in 0..10 {
        b.submit(i).unwrap();
    }
    b.close();
    let mut all = Vec::new();
    while let Some(batch) = b.next_batch() {
        all.extend(batch);
    }
    assert_eq!(all, (0..10).collect::<Vec<_>>());
}
