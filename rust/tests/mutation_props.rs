//! Deterministic mutation harness: for seeded random interleavings of
//! insert / remove / compact over every mutable family (flat and
//! sharded), top-k over the live set must EQUAL brute force over the live
//! set — ties included — deleted ids must never be emitted, and the same
//! seed must yield bitwise-identical result streams across two runs.
//!
//! The harness is sized so equality is a *guarantee*, not a recall bet:
//! with `m` chosen such that the base-layer capacity `2m` is at least
//! `n_max - 1` and `ef_construction >= n_max`, every HNSW insertion links
//! the new node to every existing node (the selection heuristic backfills
//! to capacity), so layer 0 stays a complete graph through any
//! interleaving; with the query beam width at least the universe size the
//! top queue never fills, screening never activates, and the (filtered)
//! beam search degenerates to an exact scan over the live component —
//! which is the whole live set.

use std::sync::Arc;

use finger_ann::core::distance::{l2_sq, Metric};
use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::data::persist::{load_index, save_index};
use finger_ann::data::synth::tiny;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::graph::search::Neighbor;
use finger_ann::index::impls::{BruteForce, FingerHnswIndex, HnswIndex, VamanaIndex};
use finger_ann::index::sharded::{ShardSpec, ShardedIndex};
use finger_ann::index::{AnnIndex, MutableAnnIndex, MutateError, SearchContext, SearchParams};
use finger_ann::quant::{Precision, QuantTier};
use finger_ann::testutil::forall;

/// Initial corpus size; ops can add at most `MAX_OPS` more points, so the
/// universe never exceeds `N0 + MAX_OPS`.
const N0: usize = 24;
const MAX_OPS: usize = 40;
const DIM: usize = 6;
const K: usize = 5;

/// Base-layer capacity `2m = 64 >= N0 + MAX_OPS - 1`: the graph stays
/// complete (see module docs), making brute-force equality exact.
fn graph_params() -> HnswParams {
    HnswParams { m: 32, ef_construction: 128, ..Default::default() }
}

fn query_params() -> SearchParams {
    SearchParams::new(K).with_ef(4096)
}

// The quantized families join the exact oracle because the harness beam
// (`ef = 4096`) exceeds the universe: the approximate traversal returns
// the complete live pool, and the full-pool exact re-rank then orders it
// identically to brute force — quantization error cannot surface.
const FAMILIES: &[&str] = &[
    "bruteforce",
    "hnsw",
    "hnsw-finger",
    "bruteforce-sq8",
    "hnsw-sq8",
    "sharded-bruteforce",
    "sharded-hnsw",
];

fn build_family(name: &str, data: &Arc<Matrix>) -> Box<dyn AnnIndex> {
    let spec = ShardSpec { n_shards: 3, ..Default::default() };
    match name {
        "bruteforce" => Box::new(BruteForce::new(Arc::clone(data))),
        "hnsw" => Box::new(HnswIndex::build(Arc::clone(data), graph_params())),
        "hnsw-finger" => Box::new(FingerHnswIndex::build(
            Arc::clone(data),
            graph_params(),
            FingerParams { rank: 4, ..Default::default() },
        )),
        "bruteforce-sq8" => {
            Box::new(BruteForce::with_precision(Arc::clone(data), Precision::Sq8))
        }
        "hnsw-sq8" => Box::new(HnswIndex::build_with_precision(
            Arc::clone(data),
            graph_params(),
            Precision::Sq8,
        )),
        "sharded-bruteforce" => Box::new(ShardedIndex::build(
            Arc::clone(data),
            &spec,
            |sub| -> Box<dyn AnnIndex> { Box::new(BruteForce::new(sub)) },
        )),
        "sharded-hnsw" => Box::new(ShardedIndex::build(
            Arc::clone(data),
            &spec,
            |sub| -> Box<dyn AnnIndex> { Box::new(HnswIndex::build(sub, graph_params())) },
        )),
        other => panic!("unknown family {other}"),
    }
}

/// The oracle: live (external id, vector) pairs, exact top-k by
/// `(distance, id)` with the same `l2_sq` the indexes use — so distances
/// are bitwise comparable and ties break identically.
struct Mirror {
    live: Vec<(u32, Vec<f32>)>,
}

impl Mirror {
    fn topk(&self, q: &[f32], k: usize) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = self
            .live
            .iter()
            .map(|(id, v)| Neighbor { dist: l2_sq(q, v), id: *id })
            .collect();
        all.sort();
        all.truncate(k);
        all
    }
}

/// Run one seeded interleaving against `index`, checking every query
/// checkpoint against the mirror when `check` is set. Returns the stream
/// of all emitted result lists (for the determinism property).
fn run_episode(
    index: &mut dyn MutableAnnIndex,
    base: &Matrix,
    seed: u64,
    check: bool,
) -> Vec<Vec<Neighbor>> {
    index.set_compact_threshold(0.25);
    let mut rng = Pcg32::new(seed ^ 0xC0FFEE);
    let mut mirror = Mirror {
        live: (0..N0).map(|i| (i as u32, base.row(i).to_vec())).collect(),
    };
    let mut next_id = N0 as u32;
    let mut deleted: Vec<u32> = Vec::new();
    let mut ctx = SearchContext::new();
    let params = query_params();
    let mut stream: Vec<Vec<Neighbor>> = Vec::new();

    for _ in 0..MAX_OPS {
        match rng.gen_range(100) {
            // 40%: insert a fresh gaussian vector.
            0..=39 => {
                let v: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
                let id = index.insert(&v, &mut ctx).expect("insert");
                assert_eq!(id, next_id, "watermark is monotone and gapless");
                next_id += 1;
                mirror.live.push((id, v));
            }
            // 25%: remove a random live id.
            40..=64 => {
                if mirror.live.is_empty() {
                    assert!(index.remove(next_id).is_err());
                    continue;
                }
                let at = rng.gen_range(mirror.live.len());
                let (victim, _) = mirror.live.swap_remove(at);
                index.remove(victim).expect("remove live id");
                deleted.push(victim);
                // Double-delete must be a structured error, not a panic.
                assert!(matches!(
                    index.remove(victim),
                    Err(MutateError::AlreadyDeleted(_)) | Err(MutateError::UnknownId(_))
                ));
            }
            // 10%: compaction (threshold-gated; ids must survive).
            65..=74 => {
                index.compact(&mut ctx).expect("compact");
            }
            // 25%: query checkpoint.
            _ => {
                let q: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian()).collect();
                let got = index.search(&q, &params, &mut ctx);
                if check {
                    let want = mirror.topk(&q, K);
                    assert_eq!(got, want, "live top-{K} != brute force over live set");
                    assert!(
                        got.iter().all(|n| !deleted.contains(&n.id)),
                        "deleted id emitted"
                    );
                }
                stream.push(got);
            }
        }
        if check {
            assert_eq!(index.live_len(), mirror.live.len());
        }
    }

    // Final checkpoint from fixed probes so every episode ends verified.
    for probe in 0..3 {
        let q: Vec<f32> = (0..DIM).map(|d| (probe * DIM + d) as f32 * 0.37 - 2.0).collect();
        let got = index.search(&q, &params, &mut ctx);
        if check {
            assert_eq!(got, mirror.topk(&q, K), "final probe {probe}");
        }
        stream.push(got);
    }
    stream
}

#[test]
fn prop_search_over_live_set_equals_brute_force() {
    for family in FAMILIES {
        forall(&format!("live-set exactness [{family}]"), 5, |rng: &mut Pcg32| {
            let seed = rng.next_u64();
            let ds = tiny(seed ^ 0xA5, N0, DIM, Metric::L2);
            let mut index = build_family(family, &ds.data);
            run_episode(index.as_mutable().expect(family), &ds.data, seed, true);
            true
        });
    }
}

#[test]
fn prop_same_seed_yields_identical_result_streams() {
    for family in FAMILIES {
        forall(&format!("determinism [{family}]"), 3, |rng: &mut Pcg32| {
            let seed = rng.next_u64();
            let ds = tiny(seed ^ 0x5A, N0, DIM, Metric::L2);
            let mut a = build_family(family, &ds.data);
            let mut b = build_family(family, &ds.data);
            let sa = run_episode(a.as_mutable().unwrap(), &ds.data, seed, false);
            let sb = run_episode(b.as_mutable().unwrap(), &ds.data, seed, false);
            // Neighbor equality goes through f32::total_cmp, so equal
            // streams are bitwise-identical distances and ids.
            sa == sb
        });
    }
}

#[test]
fn prop_roundtrip_preserves_tombstones_and_watermark() {
    for family in FAMILIES {
        forall(&format!("bundle roundtrip [{family}]"), 3, |rng: &mut Pcg32| {
            let seed = rng.next_u64();
            let ds = tiny(seed ^ 0x3C, N0, DIM, Metric::L2);
            let mut index = build_family(family, &ds.data);
            run_episode(index.as_mutable().unwrap(), &ds.data, seed, false);

            let path = std::env::temp_dir().join(format!(
                "finger_mutation_props_{}_{family}_{seed:x}.idx",
                std::process::id()
            ));
            save_index(&path, index.as_ref()).expect("save");
            let mut loaded = load_index(&path).expect("load");
            std::fs::remove_file(&path).ok();

            let orig = index.as_mutable().unwrap();
            let back = loaded.as_mutable().expect("family stays mutable after load");
            assert_eq!(back.live_len(), orig.live_len(), "{family}: live count");
            assert_eq!(back.live_ids(), orig.live_ids(), "{family}: live ids");
            assert_eq!(
                back.tombstone_fraction(),
                orig.tombstone_fraction(),
                "{family}: tombstone fraction"
            );

            let mut ctx = SearchContext::new();
            let params = query_params();
            for probe in 0..3 {
                let q: Vec<f32> =
                    (0..DIM).map(|d| (probe * DIM + d) as f32 * 0.23 - 1.5).collect();
                let a = orig.search(&q, &params, &mut ctx);
                let b = back.search(&q, &params, &mut ctx);
                assert_eq!(a, b, "{family}: probe {probe} diverges after reload");
            }

            // The watermark survives: the next insert allocates the same
            // id on both sides.
            let v = vec![0.5f32; DIM];
            let ia = orig.insert(&v, &mut ctx).unwrap();
            let ib = back.insert(&v, &mut ctx).unwrap();
            ia == ib
        });
    }
}

/// The freeze-discipline invariant behind the quantized tier: after any
/// interleaving of inserts, removes, and compactions, every stored code
/// row still equals the *frozen* codec's encoding of the matching data
/// row — inserts encode with the build-time codec, compaction gathers
/// surviving code rows verbatim, and nothing ever retrains.
#[test]
fn prop_sq8_codes_stay_in_lockstep_with_data() {
    forall("sq8 code lockstep", 5, |rng: &mut Pcg32| {
        let seed = rng.next_u64();
        let ds = tiny(seed ^ 0x99, N0, DIM, Metric::L2);
        let mut index =
            HnswIndex::build_with_precision(Arc::clone(&ds.data), graph_params(), Precision::Sq8);
        run_episode(index.as_mutable().expect("hnsw-sq8 is mutable"), &ds.data, seed, false);

        let Some(QuantTier::Sq8 { codec, store }) = index.quant() else {
            panic!("sq8 tier missing after mutation");
        };
        if store.rows() != index.data().rows() {
            return false;
        }
        for i in 0..store.rows() {
            if store.row_logical(i) != codec.encode(index.data().row(i)).as_slice() {
                return false;
            }
        }
        true
    });
}

#[test]
fn mutation_errors_are_structured_not_panics() {
    let ds = tiny(901, N0, DIM, Metric::L2);
    let mut ctx = SearchContext::new();
    for family in FAMILIES {
        let mut index = build_family(family, &ds.data);
        let m = index.as_mutable().expect(family);
        assert_eq!(
            m.insert(&[1.0, 2.0], &mut ctx),
            Err(MutateError::DimMismatch { got: 2, want: DIM }),
            "{family}"
        );
        assert_eq!(m.remove(9999), Err(MutateError::UnknownId(9999)), "{family}");
        m.remove(0).unwrap();
        assert_eq!(m.remove(0), Err(MutateError::AlreadyDeleted(0)), "{family}");
        assert!(!m.is_live(0));
        assert!(m.is_live(1));
        assert_eq!(m.live_len(), N0 - 1);
        assert_eq!(m.live_ids().len(), N0 - 1);
    }
}

#[test]
fn non_mutable_families_cleanly_report_unsupported() {
    let ds = tiny(902, 60, DIM, Metric::L2);
    let mut vamana = VamanaIndex::build(
        Arc::clone(&ds.data),
        finger_ann::graph::vamana::VamanaParams { r: 8, ..Default::default() },
    );
    assert!(vamana.as_mutable().is_none());
    assert!(vamana.as_mutable_view().is_none());
    // A sharded fleet with a non-mutable member refuses mutation as a whole.
    let spec = ShardSpec { n_shards: 2, ..Default::default() };
    let mut sharded = ShardedIndex::build(Arc::clone(&ds.data), &spec, |sub| -> Box<dyn AnnIndex> {
        Box::new(VamanaIndex::build(
            sub,
            finger_ann::graph::vamana::VamanaParams { r: 8, ..Default::default() },
        ))
    });
    assert!(sharded.as_mutable().is_none());
    assert!(sharded.as_mutable_view().is_none());
}
