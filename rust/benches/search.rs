//! End-to-end search benchmark: HNSW vs HNSW-FINGER per-query latency and
//! throughput at matched ef — the microbench behind Figures 5/8. Both
//! methods run through `&dyn AnnIndex` with one pooled `SearchContext`.
//!
//!   cargo bench --bench search

use std::sync::Arc;
use std::time::Instant;

use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::spec_by_name;
use finger_ann::eval::recall;
use finger_ann::finger::construct::{FingerIndex, FingerParams};
use finger_ann::finger::search::FingerHnsw;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::{FingerHnswIndex, HnswIndex};
use finger_ann::index::{AnnIndex, SearchContext, SearchParams, ShardSpec, ShardedIndex};

fn main() {
    for name in ["sift-sim-128", "gist-sim-960"] {
        let spec = spec_by_name(name, 0.15).unwrap();
        println!("\n=== {} (n={}, dim={}) ===", spec.name, spec.n, spec.dim);
        let ds = spec.generate();
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let hnsw = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 16, ef_construction: 120, ..Default::default() },
        );
        let rank = 16;
        let fidx = FingerIndex::build(
            &ds.data,
            &hnsw.graph.base,
            FingerParams { rank, ..Default::default() },
        );
        let fh = FingerHnswIndex::from_parts(
            Arc::clone(&ds.data),
            FingerHnsw { hnsw: hnsw.graph, index: fidx },
        );

        let mut ctx = SearchContext::for_universe(ds.data.rows()).with_stats();
        println!(
            "{:<14} {:>5} {:>10} {:>10} {:>12} {:>12}",
            "method", "ef", "recall@10", "QPS", "us/query", "dist calls"
        );
        for ef in [20usize, 40, 80, 160] {
            let params = SearchParams::new(10).with_ef(ef);
            for method in ["hnsw", "hnsw-finger"] {
                let index: &dyn AnnIndex = &fh;
                let search = |q: &[f32], ctx: &mut SearchContext| {
                    if method == "hnsw" {
                        fh.inner.hnsw.search(fh.store(), q, &params, ctx)
                    } else {
                        index.search(q, &params, ctx)
                    }
                };
                // Warmup
                for qi in 0..ds.queries.rows().min(8) {
                    search(ds.queries.row(qi), &mut ctx);
                }
                ctx.reset_stats();
                let mut rec = 0.0;
                let t0 = Instant::now();
                for qi in 0..ds.queries.rows() {
                    let res = search(ds.queries.row(qi), &mut ctx);
                    rec += recall(&res, &gt[qi]);
                }
                let secs = t0.elapsed().as_secs_f64();
                let nq = ds.queries.rows() as f64;
                let stats = ctx.take_stats();
                println!(
                    "{:<14} {:>5} {:>10.4} {:>10.0} {:>12.1} {:>12.0}",
                    method,
                    ef,
                    rec / nq,
                    nq / secs,
                    1e6 * secs / nq,
                    stats.dist_calls as f64 / nq
                );
            }
        }
    }
    sharded_vs_flat();
}

/// Sharded vs flat HNSW throughput at matched ef: the sequential
/// single-query scatter and the shard-parallel `batch_search` path (the
/// one the router's dynamic batcher drives).
fn sharded_vs_flat() {
    let spec = spec_by_name("sift-sim-128", 0.25).unwrap();
    println!(
        "\n=== sharded vs flat hnsw ({}, n={}, dim={}) ===",
        spec.name, spec.n, spec.dim
    );
    let ds = spec.generate();
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    let hnsw_params = HnswParams { m: 16, ef_construction: 120, ..Default::default() };

    let mut indexes: Vec<(String, Box<dyn AnnIndex>)> = vec![(
        "hnsw-flat".to_string(),
        Box::new(HnswIndex::build(Arc::clone(&ds.data), hnsw_params.clone())),
    )];
    for s in [2usize, 4, 8] {
        let t0 = Instant::now();
        let sharded = ShardedIndex::build(
            Arc::clone(&ds.data),
            &ShardSpec { n_shards: s, ..Default::default() },
            |sub| -> Box<dyn AnnIndex> {
                Box::new(HnswIndex::build(sub, hnsw_params.clone()))
            },
        );
        println!("  built {s} shards in {:.1}s", t0.elapsed().as_secs_f64());
        indexes.push((format!("hnsw-sharded-{s}x"), Box::new(sharded)));
    }

    let mut ctx = SearchContext::for_universe(ds.data.rows());
    println!(
        "{:<18} {:>5} {:>10} {:>13} {:>13}",
        "index", "ef", "recall@10", "QPS(single)", "QPS(batch)"
    );
    let nq = ds.queries.rows() as f64;
    for ef in [40usize, 80] {
        let params = SearchParams::new(10).with_ef(ef);
        for (label, index) in &indexes {
            let index = index.as_ref();
            for qi in 0..ds.queries.rows().min(8) {
                index.search(ds.queries.row(qi), &params, &mut ctx);
            }
            let t0 = Instant::now();
            let mut rec = 0.0;
            for qi in 0..ds.queries.rows() {
                let res = index.search(ds.queries.row(qi), &params, &mut ctx);
                rec += recall(&res, &gt[qi]);
            }
            let single_qps = nq / t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            let batched = index.batch_search(&ds.queries, &params, &mut ctx);
            let batch_qps = nq / t1.elapsed().as_secs_f64();
            assert_eq!(batched.len(), ds.queries.rows());
            println!(
                "{:<18} {:>5} {:>10.4} {:>13.0} {:>13.0}",
                label,
                ef,
                rec / nq,
                single_qps,
                batch_qps
            );
        }
    }
}
