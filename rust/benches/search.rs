//! End-to-end search benchmark: HNSW vs HNSW-FINGER per-query latency and
//! throughput at matched ef — the microbench behind Figures 5/8.
//!
//!   cargo bench --bench search

use std::time::Instant;

use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::spec_by_name;
use finger_ann::eval::recall;
use finger_ann::finger::construct::{FingerIndex, FingerParams};
use finger_ann::finger::search::FingerHnsw;
use finger_ann::graph::hnsw::{Hnsw, HnswParams};
use finger_ann::graph::search::SearchStats;
use finger_ann::graph::visited::VisitedSet;

fn main() {
    for name in ["sift-sim-128", "gist-sim-960"] {
        let spec = spec_by_name(name, 0.15).unwrap();
        println!("\n=== {} (n={}, dim={}) ===", spec.name, spec.n, spec.dim);
        let ds = spec.generate();
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let hnsw = Hnsw::build(&ds.data, HnswParams { m: 16, ef_construction: 120, ..Default::default() });
        let rank = if name.starts_with("gist") { 16 } else { 16 };
        let fidx = FingerIndex::build(&ds.data, &hnsw.base, FingerParams { rank, ..Default::default() });
        let fh = FingerHnsw { hnsw, index: fidx };

        let mut vis = VisitedSet::new(ds.data.rows());
        println!(
            "{:<14} {:>5} {:>10} {:>10} {:>12} {:>12}",
            "method", "ef", "recall@10", "QPS", "us/query", "dist calls"
        );
        for ef in [20usize, 40, 80, 160] {
            for method in ["hnsw", "hnsw-finger"] {
                // Warmup
                for qi in 0..ds.queries.rows().min(8) {
                    let q = ds.queries.row(qi);
                    if method == "hnsw" {
                        fh.hnsw.search(&ds.data, q, 10, ef, &mut vis, None);
                    } else {
                        fh.search(&ds.data, q, 10, ef, &mut vis, None);
                    }
                }
                let mut stats = SearchStats::default();
                let mut rec = 0.0;
                let t0 = Instant::now();
                for qi in 0..ds.queries.rows() {
                    let q = ds.queries.row(qi);
                    let res = if method == "hnsw" {
                        fh.hnsw.search(&ds.data, q, 10, ef, &mut vis, Some(&mut stats))
                    } else {
                        fh.search(&ds.data, q, 10, ef, &mut vis, Some(&mut stats))
                    };
                    rec += recall(&res, &gt[qi]);
                }
                let secs = t0.elapsed().as_secs_f64();
                let nq = ds.queries.rows() as f64;
                println!(
                    "{:<14} {:>5} {:>10.4} {:>10.0} {:>12.1} {:>12.0}",
                    method,
                    ef,
                    rec / nq,
                    nq / secs,
                    1e6 * secs / nq,
                    stats.dist_calls as f64 / nq
                );
            }
        }
    }
}
