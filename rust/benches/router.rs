//! Router/batcher benchmark: in-process request throughput and latency
//! through the dynamic batcher + worker pool (no TCP), at several offered
//! batch sizes — the serving-layer overhead budget.
//!
//!   cargo bench --bench router

use std::sync::Arc;
use std::time::{Duration, Instant};

use finger_ann::data::spec_by_name;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::index::impls::FingerHnswIndex;
use finger_ann::router::{QueryRequest, ServeIndex, Server, ServerConfig};

fn main() {
    let spec = spec_by_name("sift-sim-128", 0.1).unwrap();
    println!("dataset: {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();
    let fh = FingerHnswIndex::build(
        Arc::clone(&ds.data),
        HnswParams { m: 16, ef_construction: 100, ..Default::default() },
        FingerParams { rank: 16, ..Default::default() },
    );
    let queries = ds.queries.clone();
    let index = Arc::new(ServeIndex::new(Box::new(fh), 60));

    println!(
        "{:>8} {:>8} {:>10} {:>12} {:>12} {:>12}",
        "workers", "batch", "clients", "QPS", "p50 us", "p99 us"
    );
    for &(workers, max_batch) in &[(1usize, 1usize), (2, 4), (4, 8), (8, 16)] {
        let server = Server::start(
            Arc::clone(&index),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers,
                max_batch,
                max_wait: Duration::from_micros(100),
                max_queue: 8192,
                use_pjrt_rerank: false,
                ..Default::default()
            },
            None,
        )
        .unwrap();
        let server = Arc::new(server);
        let n_clients = 8;
        let rounds = 40;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..n_clients {
            let server = Arc::clone(&server);
            let queries = queries.clone();
            handles.push(std::thread::spawn(move || {
                let mut lats = Vec::new();
                for round in 0..rounds {
                    let qi = (c * rounds + round) % queries.rows();
                    let rx = server
                        .submit_local(QueryRequest {
                            id: (c * rounds + round) as u64,
                            vector: queries.row(qi).to_vec(),
                            k: 10,
                        })
                        .unwrap();
                    let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
                    lats.push(resp.latency_us);
                }
                lats
            }));
        }
        let mut lats: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_unstable();
        let total = lats.len();
        let pct = |p: f64| lats[((p / 100.0) * (total - 1) as f64) as usize];
        println!(
            "{:>8} {:>8} {:>10} {:>12.0} {:>12} {:>12}",
            workers,
            max_batch,
            n_clients,
            total as f64 / wall,
            pct(50.0),
            pct(99.0)
        );
        Arc::try_unwrap(server).ok().unwrap().shutdown();
    }
}
