//! Microbenchmarks of the distance kernels (harness=false: the offline
//! environment has no criterion; this prints median-of-runs ns/op).
//!
//!   cargo bench --bench distance

use std::time::Instant;

use finger_ann::core::distance::{dot, l2_sq, l2_sq_batch4};
use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::core::store::VectorStore;
use finger_ann::finger::approx::{approx_dist_sq, QueryCenter, QueryState};
use finger_ann::finger::construct::{FingerIndex, FingerParams};
use finger_ann::graph::hnsw::{Hnsw, HnswParams};

fn bench<F: FnMut() -> f32>(name: &str, iters: usize, mut f: F) {
    // Warmup + 5 timed reps; report the median.
    let mut sink = 0.0f32;
    for _ in 0..iters / 10 + 1 {
        sink += f();
    }
    let mut reps: Vec<f64> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                sink += f();
            }
            t0.elapsed().as_nanos() as f64 / iters as f64
        })
        .collect();
    reps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("{name:<40} {:>10.1} ns/op   (sink {sink:.1})", reps[2]);
}

fn main() {
    println!(
        "kernel backend: {} (set FINGER_KERNEL=scalar to force the fallback)",
        finger_ann::core::distance::kernel_backend().name()
    );
    let mut rng = Pcg32::new(1);
    for dim in [96usize, 128, 256, 784, 960] {
        let a: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        bench(&format!("l2_sq dim={dim}"), 100_000, || l2_sq(&a, &b));
        bench(&format!("dot   dim={dim}"), 100_000, || dot(&a, &b));
    }

    // Padded-store batched scoring: 4 rows per kernel pass, query loads
    // amortized. Reported per-call; divide by 4 for ns/dist.
    for dim in [128usize, 784] {
        let mut m = Matrix::zeros(0, dim);
        for _ in 0..256 {
            let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            m.push_row(&row);
        }
        let store = VectorStore::from_matrix(&m);
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let mut qp = Vec::new();
        store.pad_query(&q, &mut qp);
        let mut i = 0;
        bench(&format!("l2_sq_batch4 (4 rows) dim={dim}"), 50_000, || {
            i = (i + 4) % 252;
            let d = l2_sq_batch4(&qp, store.row(i), store.row(i + 1), store.row(i + 2), store.row(i + 3));
            d[0] + d[1] + d[2] + d[3]
        });
        let mut j = 0;
        bench(&format!("l2_sq padded row      dim={dim}"), 100_000, || {
            j = (j + 1) % 256;
            l2_sq(&qp, store.row(j))
        });
    }

    // FINGER approximate distance vs full distance at the paper's ranks.
    let dim = 128;
    let n = 2000;
    let mut data = Matrix::zeros(0, 0);
    for _ in 0..n {
        let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        data.push_row(&row);
    }
    let h = Hnsw::build(&data, HnswParams { m: 16, ef_construction: 80, ..Default::default() });
    let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
    for rank in [8usize, 16, 32] {
        let idx = FingerIndex::build(&data, &h.base, FingerParams { rank, ..Default::default() });
        let qs = QueryState::new(&idx, &q);
        let qc = QueryCenter::new(&idx, &qs, 0, l2_sq(&q, data.row(0)));
        let slots: Vec<usize> = (0..h.base.degree(0)).map(|j| h.base.edge_slot(0, j)).collect();
        let mut i = 0;
        bench(&format!("finger approx_dist_sq r={rank} (m={dim})"), 100_000, || {
            i = (i + 1) % slots.len();
            approx_dist_sq(&idx, &qc, slots[i])
        });
    }
    let d0 = data.row(0).to_vec();
    bench(&format!("exact l2 (m={dim}) for comparison"), 100_000, || l2_sq(&q, &d0));

    // QueryCenter setup amortized per expansion.
    let idx = FingerIndex::build(&data, &h.base, FingerParams { rank: 16, ..Default::default() });
    let qs = QueryState::new(&idx, &q);
    bench("QueryCenter::new r=16", 100_000, || {
        QueryCenter::new(&idx, &qs, 7, l2_sq(&q, data.row(7))).q_res_norm
    });
}
