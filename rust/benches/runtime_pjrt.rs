//! PJRT runtime benchmark: latency of executing the AOT-compiled
//! JAX/Pallas artifacts (rerank + score panels) from Rust.
//!
//!   make artifacts && cargo bench --bench runtime_pjrt

use std::time::Instant;

use finger_ann::core::matrix::Matrix;
use finger_ann::core::rng::Pcg32;
use finger_ann::runtime::{default_artifacts_dir, Engine};

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let engine = Engine::new(&dir).expect("engine");
    let mut rng = Pcg32::new(2);

    for (name, dim, cands) in [
        ("rerank_b4_c64_d32_k5", 32usize, 64usize),
        ("rerank_b8_c256_d128_k10", 128, 256),
        ("score_l2_b8_c256_d128", 128, 256),
    ] {
        let exe = engine.compile(name).expect("compile");
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..cands {
            let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let b = exe.spec.meta["batch"];
        let mut queries = Matrix::zeros(0, 0);
        for _ in 0..b {
            let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            queries.push_row(&row);
        }
        let ids: Vec<u32> = (0..cands as u32).collect();

        // Warmup
        for _ in 0..3 {
            if exe.spec.kind == "rerank" {
                exe.rerank(&data, &queries, &ids).unwrap();
            } else {
                exe.score_l2(&data, &queries, &ids).unwrap();
            }
        }
        let iters = 50;
        let t0 = Instant::now();
        for _ in 0..iters {
            if exe.spec.kind == "rerank" {
                exe.rerank(&data, &queries, &ids).unwrap();
            } else {
                exe.score_l2(&data, &queries, &ids).unwrap();
            }
        }
        let us = t0.elapsed().as_micros() as f64 / iters as f64;
        let pairs = (b * cands) as f64;
        println!(
            "{name:<28} {us:>10.1} us/exec  ({:.1} ns per query-candidate pair, batch={b})",
            us * 1000.0 / pairs
        );
    }
}
