//! `finger` — the launcher: dataset generation, index building, search,
//! serving, and the per-figure benchmark harnesses.
//!
//! Usage:
//!   finger gen-data   --dataset sift-sim-128 --scale 1.0 --out data/
//!   finger search     --dataset sift-sim-128 --method finger --ef 80
//!   finger serve      --dataset sift-sim-128 --addr 127.0.0.1:7771 [--rerank]
//!   finger bench      <figure1|figure2|figure3|figure4|figure5|figure6|
//!                      figure7|figure8|table1|rank-selection|all>
//!                     [--scale 1.0] [--out results/]
//!   finger info       # artifacts manifest summary

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use finger_ann::cli::Args;
use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::{io as dio, spec_by_name};
use finger_ann::eval::figures;
use finger_ann::finger::construct::FingerParams;
use finger_ann::finger::search::FingerHnsw;
use finger_ann::graph::hnsw::{Hnsw, HnswParams};
use finger_ann::graph::visited::VisitedSet;
use finger_ann::router::{IndexKind, ServeIndex, Server, ServerConfig};
use finger_ann::runtime::{default_artifacts_dir, service::RerankService, Manifest};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "gen-data" => gen_data(&args),
        "build" => build(&args),
        "search" => search(&args),
        "serve" => serve(&args),
        "bench" => bench(&args),
        "info" => info(),
        _ => help(),
    }
}

fn help() {
    println!(
        "finger — FINGER (WWW 2023) reproduction\n\
         commands:\n\
         \u{20}  gen-data --dataset NAME [--scale F] [--out DIR]\n\
         \u{20}  build    --dataset NAME [--scale F] [--rank R] [--out index.bin]\n\
         \u{20}  search   --dataset NAME [--scale F] [--method hnsw|finger] [--ef N] [--k N]\n\
         \u{20}  serve    --dataset NAME [--scale F] [--addr A] [--workers N] [--rerank]\n\
         \u{20}  bench    FIGURE [--scale F] [--out DIR]   (figure1..figure8, table1, rank-selection, all)\n\
         \u{20}  info"
    );
}

fn dataset_from_args(args: &Args) -> finger_ann::data::Dataset {
    let name = args.get("dataset").unwrap_or("sift-sim-128");
    let scale = args.get_f64("scale", 0.25);
    let spec = spec_by_name(name, scale).unwrap_or_else(|| {
        eprintln!("unknown dataset '{name}'; known: fashion-sim-784 sift-sim-128 gist-sim-960 nytimes-sim-256 glove-sim-100 deep-sim-96");
        std::process::exit(2);
    });
    println!("generating {} (n={}, dim={})...", spec.name, spec.n, spec.dim);
    spec.generate()
}

fn gen_data(args: &Args) {
    let ds = dataset_from_args(args);
    let out = PathBuf::from(args.get("out").unwrap_or("data"));
    std::fs::create_dir_all(&out).expect("mkdir");
    dio::write_fvecs(&out.join(format!("{}.base.fvecs", ds.name)), &ds.data).unwrap();
    dio::write_fvecs(&out.join(format!("{}.query.fvecs", ds.name)), &ds.queries).unwrap();
    let gt = exact_knn(&ds.data, &ds.queries, 100);
    dio::write_ivecs(&out.join(format!("{}.gt.ivecs", ds.name)), &gt).unwrap();
    println!(
        "wrote {}.base/query.fvecs + gt.ivecs to {}",
        ds.name,
        out.display()
    );
}

/// Build an HNSW-FINGER index and persist it as a serving bundle.
fn build(args: &Args) {
    let ds = dataset_from_args(args);
    let rank = args.get_usize("rank", 16);
    let m = args.get_usize("M", 16);
    let out = PathBuf::from(args.get("out").unwrap_or("index.bin"));
    let t0 = Instant::now();
    let fh = FingerHnsw::build(
        &ds.data,
        HnswParams { m, ef_construction: args.get_usize("efc", 120), ..Default::default() },
        FingerParams { rank, ..Default::default() },
    );
    println!(
        "built in {:.1}s ({:.1} MB, corr={:.3})",
        t0.elapsed().as_secs_f64(),
        fh.nbytes() as f64 / 1e6,
        fh.index.matching.correlation
    );
    finger_ann::data::persist::save_bundle(&out, &ds.data, &fh).expect("save bundle");
    println!("saved bundle to {}", out.display());
}

fn search(args: &Args) {
    let ds = dataset_from_args(args);
    let method = args.get("method").unwrap_or("finger");
    let ef = args.get_usize("ef", 80);
    let k = args.get_usize("k", 10);
    let m = args.get_usize("M", 16);

    println!("building {method} index...");
    let t0 = Instant::now();
    let hnsw = Hnsw::build(&ds.data, HnswParams { m, ef_construction: 120, ..Default::default() });
    let gt = exact_knn(&ds.data, &ds.queries, k);

    let run = |search: &dyn Fn(&[f32], &mut VisitedSet) -> Vec<finger_ann::graph::Neighbor>| {
        let mut vis_local = VisitedSet::new(ds.data.rows());
        let t = Instant::now();
        let mut rec = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = search(ds.queries.row(qi), &mut vis_local);
            rec += finger_ann::eval::recall(&res, &gt[qi]);
        }
        let secs = t.elapsed().as_secs_f64();
        (
            rec / ds.queries.rows() as f64,
            ds.queries.rows() as f64 / secs,
        )
    };

    match method {
        "hnsw" => {
            println!("built in {:.1}s", t0.elapsed().as_secs_f64());
            let (rec, qps) = run(&|q, vis| hnsw.search(&ds.data, q, k, ef, vis, None));
            println!("hnsw: recall@{k}={rec:.4} QPS={qps:.0} (ef={ef})");
        }
        "finger" => {
            let rank = args.get_usize("rank", 16);
            let fidx = finger_ann::finger::construct::FingerIndex::build(
                &ds.data,
                &hnsw.base,
                FingerParams { rank, ..Default::default() },
            );
            println!(
                "built in {:.1}s (finger corr={:.3})",
                t0.elapsed().as_secs_f64(),
                fidx.matching.correlation
            );
            let fh = FingerHnsw { hnsw, index: fidx };
            let (rec, qps) = run(&|q, vis| fh.search(&ds.data, q, k, ef, vis, None));
            println!("hnsw-finger: recall@{k}={rec:.4} QPS={qps:.0} (ef={ef}, r={rank})");
        }
        other => {
            eprintln!("unknown method '{other}' (hnsw|finger)");
            std::process::exit(2);
        }
    }
}

fn serve(args: &Args) {
    // Either load a prebuilt bundle (`--index path`) or build in-process.
    let (data, fh) = if let Some(path) = args.get("index") {
        println!("loading bundle {path}...");
        finger_ann::data::persist::load_bundle(std::path::Path::new(path)).expect("load bundle")
    } else {
        let ds = dataset_from_args(args);
        let rank = args.get_usize("rank", 16);
        println!("building HNSW-FINGER index...");
        let fh = FingerHnsw::build(
            &ds.data,
            HnswParams { m: 16, ef_construction: 120, ..Default::default() },
            FingerParams { rank, ..Default::default() },
        );
        (ds.data, fh)
    };
    let dim = data.cols();
    let index = Arc::new(ServeIndex {
        data,
        kind: IndexKind::Finger(fh),
        ef_search: args.get_usize("ef", 80),
    });

    let rerank = if args.has_flag("rerank") {
        let data = Arc::new(index.data.clone());
        match RerankService::start(default_artifacts_dir(), dim, data) {
            Ok(svc) => {
                println!("PJRT rerank service up (panel width {})", svc.max_cands);
                Some(Arc::new(svc))
            }
            Err(e) => {
                eprintln!("rerank service unavailable ({e:#}); serving without");
                None
            }
        }
    } else {
        None
    };

    let config = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7771").to_string(),
        workers: args.get_usize("workers", 4),
        max_batch: args.get_usize("max-batch", 8),
        use_pjrt_rerank: rerank.is_some(),
        ..Default::default()
    };
    let server = Server::start(index, config.clone(), rerank).expect("bind");
    println!(
        "serving {}-dim index on {} ({} workers, max_batch {})",
        dim, server.local_addr, config.workers, config.max_batch
    );
    println!("protocol: one JSON per line: {{\"id\":1,\"vector\":[..],\"k\":10}}");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!("{}", server.metrics.summary());
    }
}

fn bench(args: &Args) {
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let scale = args.get_f64("scale", 0.25);
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    println!("benchmark scale={scale} out={}", out.display());
    let t0 = Instant::now();
    match what {
        // Figure 1 is the baseline subset of Figure 5; same harness.
        "figure1" | "figure5" => figures::figure5(&out, scale, false),
        "figure8" => figures::figure5(&out, scale, true),
        "figure2" => figures::figure2(&out, scale),
        "figure3" => figures::figure3(&out, scale),
        "figure4" => figures::figure4(&out, scale),
        "figure6" => figures::figure6(&out, scale),
        "figure7" => figures::figure7(&out, scale),
        "table1" => figures::table1(&out, scale),
        "rank-selection" => figures::rank_selection(&out, scale),
        "all" => {
            figures::figure2(&out, scale);
            figures::figure3(&out, scale);
            figures::figure4(&out, scale);
            figures::figure5(&out, scale, false);
            figures::figure6(&out, scale);
            figures::figure7(&out, scale);
            figures::figure5(&out, scale, true); // figure 8
            figures::table1(&out, scale);
            figures::rank_selection(&out, scale);
        }
        other => {
            eprintln!("unknown bench '{other}'");
            std::process::exit(2);
        }
    }
    println!("bench '{what}' finished in {:.1}s", t0.elapsed().as_secs_f64());
}

fn info() {
    let dir = default_artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts in {}:", dir.display());
            for (name, a) in &m.artifacts {
                println!(
                    "  {:<28} kind={:<9} inputs={} outputs={} meta={:?}",
                    name,
                    a.kind,
                    a.inputs.len(),
                    a.outputs.len(),
                    a.meta
                );
            }
        }
        Err(e) => {
            eprintln!("no artifacts at {} ({e:#}); run `make artifacts`", dir.display());
            std::process::exit(1);
        }
    }
}
