//! `finger` — the launcher: dataset generation, index building, search,
//! serving, and the per-figure benchmark harnesses.
//!
//! Every command that touches an index takes the same `--method` flag
//! (bruteforce | hnsw | finger | vamana | nndescent | ivfpq) and goes
//! through the unified `AnnIndex` trait. Adding `--shards S` (with
//! optional `--shard-strategy round-robin|kmeans` and
//! `--min-shard-frac F`) partitions the dataset and builds the chosen
//! method per shard behind a scatter-gather `ShardedIndex`.
//!
//! Usage:
//!   finger gen-data   --dataset sift-sim-128 --scale 1.0 --out data/
//!   finger build      --dataset sift-sim-128 --method finger --out index.bin
//!   finger search     --dataset sift-sim-128 --method vamana --ef 80
//!   finger serve      --dataset sift-sim-128 --method ivfpq --addr 127.0.0.1:7771
//!   finger serve      --index index.bin [--rerank]
//!   finger bench      <figure1|figure2|figure3|figure4|figure5|figure6|
//!                      figure7|figure8|table1|rank-selection|churn|
//!                      hotpath|all>
//!                     [--scale 1.0] [--out results/]
//!   finger info       # artifacts manifest summary

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use finger_ann::cli::Args;
use finger_ann::core::matrix::Matrix;
use finger_ann::data::groundtruth::exact_knn;
use finger_ann::data::persist::{load_index, save_index};
use finger_ann::data::{io as dio, spec_by_name};
use finger_ann::eval::figures;
use finger_ann::finger::construct::FingerParams;
use finger_ann::graph::hnsw::HnswParams;
use finger_ann::graph::nndescent::NnDescentParams;
use finger_ann::graph::vamana::VamanaParams;
use finger_ann::index::impls::{
    BruteForce, FingerHnswIndex, HnswIndex, IvfPqIndex, NnDescentIndex, VamanaIndex,
};
use finger_ann::index::{
    AnnIndex, SearchContext, SearchParams, ShardSpec, ShardStrategy, ShardedIndex,
};
use finger_ann::quant::ivfpq::IvfPqParams;
use finger_ann::quant::Precision;
use finger_ann::repl::cluster::{ClusterNode, ClusterOpts};
use finger_ann::repl::election::{ElectionConfig, ElectionNode, PeerSpec};
use finger_ann::repl::hub::{HubOpts, ReplHub};
use finger_ann::repl::replica::{Replica, ReplicaOpts, ReplicaStore};
use finger_ann::repl::{AckLevel, ReadPool};
use finger_ann::router::protocol::{FingerprintInfo, QueryRequest};
use finger_ann::router::{
    poll, Client, MutOutcome, MutResponse, Request, ServeIndex, ServeMode, Server, ServerConfig,
};
use finger_ann::runtime::{default_artifacts_dir, service::RerankService, Manifest};
use finger_ann::wal::{FsyncPolicy, ScanResult, Wal, WalOp};

const METHODS: &str = "bruteforce|hnsw|finger|vamana|nndescent|ivfpq";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "gen-data" => gen_data(&args),
        "build" => build(&args),
        "search" => search(&args),
        "serve" => serve(&args),
        "update" => update(&args),
        "delete" => delete(&args),
        "compact" => compact(&args),
        "set-threshold" => set_threshold(&args),
        "snapshot" => snapshot(&args),
        "query" => query_cmd(&args),
        "repl" => repl_cmd(&args),
        "wal" => wal_cmd(&args),
        "bench" => bench(&args),
        "info" => info(),
        _ => help(),
    }
}

fn help() {
    println!(
        "finger — FINGER (WWW 2023) reproduction\n\
         commands:\n\
         \u{20}  gen-data --dataset NAME [--scale F] [--out DIR]\n\
         \u{20}  build    --dataset NAME [--method {METHODS}] [--scale F] [--rank R] [--out index.bin]\n\
         \u{20}  search   --dataset NAME [--method {METHODS}] [--ef N] [--k N] [--nprobe N] [--patience N]\n\
         \u{20}  serve    --dataset NAME [--method {METHODS}] [--addr A] [--workers N] [--rerank]\n\
         \u{20}  serve    --index index.bin [--addr A] [--workers N] [--rerank]\n\
         \u{20}           [--serve-mode threads|epoll]  (default epoll on Linux: one event loop,\n\
         \u{20}                         fixed worker pool; threads = blocking fallback)\n\
         \u{20}  update   --vector \"v1,v2,...\" [--addr A]   (insert into a running server)\n\
         \u{20}  delete   --key ID [--addr A]               (tombstone a served point)\n\
         \u{20}  compact  [--addr A]                        (reclaim tombstones if over threshold)\n\
         \u{20}  set-threshold --frac F [--addr A]          (retune the compaction gate; logged + replicated)\n\
         \u{20}  snapshot [--addr A]                        (checkpoint a serving index via its WAL)\n\
         \u{20}  query    --vector \"v1,v2,...\" [--k N] [--addrs A,B,...]  (read fan-out across replicas)\n\
         \u{20}  repl     status [--addr A]                (role, term, applied seq, ack progress; any node)\n\
         \u{20}  repl     fingerprint --addrs A,B,...      (compare state hashes; exit 1 on divergence)\n\
         \u{20}  repl     leader --addrs A,B,...           (discover the elected leader; exit 1 if none)\n\
         \u{20}  wal      dump|truncate --wal-dir DIR      (inspect / repair a WAL directory)\n\
         \u{20}  bench    FIGURE [--scale F] [--out DIR]   (figure1..figure8, table1, rank-selection, churn, hotpath, router, repl, all)\n\
         \u{20}  info\n\
         durability (serve): --wal-dir DIR [--fsync-policy always|every_n:N|interval_ms:M|never]\n\
         \u{20}                         (log every mutation before ack; recover on restart)\n\
         replication (serve): primary: --repl-listen ADDR [--ack-level none|one|all|quorum]\n\
         \u{20}                         [--repl-expect N] [--repl-ack-timeout-ms M]  (requires --wal-dir)\n\
         \u{20}               replica: --replica-of ADDR [--wal-dir DIR]  (read-only; binds at once,\n\
         \u{20}                         answers {{\"state\":\"warming\"}} until caught up)\n\
         cluster (serve): --cluster \"1@H:P,2@H:P,3@H:P\" --cluster-id N --wal-dir DIR\n\
         \u{20}                         [--repl-listen A] [--advertise-repl A] [--advertise-query A]\n\
         \u{20}                         [--ack-level quorum] [--election-timeout-ms M] [--heartbeat-ms M]\n\
         \u{20}                         (leader elected by term-numbered votes; writes quorum-acked;\n\
         \u{20}                         followers redirect writes and keep serving reads)\n\
         precision (build/search/serve): --precision f32|sq8|pq   (quantized in-loop distances\n\
         \u{20}                         + exact re-rank; bruteforce/hnsw/finger only)\n\
         sharding (build/search/serve): --shards S [--shard-strategy round-robin|kmeans]\n\
         \u{20}                         [--min-shard-frac F]   (probe the nearest F·S shards, 0<F<=1)\n\
         build parallelism (build/search/serve): --threads N   (0 = FINGER_THREADS/auto;\n\
         \u{20}                         any N builds a bitwise-identical index)"
    );
}

fn dataset_from_args(args: &Args) -> finger_ann::data::Dataset {
    let name = args.get("dataset").unwrap_or("sift-sim-128");
    let scale = args.get_f64("scale", 0.25);
    let spec = spec_by_name(name, scale).unwrap_or_else(|| {
        eprintln!("unknown dataset '{name}'; known: fashion-sim-784 sift-sim-128 gist-sim-960 nytimes-sim-256 glove-sim-100 deep-sim-96");
        std::process::exit(2);
    });
    println!("generating {} (n={}, dim={})...", spec.name, spec.n, spec.dim);
    spec.generate()
}

/// `--precision f32|sq8|pq` — which distance tier the beam search
/// traverses on (quantized tiers re-rank the final pool exactly).
fn precision_from_args(args: &Args) -> Precision {
    let name = args.get("precision").unwrap_or("f32");
    Precision::parse(name).unwrap_or_else(|| {
        eprintln!("unknown precision '{name}' (f32|sq8|pq)");
        std::process::exit(2);
    })
}

/// Build any index family over `data` — the single construction path used
/// by `build`, `search`, and `serve`. `threads` is the build parallelism
/// for this index (0 = `FINGER_THREADS`/auto); the built index is
/// bitwise identical for every value.
fn build_method(method: &str, data: Arc<Matrix>, args: &Args, threads: usize) -> Box<dyn AnnIndex> {
    let m = args.get_usize("M", 16);
    let efc = args.get_usize("efc", 120);
    let rank = args.get_usize("rank", 16);
    let precision = precision_from_args(args);
    if precision != Precision::F32
        && !matches!(method, "bruteforce" | "hnsw" | "finger" | "hnsw-finger")
    {
        eprintln!(
            "--precision {} only applies to bruteforce|hnsw|finger (got '{method}')",
            precision.name()
        );
        std::process::exit(2);
    }
    match method {
        "bruteforce" => Box::new(BruteForce::with_precision(data, precision)),
        "hnsw" => Box::new(HnswIndex::build_with_precision(
            data,
            HnswParams { m, ef_construction: efc, threads, ..Default::default() },
            precision,
        )),
        "finger" | "hnsw-finger" => Box::new(FingerHnswIndex::build_with_precision(
            data,
            HnswParams { m, ef_construction: efc, threads, ..Default::default() },
            FingerParams { rank, threads, ..Default::default() },
            precision,
        )),
        "vamana" => Box::new(VamanaIndex::build(
            data,
            VamanaParams { r: args.get_usize("R", 32), threads, ..Default::default() },
        )),
        "nndescent" => Box::new(NnDescentIndex::build(
            data,
            NnDescentParams { degree: args.get_usize("degree", 32), threads, ..Default::default() },
        )),
        "ivfpq" => Box::new(IvfPqIndex::build(
            data,
            IvfPqParams { n_list: args.get_usize("nlist", 64), ..Default::default() },
        )),
        other => {
            eprintln!("unknown method '{other}' ({METHODS})");
            std::process::exit(2);
        }
    }
}

/// Build the requested index, sharded when `--shards S` (S > 1) is given:
/// the dataset is partitioned per `--shard-strategy` and `--method` is
/// built per shard, all behind the same `Box<dyn AnnIndex>`.
fn build_index(args: &Args, data: Arc<Matrix>) -> Box<dyn AnnIndex> {
    let method = args.get("method").unwrap_or("finger");
    let shards = args.get_usize("shards", 1);
    let threads = args.get_usize("threads", 0);
    if shards <= 1 {
        return build_method(method, data, args, threads);
    }
    let strategy_name = args.get("shard-strategy").unwrap_or("round-robin");
    let strategy = ShardStrategy::parse(strategy_name).unwrap_or_else(|| {
        eprintln!("unknown shard strategy '{strategy_name}' (round-robin|kmeans)");
        std::process::exit(2);
    });
    // The shard fan-out (`spec.threads`) supplies the parallelism; each
    // shard builds single-threaded so S × T workers don't oversubscribe.
    let spec = ShardSpec { n_shards: shards, strategy, threads, ..Default::default() };
    // Reject rather than clamp: a typo'd fraction would otherwise silently
    // probe one shard and collapse recall.
    let frac = match args.get("min-shard-frac") {
        None => 1.0f32,
        Some(raw) => match raw.parse::<f32>() {
            Ok(f) if f > 0.0 && f <= 1.0 => f,
            _ => {
                eprintln!("--min-shard-frac must be in (0, 1], got '{raw}'");
                std::process::exit(2);
            }
        },
    };
    let index = ShardedIndex::build(data, &spec, |sub| build_method(method, sub, args, 1))
        .with_min_shard_frac(frac);
    println!(
        "sharded across {} {} shards (probing {}/query)",
        index.n_shards(),
        strategy.name(),
        index.probe_count()
    );
    Box::new(index)
}

/// Search-time parameters from the shared CLI flags.
fn params_from_args(args: &Args, k: usize) -> SearchParams {
    let mut p = SearchParams::new(k)
        .with_ef(args.get_usize("ef", 80))
        .with_probes(args.get_usize("nprobe", 8));
    if let Some(patience) = args.get("patience").and_then(|s| s.parse().ok()) {
        p = p.with_patience(patience);
    }
    p
}

fn gen_data(args: &Args) {
    let ds = dataset_from_args(args);
    let out = PathBuf::from(args.get("out").unwrap_or("data"));
    std::fs::create_dir_all(&out).expect("mkdir");
    dio::write_fvecs(&out.join(format!("{}.base.fvecs", ds.name)), &ds.data).unwrap();
    dio::write_fvecs(&out.join(format!("{}.query.fvecs", ds.name)), &ds.queries).unwrap();
    let gt = exact_knn(&ds.data, &ds.queries, 100);
    dio::write_ivecs(&out.join(format!("{}.gt.ivecs", ds.name)), &gt).unwrap();
    println!(
        "wrote {}.base/query.fvecs + gt.ivecs to {}",
        ds.name,
        out.display()
    );
}

/// Build any index family and persist it as a tagged bundle.
fn build(args: &Args) {
    let ds = dataset_from_args(args);
    let out = PathBuf::from(args.get("out").unwrap_or("index.bin"));
    let t0 = Instant::now();
    let index = build_index(args, Arc::clone(&ds.data));
    println!(
        "built {} in {:.1}s ({:.1} MB index side data)",
        index.name(),
        t0.elapsed().as_secs_f64(),
        index.nbytes() as f64 / 1e6,
    );
    save_index(&out, index.as_ref()).expect("save index");
    println!("saved {} bundle to {}", index.name(), out.display());
}

fn search(args: &Args) {
    let ds = dataset_from_args(args);
    let method = args.get("method").unwrap_or("finger");
    let k = args.get_usize("k", 10);
    let params = params_from_args(args, k);

    println!("building {method} index...");
    let t0 = Instant::now();
    let index = build_index(args, Arc::clone(&ds.data));
    println!("built in {:.1}s", t0.elapsed().as_secs_f64());
    let gt = exact_knn(&ds.data, &ds.queries, k);

    let mut ctx = SearchContext::for_universe(index.len()).with_stats();
    let t = Instant::now();
    let mut rec = 0.0;
    for qi in 0..ds.queries.rows() {
        let res = index.search(ds.queries.row(qi), &params, &mut ctx);
        rec += finger_ann::eval::recall(&res, &gt[qi]);
    }
    let secs = t.elapsed().as_secs_f64();
    let nq = ds.queries.rows() as f64;
    let stats = ctx.take_stats();
    println!(
        "{}: recall@{k}={:.4} QPS={:.0} (ef={}, nprobe={}) — {:.0} full + {:.0} approx dist calls/query",
        index.name(),
        rec / nq,
        nq / secs,
        params.ef,
        params.n_probe,
        stats.dist_calls as f64 / nq,
        stats.approx_calls as f64 / nq,
    );
}

/// The non-durable index acquisition for `serve`: load a prebuilt tagged
/// bundle (`--index path`, any family) or build `--method` in-process.
fn build_or_load(args: &Args) -> Box<dyn AnnIndex> {
    if let Some(path) = args.get("index") {
        // A prebuilt bundle carries its own shard layout, probe
        // fraction, and quantized tier; accepting build-time flags here
        // would silently ignore them, so reject the combination outright.
        for flag in ["shards", "shard-strategy", "min-shard-frac", "precision"] {
            if args.get(flag).is_some() {
                eprintln!(
                    "--{flag} only applies when building (it is baked into the \
                     bundle); rebuild with `finger build --{flag} ...` instead"
                );
                std::process::exit(2);
            }
        }
        println!("loading index bundle {path}...");
        load_index(std::path::Path::new(path)).expect("load index")
    } else {
        let ds = dataset_from_args(args);
        println!("building {} index...", args.get("method").unwrap_or("finger"));
        build_index(args, Arc::clone(&ds.data))
    }
}

fn fsync_policy_from_args(args: &Args) -> FsyncPolicy {
    let name = args.get("fsync-policy").unwrap_or("always");
    FsyncPolicy::parse(name).unwrap_or_else(|| {
        eprintln!("bad --fsync-policy '{name}' (always|every_n:N|interval_ms:M|never)");
        std::process::exit(2);
    })
}

/// `--serve-mode threads|epoll` (default: epoll where supported).
fn serve_mode_from_args(args: &Args) -> ServeMode {
    match args.get("serve-mode") {
        None => ServeMode::default(),
        Some(raw) => ServeMode::parse(raw).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        }),
    }
}

fn serve(args: &Args) {
    // `--cluster` runs the node under quorum replication with leader
    // election: roles are elected, not configured.
    if args.get("cluster").is_some() {
        serve_cluster(args);
        return;
    }
    // `--replica-of` flips the whole command into read-only replica mode:
    // no local build, state arrives over the replication stream.
    if args.get("replica-of").is_some() {
        serve_replica(args);
        return;
    }
    // With `--wal-dir`, the directory is the source of truth: a durable
    // generation in it is recovered (build/--index flags are ignored so a
    // restart can never silently serve stale pre-crash state); an empty
    // one is bootstrapped around the built/loaded index.
    let mut wal: Option<Arc<Wal>> = None;
    let mut recovered_seq = 0u64;
    let index: Box<dyn AnnIndex> = if let Some(dir) = args.get("wal-dir") {
        let dir = PathBuf::from(dir);
        let policy = fsync_policy_from_args(args);
        if Wal::has_snapshot(&dir) {
            if args.get("index").is_some() || args.get("dataset").is_some() {
                println!(
                    "--wal-dir {} holds a durable generation; recovering it \
                     (--index/--dataset flags ignored)",
                    dir.display()
                );
            }
            let (index, w, report) = Wal::recover(&dir, policy).unwrap_or_else(|e| {
                eprintln!("recovery from {} failed: {e}", dir.display());
                std::process::exit(1);
            });
            println!("{}", report.summary());
            recovered_seq = report.last_seq;
            wal = Some(Arc::new(w));
            index
        } else {
            let index = build_or_load(args);
            let w = Wal::bootstrap(&dir, index.as_ref(), policy).unwrap_or_else(|e| {
                eprintln!("wal bootstrap in {} failed: {e}", dir.display());
                std::process::exit(1);
            });
            println!(
                "wal bootstrapped in {} (fsync policy {})",
                dir.display(),
                policy.name()
            );
            wal = Some(Arc::new(w));
            index
        }
    } else {
        build_or_load(args)
    };
    let dim = index.dim();
    let name = index.name();
    // Same knob surface as `search`: --ef/--nprobe/--patience all apply
    // (k still comes per request).
    let mut serve_index = ServeIndex::with_params(index, params_from_args(args, 10));
    if let Some(w) = &wal {
        serve_index = serve_index.with_wal(Arc::clone(w));
    }
    // Primary replication: stream the WAL to replicas over `--repl-listen`.
    if let Some(listen) = args.get("repl-listen") {
        let Some(w) = &wal else {
            eprintln!("--repl-listen requires --wal-dir (the WAL is the replication stream)");
            std::process::exit(2);
        };
        let level_name = args.get("ack-level").unwrap_or("one");
        let level = AckLevel::parse(level_name).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        let expect = args.get_usize("repl-expect", 1);
        let timeout_ms = args.get_usize("repl-ack-timeout-ms", 5000) as u64;
        let hub = ReplHub::start(
            listen,
            Arc::clone(w),
            HubOpts {
                level,
                expect,
                ack_timeout: std::time::Duration::from_millis(timeout_ms),
                ..HubOpts::default()
            },
        )
        .unwrap_or_else(|e| {
            eprintln!("replication listener bind on {listen} failed: {e}");
            std::process::exit(1);
        });
        println!(
            "replication listener on {} (ack level {}, expect {expect})",
            hub.local_addr(),
            level.name()
        );
        serve_index = serve_index.with_repl(hub);
    }
    serve_index.set_applied_seq(recovered_seq);
    let serve_index = Arc::new(serve_index);

    let rerank = if args.has_flag("rerank") {
        let data = Arc::new(serve_index.data_clone());
        match RerankService::start(default_artifacts_dir(), dim, data) {
            Ok(svc) => {
                println!("PJRT rerank service up (panel width {})", svc.max_cands);
                Some(Arc::new(svc))
            }
            Err(e) => {
                eprintln!("rerank service unavailable ({e:#}); serving without");
                None
            }
        }
    } else {
        None
    };

    let config = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7771").to_string(),
        workers: args.get_usize("workers", 4),
        max_batch: args.get_usize("max-batch", 8),
        use_pjrt_rerank: rerank.is_some(),
        mode: serve_mode_from_args(args),
        ..Default::default()
    };
    // Best-effort: lift RLIMIT_NOFILE to its hard cap so the epoll loop
    // can actually hold thousands of sockets.
    if let Ok(limit) = poll::raise_nofile_limit() {
        println!("nofile limit: {limit}");
    }
    let server = Server::start(serve_index, config.clone(), rerank).expect("bind");
    println!(
        "serving {name} ({dim}-dim) on {} ({} workers, max_batch {}, {} mode)",
        server.local_addr,
        config.workers,
        config.max_batch,
        config.mode.name()
    );
    println!("protocol: one JSON per line: {{\"id\":1,\"vector\":[..],\"k\":10}}");
    // Piped stdout is block-buffered: flush so a supervising process (the
    // crash-recovery smoke test included) can read the bound address now.
    std::io::Write::flush(&mut std::io::stdout()).ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!("{}", server.metrics.summary());
    }
}

/// `serve --replica-of ADDR` — read-only replica. State arrives over the
/// primary's replication stream (snapshot + ordered WAL ops); with
/// `--wal-dir` the stream is also persisted locally so a restart resumes
/// from the durable position instead of re-fetching the snapshot.
///
/// The query listener binds *immediately* — before the first byte of
/// catch-up — so orchestrators get a stable address to health-check and
/// clients get a structured `{"state":"warming"}` answer instead of a
/// connection refusal. Queries serve real state only after catch-up
/// flips the readiness latch.
fn serve_replica(args: &Args) {
    let raw = args.get("replica-of").expect("checked by caller");
    let primary: std::net::SocketAddr = raw.parse().unwrap_or_else(|_| {
        eprintln!("bad --replica-of '{raw}'");
        std::process::exit(2);
    });
    // Placeholder until the first snapshot (or local recovery) installs
    // real state; the warming gate keeps it invisible to clients.
    let placeholder: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(Matrix::zeros(0, 1))));
    let serve_index =
        Arc::new(ServeIndex::with_params(placeholder, params_from_args(args, 10)).as_replica());

    let config = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7772").to_string(),
        workers: args.get_usize("workers", 4),
        max_batch: args.get_usize("max-batch", 8),
        mode: serve_mode_from_args(args),
        ..Default::default()
    };
    if let Ok(limit) = poll::raise_nofile_limit() {
        println!("nofile limit: {limit}");
    }
    let server = Server::start(Arc::clone(&serve_index), config.clone(), None).expect("bind");
    println!(
        "serving replica of {primary} on {} ({} workers, max_batch {}, {} mode)",
        server.local_addr,
        config.workers,
        config.max_batch,
        config.mode.name()
    );
    println!(
        "protocol: one JSON per line: {{\"id\":1,\"vector\":[..],\"k\":10}} \
         (read-only; answers {{\"state\":\"warming\"}} until caught up)"
    );
    std::io::Write::flush(&mut std::io::stdout()).ok();

    let opts = ReplicaOpts {
        store: match args.get("wal-dir") {
            Some(d) => ReplicaStore::Dir(PathBuf::from(d)),
            None => ReplicaStore::None,
        },
        policy: fsync_policy_from_args(args),
        seed: args.get_usize("seed", 0x5EED) as u64,
        ..ReplicaOpts::default()
    };
    let replica = Replica::start(primary, Arc::clone(&serve_index), opts).unwrap_or_else(|e| {
        eprintln!("replica start failed: {e}");
        std::process::exit(1);
    });
    serve_index.set_repl_metrics(replica.metrics());
    print!("replica of {primary}: catching up...");
    std::io::Write::flush(&mut std::io::stdout()).ok();
    while !replica.wait_ready(std::time::Duration::from_secs(1)) {
        print!(".");
        std::io::Write::flush(&mut std::io::stdout()).ok();
    }
    println!(" caught up at seq {}", replica.applied());
    std::io::Write::flush(&mut std::io::stdout()).ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(10));
        println!(
            "{} (applied seq {}, {} reconnect(s))",
            server.metrics.summary(),
            replica.applied(),
            replica.reconnects()
        );
    }
}

/// `serve --cluster "1@H:P,2@H:P,3@H:P" --cluster-id N` — one node of a
/// quorum-replicated cluster with automatic failover.
///
/// The spec lists every node's *election* endpoint; who leads is decided
/// by term-numbered elections, not flags. The node binds its query
/// listener and replication listener up front (both addresses are
/// stable across role flips), recovers local state from `--wal-dir`
/// (required — quorum commit is WAL-fsync based), and then converges on
/// whatever role the election hands it: leaders take writes at ack
/// level `quorum` and stream the WAL to followers; followers serve
/// reads and redirect writes to the leader's advertised query address.
fn serve_cluster(args: &Args) {
    let spec = args.get("cluster").expect("checked by caller");
    let my_id = args.get_usize("cluster-id", 0) as u64;
    if my_id == 0 {
        eprintln!("--cluster requires --cluster-id N (nonzero, listed in the spec)");
        std::process::exit(2);
    }
    let mut listen: Option<String> = None;
    let mut peers: Vec<PeerSpec> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let Some((id_s, addr)) = part.split_once('@') else {
            eprintln!("bad --cluster entry '{part}' (want ID@HOST:PORT)");
            std::process::exit(2);
        };
        let Ok(id) = id_s.trim().parse::<u64>() else {
            eprintln!("bad node id '{id_s}' in --cluster entry '{part}'");
            std::process::exit(2);
        };
        if id == my_id {
            listen = Some(addr.trim().to_string());
        } else {
            peers.push(PeerSpec { id, addr: addr.trim().to_string() });
        }
    }
    let Some(listen) = listen else {
        eprintln!("--cluster-id {my_id} does not appear in --cluster '{spec}'");
        std::process::exit(2);
    };
    let expect = peers.len() + 1;
    let Some(dir) = args.get("wal-dir") else {
        eprintln!("--cluster requires --wal-dir (quorum commit is WAL-fsync based)");
        std::process::exit(2);
    };
    let dir = PathBuf::from(dir);
    let policy = fsync_policy_from_args(args);

    // Same source-of-truth rule as plain `serve`: a durable generation in
    // the WAL dir wins over build flags.
    let (index, wal, recovered_seq): (Box<dyn AnnIndex>, Arc<Wal>, u64) = if Wal::has_snapshot(&dir)
    {
        let (index, w, report) = Wal::recover(&dir, policy).unwrap_or_else(|e| {
            eprintln!("recovery from {} failed: {e}", dir.display());
            std::process::exit(1);
        });
        println!("{}", report.summary());
        let seq = report.last_seq;
        (index, Arc::new(w), seq)
    } else {
        let index = build_or_load(args);
        let w = Wal::bootstrap(&dir, index.as_ref(), policy).unwrap_or_else(|e| {
            eprintln!("wal bootstrap in {} failed: {e}", dir.display());
            std::process::exit(1);
        });
        println!("wal bootstrapped in {} (fsync policy {})", dir.display(), policy.name());
        (index, Arc::new(w), 0)
    };
    let dim = index.dim();
    let name = index.name();
    let serve_index = ServeIndex::with_params(index, params_from_args(args, 10))
        .with_wal(Arc::clone(&wal))
        .in_cluster();
    serve_index.set_applied_seq(recovered_seq);
    let serve_index = Arc::new(serve_index);

    let config = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7771").to_string(),
        workers: args.get_usize("workers", 4),
        max_batch: args.get_usize("max-batch", 8),
        mode: serve_mode_from_args(args),
        ..Default::default()
    };
    if let Ok(limit) = poll::raise_nofile_limit() {
        println!("nofile limit: {limit}");
    }
    let server = Server::start(Arc::clone(&serve_index), config.clone(), None).expect("bind");
    println!(
        "serving {name} ({dim}-dim) on {} ({} workers, max_batch {}, {} mode, \
         cluster node {my_id} of {expect})",
        server.local_addr,
        config.workers,
        config.max_batch,
        config.mode.name()
    );
    std::io::Write::flush(&mut std::io::stdout()).ok();

    // Replication listener: bound once, before any election outcome, so
    // the address this node advertises in heartbeats never changes.
    let repl_listener = std::net::TcpListener::bind(args.get("repl-listen").unwrap_or("127.0.0.1:0"))
        .unwrap_or_else(|e| {
            eprintln!("replication listener bind failed: {e}");
            std::process::exit(1);
        });
    let repl_local = repl_listener.local_addr().expect("bound listener has an addr");
    let repl_advertise = args
        .get("advertise-repl")
        .map(str::to_string)
        .unwrap_or_else(|| repl_local.to_string());
    let query_advertise = args
        .get("advertise-query")
        .map(str::to_string)
        .unwrap_or_else(|| server.local_addr.to_string());

    let level = AckLevel::parse(args.get("ack-level").unwrap_or("quorum")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    // `all` counts replica acks (there are expect-1 of them); `quorum`
    // counts cluster nodes, the leader included.
    let hub_expect = if level == AckLevel::All { expect - 1 } else { expect };
    let timeout_ms = args.get_usize("repl-ack-timeout-ms", 5000) as u64;
    let election = ElectionNode::start(ElectionConfig {
        id: my_id,
        listen: listen.clone(),
        peers,
        election_timeout: std::time::Duration::from_millis(
            args.get_usize("election-timeout-ms", 300) as u64,
        ),
        heartbeat_interval: std::time::Duration::from_millis(
            args.get_usize("heartbeat-ms", 60) as u64,
        ),
        state_dir: Some(dir.clone()),
        seed: args.get_usize("election-seed", my_id as usize) as u64,
    })
    .unwrap_or_else(|e| {
        eprintln!("election start on {listen} failed: {e}");
        std::process::exit(1);
    });
    println!(
        "election listener on {} (node {my_id}, term resumes from {})",
        election.local_addr(),
        election.term()
    );
    let cluster = ClusterNode::start(
        election,
        repl_listener,
        Arc::clone(&wal),
        Arc::clone(&serve_index),
        ClusterOpts {
            hub: HubOpts {
                level,
                expect: hub_expect,
                ack_timeout: std::time::Duration::from_millis(timeout_ms),
                ..HubOpts::default()
            },
            policy,
            repl_advertise: repl_advertise.clone(),
            query_advertise,
            seed: 0x5EED ^ my_id,
        },
    )
    .unwrap_or_else(|e| {
        eprintln!("cluster supervisor start failed: {e}");
        std::process::exit(1);
    });
    serve_index.set_cluster(Arc::clone(&cluster));
    println!(
        "replication listener on {repl_local} (advertised {repl_advertise}, ack level {}, \
         quorum {}/{expect})",
        level.name(),
        expect / 2 + 1
    );
    std::io::Write::flush(&mut std::io::stdout()).ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        println!(
            "{} (role {}, term {}, applied seq {})",
            server.metrics.summary(),
            cluster.role().name(),
            cluster.term(),
            serve_index.applied_seq()
        );
        std::io::Write::flush(&mut std::io::stdout()).ok();
    }
}

fn mutation_addr(args: &Args) -> std::net::SocketAddr {
    let addr = args.get("addr").unwrap_or("127.0.0.1:7771");
    addr.parse().unwrap_or_else(|_| {
        eprintln!("bad --addr '{addr}'");
        std::process::exit(2);
    })
}

fn send_mutation(addr: &std::net::SocketAddr, req: &Request) -> Result<MutResponse, String> {
    let mut client =
        Client::connect(addr).map_err(|e| format!("cannot connect to {addr}: {e}"))?;
    client.mutate(req)
}

fn apply_mutation(args: &Args, req: Request) {
    let addr = mutation_addr(args);
    let resp = match send_mutation(&addr, &req) {
        Ok(resp) => resp,
        // Follower rejections name the leader's query address — chase it
        // once, so writes work against any cluster node.
        Err(e) => match e
            .split("leader is at ")
            .nth(1)
            .and_then(|rest| rest.trim().parse::<std::net::SocketAddr>().ok())
        {
            Some(leader) => {
                eprintln!("{addr} is not the leader; redirecting to {leader}");
                send_mutation(&leader, &req).unwrap_or_else(|e| {
                    eprintln!("leader {leader} rejected the mutation: {e}");
                    std::process::exit(1);
                })
            }
            None => {
                eprintln!("server rejected the mutation: {e}");
                std::process::exit(1);
            }
        },
    };
    match resp.outcome {
        MutOutcome::Inserted(id) => println!("inserted id {id} ({} live)", resp.live),
        MutOutcome::Deleted(id) => println!("deleted id {id} ({} live)", resp.live),
        MutOutcome::Compacted(did) => println!(
            "{} ({} live)",
            if did { "compacted" } else { "below compaction threshold; not rebuilt" },
            resp.live
        ),
        MutOutcome::Saved(seq) => {
            println!("checkpointed at seq {seq} ({} live)", resp.live)
        }
        MutOutcome::ThresholdSet(frac) => {
            println!("compaction threshold set to {frac} ({} live)", resp.live)
        }
    }
}

fn parse_vector_arg(args: &Args, cmd: &str) -> Vec<f32> {
    let Some(raw) = args.get("vector") else {
        eprintln!("{cmd} requires --vector \"v1,v2,...\"");
        std::process::exit(2);
    };
    let vector: Vec<f32> = raw
        .split(',')
        .map(|s| {
            s.trim().parse::<f32>().unwrap_or_else(|_| {
                eprintln!("bad vector component '{s}'");
                std::process::exit(2);
            })
        })
        .collect();
    if vector.is_empty() {
        eprintln!("empty vector");
        std::process::exit(2);
    }
    vector
}

/// `finger update --vector "v1,v2,..."` — online insert into a running
/// server (the INSERT protocol verb).
fn update(args: &Args) {
    let vector = parse_vector_arg(args, "update");
    apply_mutation(args, Request::Insert { id: 0, vector });
}

/// `finger delete --key ID` — tombstone a served point (DELETE verb).
fn delete(args: &Args) {
    let Some(key) = args.get("key").and_then(|s| s.parse::<u32>().ok()) else {
        eprintln!("delete requires --key ID (a u32)");
        std::process::exit(2);
    };
    apply_mutation(args, Request::Delete { id: 0, key });
}

/// `finger compact` — ask the server to reclaim tombstones (COMPACT verb).
fn compact(args: &Args) {
    apply_mutation(args, Request::Compact { id: 0 });
}

/// `finger set-threshold --frac F` — retune the compaction gate on a
/// running server (SET_THRESHOLD verb). Logged and replicated like any
/// other mutation, so replicas and post-recovery replay converge on the
/// same compaction decisions.
fn set_threshold(args: &Args) {
    let Some(frac) = args.get("frac").and_then(|s| s.parse::<f64>().ok()) else {
        eprintln!("set-threshold requires --frac F (a float in (0, 1])");
        std::process::exit(2);
    };
    apply_mutation(args, Request::SetThreshold { id: 0, frac });
}

/// `finger snapshot` — checkpoint a serving index through its WAL (SAVE
/// verb): fresh durable snapshot + log rotation, no restart.
fn snapshot(args: &Args) {
    apply_mutation(args, Request::Save { id: 0 });
}

/// Parse `--addrs A,B,...` (falling back to `--addr`, then the default
/// mutation address) into a read-pool address list.
fn read_addrs(args: &Args) -> Vec<std::net::SocketAddr> {
    let raw = args
        .get("addrs")
        .unwrap_or_else(|| args.get("addr").unwrap_or("127.0.0.1:7771"))
        .to_string();
    let mut addrs = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.parse() {
            Ok(a) => addrs.push(a),
            Err(_) => {
                eprintln!("bad address '{part}' in --addrs");
                std::process::exit(2);
            }
        }
    }
    if addrs.is_empty() {
        eprintln!("--addrs is empty");
        std::process::exit(2);
    }
    addrs
}

/// `finger query --vector "v1,v2,..." [--k N] [--addrs A,B,...]` — one
/// search request fanned over a read pool (primary + replicas) with
/// round-robin rotation and failover.
fn query_cmd(args: &Args) {
    let vector = parse_vector_arg(args, "query");
    let k = args.get_usize("k", 10);
    let mut pool = ReadPool::new(read_addrs(args));
    let req = QueryRequest { id: 0, vector, k };
    match pool.query(&req) {
        Ok((addr, resp)) => {
            println!("{} hit(s) from {addr} ({} us server-side):", resp.hits.len(), resp.latency_us);
            for (dist, key) in &resp.hits {
                println!("  key {key:>8}  dist {dist:.6}");
            }
        }
        Err(e) => {
            eprintln!("query failed on every address: {e}");
            std::process::exit(1);
        }
    }
}

/// `finger repl status|fingerprint` — replication observability.
///
/// `status` prints one node's role and per-replica ack progress;
/// `fingerprint` hashes the live state of every listed node and exits 1
/// if they disagree (the divergence check the replication contract is
/// supposed to make impossible).
fn repl_cmd(args: &Args) {
    let action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("status");
    match action {
        "status" => {
            let addr = mutation_addr(args);
            let mut client = Client::connect(&addr).unwrap_or_else(|e| {
                eprintln!("cannot connect to {addr}: {e}");
                std::process::exit(1);
            });
            let line = client
                .send_raw(&Request::ReplStatus { id: 0 }.to_json_line())
                .unwrap_or_else(|e| {
                    eprintln!("repl status on {addr} failed: {e}");
                    std::process::exit(1);
                });
            println!("{}", line.trim_end());
        }
        "fingerprint" => {
            let addrs = read_addrs(args);
            let mut infos: Vec<(std::net::SocketAddr, FingerprintInfo)> = Vec::new();
            for addr in &addrs {
                let info = Client::connect(addr)
                    .map_err(|e| e.to_string())
                    .and_then(|mut c| {
                        c.send_raw(&Request::Fingerprint { id: 0 }.to_json_line())
                            .map_err(|e| e.to_string())
                    })
                    .and_then(|line| FingerprintInfo::parse(&line))
                    .unwrap_or_else(|e| {
                        eprintln!("fingerprint on {addr} failed: {e}");
                        std::process::exit(1);
                    });
                println!(
                    "  {addr}: fingerprint {:016x}  seq {}  live {}",
                    info.fingerprint, info.seq, info.live
                );
                infos.push((*addr, info));
            }
            let first = &infos[0].1;
            if infos.iter().all(|(_, i)| i.fingerprint == first.fingerprint) {
                println!("all {} node(s) agree at fingerprint {:016x}", infos.len(), first.fingerprint);
            } else {
                eprintln!("STATE DIVERGENCE across {} node(s)", infos.len());
                std::process::exit(1);
            }
        }
        // `repl leader --addrs A,B,...` — ask every node who leads.
        // Works against followers (they relay what heartbeats told them),
        // so any one reachable node is enough.
        "leader" => {
            let pool = ReadPool::new(read_addrs(args));
            match pool.discover_leader() {
                Some(leader) => println!("leader: {leader}"),
                None => {
                    eprintln!("no leader discovered (cluster may be mid-election)");
                    std::process::exit(1);
                }
            }
        }
        other => {
            eprintln!("unknown repl action '{other}' (status|fingerprint|leader)");
            std::process::exit(2);
        }
    }
}

fn describe_op(op: &WalOp) -> String {
    match op {
        WalOp::Insert { vector } => format!("insert (dim {})", vector.len()),
        WalOp::Delete { key } => format!("delete key {key}"),
        WalOp::Compact => "compact".into(),
        WalOp::SetThreshold { frac } => format!("set_threshold {frac}"),
    }
}

fn print_scan(dir: &std::path::Path, seq: u64, scan: &ScanResult) {
    println!("wal generation {seq} in {}", dir.display());
    for (s, op) in &scan.ops {
        println!("  seq {s:>6}  {}", describe_op(op));
    }
    match &scan.corruption {
        Some(why) => println!(
            "  ! torn tail: {why} ({} byte(s) past the durable prefix)",
            scan.dropped_bytes
        ),
        None => println!(
            "  clean: {} op(s), {} durable byte(s)",
            scan.ops.len(),
            scan.durable_len
        ),
    }
}

/// `finger wal dump|truncate --wal-dir DIR` — offline WAL inspection and
/// repair (truncate cuts the log back to its durable prefix).
fn wal_cmd(args: &Args) {
    let action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("dump");
    let Some(dir) = args.get("wal-dir") else {
        eprintln!("wal {action} requires --wal-dir DIR");
        std::process::exit(2);
    };
    let dir = std::path::Path::new(dir);
    let result = match action {
        "dump" => Wal::dump(dir),
        "truncate" => Wal::repair(dir),
        other => {
            eprintln!("unknown wal action '{other}' (dump|truncate)");
            std::process::exit(2);
        }
    };
    match result {
        Ok((seq, scan)) => {
            print_scan(dir, seq, &scan);
            if action == "truncate" {
                println!(
                    "truncated to {} byte(s); {} torn byte(s) dropped",
                    scan.durable_len, scan.dropped_bytes
                );
            }
        }
        Err(e) => {
            eprintln!("wal {action} failed: {e}");
            std::process::exit(1);
        }
    }
}

/// Churn sweep: interleaved insert/delete/query recall-over-time for the
/// mutable families (the streaming-workload scenario).
fn bench_churn(out: &std::path::Path, scale: f64) {
    use finger_ann::core::distance::Metric;
    use finger_ann::data::tiny;
    use finger_ann::eval::sweep::{churn_sweep, churn_to_csv};
    use finger_ann::index::MutableAnnIndex;

    let n = ((4000.0 * scale) as usize).clamp(200, 20_000);
    let ds = tiny(4242, n, 32, Metric::L2);
    std::fs::create_dir_all(out).expect("mkdir");
    let params = SearchParams::new(10).with_ef(120);
    let mut csv_all = String::new();
    for method in ["hnsw", "finger"] {
        let mut index: Box<dyn AnnIndex> = match method {
            "hnsw" => Box::new(HnswIndex::build(
                Arc::clone(&ds.data),
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            )),
            _ => Box::new(FingerHnswIndex::build(
                Arc::clone(&ds.data),
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
                FingerParams { rank: 8, ..Default::default() },
            )),
        };
        let mutable = index.as_mutable().expect("graph families are mutable");
        mutable.set_compact_threshold(0.25);
        let ins = (n / 50).max(5);
        let del = ins + ins / 2;
        let points = churn_sweep(mutable, &ds.queries, 10, &params, 10, ins, del, 7);
        println!("churn [{method}] (n={n}, +{ins}/-{del} per step):");
        for p in &points {
            println!(
                "  step {:>2}: live {:>6}  tomb {:.3}  compacted {:<5}  recall@10 {:.4}  {:.0} QPS",
                p.step, p.live, p.tombstone_frac, p.compacted, p.recall10, p.qps
            );
        }
        csv_all.push_str(&format!("# method={method}\n"));
        csv_all.push_str(&churn_to_csv(&points));
    }
    let path = out.join("churn.csv");
    std::fs::write(&path, csv_all).expect("write churn.csv");
    println!("wrote {}", path.display());
    bench_churn_durability(out, &ds, n);
}

/// Durability section of the churn benchmark: mutation throughput with the
/// WAL attached, one row per fsync policy. Shows what `fsync=always` costs
/// relative to group-committed (`every_n`) and unsynced (`never`) appends.
fn bench_churn_durability(out: &std::path::Path, ds: &finger_ann::data::Dataset, n: usize) {
    use finger_ann::core::json::Json;
    use finger_ann::core::rng::Pcg32;

    let dim = ds.data.cols();
    let ops = (n / 4).clamp(50, 1000);
    let mut rows = Vec::new();
    println!("churn durability (hnsw, {ops} inserts per policy):");
    for policy_name in ["always", "every_n:8", "never"] {
        let policy = FsyncPolicy::parse(policy_name).expect("known policy");
        let dir = std::env::temp_dir()
            .join(format!("finger_bench_wal_{}_{}", std::process::id(), policy.name().replace(':', "_")));
        let _ = std::fs::remove_dir_all(&dir);
        let mut index: Box<dyn AnnIndex> = Box::new(HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        ));
        let wal = Wal::bootstrap(&dir, index.as_ref(), policy).expect("bootstrap wal");
        let mutable = index.as_mutable().expect("hnsw is mutable");
        let mut ctx = SearchContext::new();
        let mut rng = Pcg32::new(991);
        let t0 = Instant::now();
        for _ in 0..ops {
            let vector: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            mutable.insert(&vector, &mut ctx).expect("insert");
            let (w, seq) = wal.append(&WalOp::Insert { vector }).expect("append");
            w.commit(seq).expect("commit");
        }
        let secs = t0.elapsed().as_secs_f64();
        let w = wal.writer();
        let ops_per_sec = ops as f64 / secs.max(1e-9);
        println!(
            "  fsync={:<12} {:>9.0} ops/s  ({} fsync(s), {} log byte(s))",
            policy.name(),
            ops_per_sec,
            w.sync_count(),
            w.len()
        );
        rows.push(Json::obj(vec![
            ("policy", Json::str(policy.name().as_str())),
            ("ops", Json::num(ops as f64)),
            ("ops_per_sec", Json::num(ops_per_sec)),
            ("fsyncs", Json::num(w.sync_count() as f64)),
            ("log_bytes", Json::num(w.len() as f64)),
        ]));
        let _ = std::fs::remove_dir_all(&dir);
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("finger-ann/churn-durability/v1")),
        ("n", Json::num(n as f64)),
        ("dim", Json::num(dim as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = out.join("BENCH_churn.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_churn.json");
    println!("wrote {}", path.display());
}

/// Serving-plane benchmark: mixed read/write load over real TCP for each
/// frontend (thread-per-connection and, where supported, the epoll event
/// loop). 16 blocking clients each run a seeded ~90% query / ~8% insert /
/// ~2% delete mix and record per-op client-side latency; the JSON row per
/// mode carries QPS and p50/p99/p999.
fn bench_router(out: &std::path::Path, scale: f64) {
    use finger_ann::core::distance::Metric;
    use finger_ann::core::json::Json;
    use finger_ann::core::rng::Pcg32;
    use finger_ann::data::synth::tiny;

    let n = ((4000.0 * scale) as usize).clamp(400, 20_000);
    let dim = 32usize;
    let clients = 16usize;
    let ops_per_client = (n / 8).clamp(100, 800);
    let ds = tiny(7411, n, dim, Metric::L2);
    let mut modes = vec![ServeMode::Threads];
    if poll::SUPPORTED {
        modes.push(ServeMode::Epoll);
    }
    println!(
        "router serving bench (hnsw n={n} dim={dim}, {clients} clients x {ops_per_client} mixed ops):"
    );

    let mut rows = Vec::new();
    for mode in modes {
        // Fresh index per mode: the mix mutates it.
        let index: Box<dyn AnnIndex> = Box::new(HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        ));
        let serve_index = Arc::new(ServeIndex::new(index, 64));
        let server = Server::start(
            Arc::clone(&serve_index),
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers: 4,
                mode,
                ..Default::default()
            },
            None,
        )
        .expect("bind bench server");
        let addr = server.local_addr;

        let t0 = Instant::now();
        let handles: Vec<_> = (0..clients)
            .map(|ci| {
                let data = Arc::clone(&ds.data);
                std::thread::spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let mut rng = Pcg32::new(0x7700 + ci as u64);
                    let mut lats = Vec::with_capacity(ops_per_client);
                    let mut errors = 0u64;
                    let mut inserted: Vec<u32> = Vec::new();
                    for op in 0..ops_per_client {
                        let roll = rng.next_u32() % 100;
                        let t = Instant::now();
                        let ok = if roll < 90 || (roll >= 98 && inserted.is_empty()) {
                            let row = rng.next_u32() as usize % data.rows();
                            client
                                .query(&QueryRequest {
                                    id: op as u64,
                                    vector: data.row(row).to_vec(),
                                    k: 10,
                                })
                                .is_ok()
                        } else if roll < 98 {
                            let vector: Vec<f32> =
                                (0..dim).map(|_| rng.next_gaussian()).collect();
                            match client.mutate(&Request::Insert { id: op as u64, vector }) {
                                Ok(ack) => {
                                    if let MutOutcome::Inserted(key) = ack.outcome {
                                        inserted.push(key);
                                    }
                                    true
                                }
                                Err(_) => false,
                            }
                        } else {
                            let key = inserted.pop().expect("checked non-empty");
                            client.mutate(&Request::Delete { id: op as u64, key }).is_ok()
                        };
                        lats.push(t.elapsed().as_micros() as u64);
                        if !ok {
                            errors += 1;
                        }
                    }
                    (lats, errors)
                })
            })
            .collect();
        let mut lats: Vec<u64> = Vec::new();
        let mut errors = 0u64;
        for h in handles {
            let (l, e) = h.join().expect("client thread");
            lats.extend(l);
            errors += e;
        }
        let secs = t0.elapsed().as_secs_f64();
        server.shutdown();

        lats.sort_unstable();
        let pct = |p: f64| -> u64 {
            let idx = ((lats.len() - 1) as f64 * p).round() as usize;
            lats[idx]
        };
        let total_ops = lats.len();
        let qps = total_ops as f64 / secs.max(1e-9);
        let (p50, p99, p999) = (pct(0.50), pct(0.99), pct(0.999));
        println!(
            "  mode={:<8} {:>8.0} qps  p50={}us p99={}us p999={}us  ({} ops, {} errors)",
            mode.name(),
            qps,
            p50,
            p99,
            p999,
            total_ops,
            errors
        );
        rows.push(Json::obj(vec![
            ("mode", Json::str(mode.name())),
            ("ops", Json::num(total_ops as f64)),
            ("qps", Json::num(qps)),
            ("p50_us", Json::num(p50 as f64)),
            ("p99_us", Json::num(p99 as f64)),
            ("p999_us", Json::num(p999 as f64)),
            ("errors", Json::num(errors as f64)),
        ]));
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("finger-ann/router-bench/v1")),
        ("n", Json::num(n as f64)),
        ("dim", Json::num(dim as f64)),
        ("clients", Json::num(clients as f64)),
        ("ops_per_client", Json::num(ops_per_client as f64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = out.join("BENCH_router.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_router.json");
    println!("wrote {}", path.display());
}

/// Replication-plane benchmark: client-observed write-ack latency per
/// ack level over real TCP, against a leader streaming to two local
/// replicas (fsync `always` on every node, so the numbers carry the
/// true durability cost). The `quorum` row is the one failover cares
/// about: it is what a 3-node cluster charges per write.
fn bench_repl(out: &std::path::Path, scale: f64) {
    use finger_ann::core::distance::Metric;
    use finger_ann::core::json::Json;
    use finger_ann::core::rng::Pcg32;
    use finger_ann::data::synth::tiny;

    let n = ((2000.0 * scale) as usize).clamp(200, 8_000);
    let dim = 16usize;
    let ops = ((400.0 * scale) as usize).clamp(60, 1000);
    let ds = tiny(9113, n, dim, Metric::L2);
    std::fs::create_dir_all(out).expect("mkdir");
    println!("repl ack-latency bench (hnsw n={n} dim={dim}, {ops} inserts per level, 2 replicas):");

    let mut rows = Vec::new();
    for level in [AckLevel::None, AckLevel::One, AckLevel::Quorum, AckLevel::All] {
        let stamp = format!("{}_{}", std::process::id(), level.name());
        let leader_dir = std::env::temp_dir().join(format!("finger_bench_repl_l_{stamp}"));
        let _ = std::fs::remove_dir_all(&leader_dir);
        let index: Box<dyn AnnIndex> = Box::new(HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 12, ef_construction: 80, ..Default::default() },
        ));
        let wal =
            Arc::new(Wal::bootstrap(&leader_dir, index.as_ref(), FsyncPolicy::Always).expect("wal"));
        // `all` counts replica acks (2 replicas); `quorum` counts cluster
        // nodes (leader + 2 = 3, majority 2).
        let expect = if level == AckLevel::Quorum { 3 } else { 2 };
        let hub = ReplHub::start(
            "127.0.0.1:0",
            Arc::clone(&wal),
            HubOpts {
                level,
                expect,
                ack_timeout: std::time::Duration::from_secs(10),
                ..HubOpts::default()
            },
        )
        .expect("hub");
        let serve_index = Arc::new(
            ServeIndex::new(index, 64).with_wal(Arc::clone(&wal)).with_repl(Arc::clone(&hub)),
        );
        let server = Server::start(
            Arc::clone(&serve_index),
            ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() },
            None,
        )
        .expect("bind bench server");

        let mut replicas = Vec::new();
        for r in 0..2 {
            let rdir = std::env::temp_dir().join(format!("finger_bench_repl_r{r}_{stamp}"));
            let _ = std::fs::remove_dir_all(&rdir);
            let placeholder: Box<dyn AnnIndex> =
                Box::new(BruteForce::new(Arc::new(Matrix::zeros(0, 1))));
            let rserve = Arc::new(ServeIndex::new(placeholder, 64).as_replica());
            let replica = Replica::start(
                hub.local_addr(),
                Arc::clone(&rserve),
                ReplicaOpts {
                    store: ReplicaStore::Dir(rdir.clone()),
                    policy: FsyncPolicy::Always,
                    ..ReplicaOpts::default()
                },
            )
            .expect("replica");
            assert!(
                replica.wait_ready(std::time::Duration::from_secs(20)),
                "replica catch-up timed out"
            );
            replicas.push((replica, rdir));
        }

        let mut client = Client::connect(&server.local_addr).expect("connect");
        let mut rng = Pcg32::new(0x9E11 + expect as u64);
        let mut lats: Vec<u64> = Vec::with_capacity(ops);
        let t0 = Instant::now();
        for op in 0..ops {
            let vector: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let t = Instant::now();
            client
                .mutate(&Request::Insert { id: op as u64, vector })
                .expect("quorum-acked insert");
            lats.push(t.elapsed().as_micros() as u64);
        }
        let secs = t0.elapsed().as_secs_f64();
        drop(client);
        server.shutdown();
        hub.shutdown();
        for (replica, rdir) in replicas {
            replica.stop();
            let _ = std::fs::remove_dir_all(&rdir);
        }
        let _ = std::fs::remove_dir_all(&leader_dir);

        lats.sort_unstable();
        let pct = |p: f64| -> u64 { lats[((lats.len() - 1) as f64 * p).round() as usize] };
        let (p50, p99) = (pct(0.50), pct(0.99));
        let wps = ops as f64 / secs.max(1e-9);
        println!(
            "  ack={:<7} {:>8.0} writes/s  p50={p50}us p99={p99}us  ({ops} ops)",
            level.name(),
            wps
        );
        rows.push(Json::obj(vec![
            ("ack_level", Json::str(level.name())),
            ("ops", Json::num(ops as f64)),
            ("writes_per_sec", Json::num(wps)),
            ("p50_us", Json::num(p50 as f64)),
            ("p99_us", Json::num(p99 as f64)),
        ]));
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("finger-ann/repl-bench/v1")),
        ("n", Json::num(n as f64)),
        ("dim", Json::num(dim as f64)),
        ("replicas", Json::num(2.0)),
        ("fsync", Json::str("always")),
        ("rows", Json::Arr(rows)),
    ]);
    let path = out.join("BENCH_repl.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_repl.json");
    println!("wrote {}", path.display());
}

fn bench(args: &Args) {
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let scale = args.get_f64("scale", 0.25);
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    println!("benchmark scale={scale} out={}", out.display());
    let t0 = Instant::now();
    match what {
        // Figure 1 is the baseline subset of Figure 5; same harness.
        "figure1" | "figure5" => figures::figure5(&out, scale, false),
        "figure8" => figures::figure5(&out, scale, true),
        "figure2" => figures::figure2(&out, scale),
        "figure3" => figures::figure3(&out, scale),
        "figure4" => figures::figure4(&out, scale),
        "figure6" => figures::figure6(&out, scale),
        "figure7" => figures::figure7(&out, scale),
        "table1" => figures::table1(&out, scale),
        "rank-selection" => figures::rank_selection(&out, scale),
        "churn" => bench_churn(&out, scale),
        // Hot-path data-plane microharness (padded store + batched
        // kernels): scalar-vs-batched ns/dist and QPS for flat HNSW and
        // FINGER-HNSW, written as BENCH_hotpath.json for the perf
        // trajectory CI records every PR.
        "hotpath" => finger_ann::eval::hotpath::bench_hotpath(&out, scale),
        // Serving-plane benchmark: mixed read/write load over real TCP,
        // per serve mode, written as BENCH_router.json.
        "router" => bench_router(&out, scale),
        // Replication-plane benchmark: write-ack latency per ack level
        // (the quorum row is the failover-safe cost), BENCH_repl.json.
        "repl" => bench_repl(&out, scale),
        "all" => {
            figures::figure2(&out, scale);
            figures::figure3(&out, scale);
            figures::figure4(&out, scale);
            figures::figure5(&out, scale, false);
            figures::figure6(&out, scale);
            figures::figure7(&out, scale);
            figures::figure5(&out, scale, true); // figure 8
            figures::table1(&out, scale);
            figures::rank_selection(&out, scale);
            bench_churn(&out, scale);
            finger_ann::eval::hotpath::bench_hotpath(&out, scale);
            bench_router(&out, scale);
            bench_repl(&out, scale);
        }
        other => {
            eprintln!("unknown bench '{other}'");
            std::process::exit(2);
        }
    }
    println!("bench '{what}' finished in {:.1}s", t0.elapsed().as_secs_f64());
}

fn info() {
    let dir = default_artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts in {}:", dir.display());
            for (name, a) in &m.artifacts {
                println!(
                    "  {:<28} kind={:<9} inputs={} outputs={} meta={:?}",
                    name,
                    a.kind,
                    a.inputs.len(),
                    a.outputs.len(),
                    a.meta
                );
            }
        }
        Err(e) => {
            eprintln!("no artifacts at {} ({e:#}); run `make artifacts`", dir.display());
            std::process::exit(1);
        }
    }
}
