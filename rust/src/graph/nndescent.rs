//! NN-descent KNN-graph construction (Dong, Moses & Li, WWW 2011) — the
//! PyNNDescent-style baseline of the paper's Figures 1/5/8. Builds an
//! approximate K-NN graph by iterated local joins, then diversity-prunes
//! and symmetrizes it into a searchable graph.
//!
//! Construction is batch-parallel and deterministic: random init draws
//! from a per-node PCG stream (`Pcg32::with_stream(seed, u)`), and each
//! local-join batch computes its candidate pools and all pairwise
//! distances concurrently from the frozen lists (state as of the batch
//! start) before applying the `offer` updates serially in ascending node
//! order. Every parallel item is a pure function of frozen state, so the
//! built graph is bitwise identical for every `params.threads` (pinned
//! by `rust/tests/kernel_dispatch.rs`).

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::core::store::VectorStore;
use crate::core::threads::{parallel_map, resolve_threads};
use crate::graph::adjacency::FlatAdj;
use crate::graph::earlyterm::beam_search_early_term;
use crate::graph::hnsw::select_heuristic;
use crate::graph::search::{beam_search_filtered, AllLive, Neighbor};
use crate::index::context::{SearchContext, SearchParams};

#[derive(Clone, Debug)]
pub struct NnDescentParams {
    /// K of the intermediate KNN graph.
    pub k: usize,
    /// Sampled neighbors per local join.
    pub sample: usize,
    pub iters: usize,
    /// Final searchable-graph degree cap.
    pub degree: usize,
    pub seed: u64,
    /// Diversity-prune (PyNNDescent does this for its search graph).
    pub prune: bool,
    /// Build worker threads (0 = `FINGER_THREADS`/auto); the built graph
    /// is identical for every value, so this is never persisted.
    pub threads: usize,
}

impl Default for NnDescentParams {
    fn default() -> Self {
        Self {
            k: 24,
            sample: 12,
            iters: 6,
            degree: 32,
            seed: 42,
            prune: true,
            threads: 0,
        }
    }
}

/// Nodes per local-join batch: bounds the transient pairwise-distance
/// buffers (~`2·sample²` entries per node) while keeping every worker fed.
const JOIN_BATCH: usize = 2048;

pub struct NnDescent {
    pub params: NnDescentParams,
    pub adj: FlatAdj,
    /// Entry probes: the search starts from the nearest of these
    /// (KNN graphs lack HNSW's navigable hierarchy, so a handful of probes
    /// substitutes for the coarse descent — PyNNDescent does the same with
    /// its random-projection-forest init).
    pub entry_probes: Vec<u32>,
}

/// Per-node bounded candidate list (max-heap by distance, dedup by id).
struct KnnList {
    items: Vec<Neighbor>,
    cap: usize,
}

impl KnnList {
    fn new(cap: usize) -> Self {
        Self {
            items: Vec::with_capacity(cap + 1),
            cap,
        }
    }

    /// Insert; returns true if the list changed.
    fn offer(&mut self, cand: Neighbor) -> bool {
        if self.items.iter().any(|x| x.id == cand.id) {
            return false;
        }
        if self.items.len() < self.cap {
            self.items.push(cand);
            self.items.sort();
            return true;
        }
        if cand.dist >= self.items[self.cap - 1].dist {
            return false;
        }
        self.items[self.cap - 1] = cand;
        self.items.sort();
        true
    }
}

impl NnDescent {
    /// Build over `data`, padding it into a throwaway store; callers that
    /// keep a [`VectorStore`] use [`NnDescent::build_with_store`].
    pub fn build(data: &Matrix, params: NnDescentParams) -> NnDescent {
        let store = VectorStore::from_matrix(data);
        NnDescent::build_with_store(&store, params)
    }

    pub fn build_with_store(store: &VectorStore, params: NnDescentParams) -> NnDescent {
        let n = store.rows();
        assert!(n > 1);
        let k = params.k.min(n - 1);
        let threads = resolve_threads(params.threads);
        let mut rng = Pcg32::new(params.seed);

        // Random initialization: each node draws its starting neighbors
        // from a private PCG stream keyed on (seed, node id), so the init
        // is order-free and fans out across workers.
        let init: Vec<Vec<Neighbor>> = parallel_map(n, threads, |u| {
            let mut r = Pcg32::with_stream(params.seed, u as u64);
            let mut items: Vec<Neighbor> = Vec::with_capacity(k);
            while items.len() < k {
                let v = r.gen_range(n);
                if v != u && !items.iter().any(|x| x.id == v as u32) {
                    items.push(Neighbor {
                        dist: l2_sq(store.row(u), store.row(v)),
                        id: v as u32,
                    });
                }
            }
            items.sort();
            items
        });
        let mut lists: Vec<KnnList> = init
            .into_iter()
            .map(|items| {
                let mut l = KnnList::new(k);
                l.items = items;
                l
            })
            .collect();

        // Iterated local joins: for each u, sample pairs among (neighbors ∪
        // reverse neighbors) and try cross-linking them. Per batch, the
        // pools and all pairwise distances are computed concurrently from
        // the frozen lists; the `offer` updates (which mutate arbitrary
        // nodes' lists) commit serially in ascending node order.
        for it in 0..params.iters {
            // Reverse adjacency sample (frozen at iteration start).
            let mut reverse: Vec<Vec<u32>> = vec![Vec::new(); n];
            for u in 0..n {
                for nb in &lists[u].items {
                    let r = &mut reverse[nb.id as usize];
                    if r.len() < params.sample {
                        r.push(u as u32);
                    }
                }
            }
            let mut updates = 0usize;
            let mut start = 0usize;
            while start < n {
                let end = (start + JOIN_BATCH).min(n);
                let scored: Vec<Vec<(u32, u32, f32)>> = {
                    let frozen = &lists;
                    let rev = &reverse;
                    let (sample, seed) = (params.sample, params.seed);
                    parallel_map(end - start, threads, move |bi| {
                        let u = start + bi;
                        let mut pool: Vec<u32> =
                            frozen[u].items.iter().map(|x| x.id).collect();
                        pool.extend_from_slice(&rev[u]);
                        pool.sort_unstable();
                        pool.dedup();
                        if pool.len() > sample * 2 {
                            // Keyed stream per (iteration, node): the
                            // subsample is independent of visit order.
                            let mut r = Pcg32::with_stream(
                                seed ^ (it as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
                                u as u64,
                            );
                            r.shuffle(&mut pool);
                            pool.truncate(sample * 2);
                        }
                        let mut out = Vec::with_capacity(pool.len() * (pool.len() - 1) / 2);
                        for i in 0..pool.len() {
                            for j in i + 1..pool.len() {
                                let (a, b) = (pool[i], pool[j]);
                                if a == b {
                                    continue;
                                }
                                let d = l2_sq(store.row(a as usize), store.row(b as usize));
                                out.push((a, b, d));
                            }
                        }
                        out
                    })
                };
                for pairs in &scored {
                    for &(a, b, d) in pairs {
                        if lists[a as usize].offer(Neighbor { dist: d, id: b }) {
                            updates += 1;
                        }
                        if lists[b as usize].offer(Neighbor { dist: d, id: a }) {
                            updates += 1;
                        }
                    }
                }
                start = end;
            }
            if updates == 0 {
                break; // converged
            }
        }

        // Convert to a searchable graph: optional diversity prune (a pure
        // per-node function of the final lists — fanned out), then add
        // reverse edges up to the degree cap (serial, order-dependent).
        let mut adj = FlatAdj::new(n, params.degree);
        let kept_ids: Vec<Vec<u32>> = {
            let frozen = &lists;
            let (prune, degree) = (params.prune, params.degree);
            parallel_map(n, threads, move |u| {
                let kept: Vec<Neighbor> = if prune {
                    select_heuristic(store, &frozen[u].items, degree)
                } else {
                    frozen[u].items.iter().take(degree).copied().collect()
                };
                kept.iter().map(|x| x.id).collect()
            })
        };
        for (u, ids) in kept_ids.iter().enumerate() {
            adj.set(u as u32, ids);
        }
        for u in 0..n as u32 {
            let nbs: Vec<u32> = adj.neighbors(u).to_vec();
            for v in nbs {
                if !adj.contains(v, u) {
                    adj.push(v, u); // best-effort; ignore overflow
                }
            }
        }

        let entry_probes: Vec<u32> = (0..16.min(n)).map(|_| rng.gen_range(n) as u32).collect();
        NnDescent {
            params,
            adj,
            entry_probes,
        }
    }

    /// Beam search from the nearest entry probe; honors `params.patience`
    /// and `params.scalar_kernels`.
    pub fn search(
        &self,
        store: &VectorStore,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        // Nearest probe as the entry point.
        let mut entry = self.entry_probes[0];
        let mut best = f32::INFINITY;
        for &p in &self.entry_probes {
            let d = l2_sq(q, store.row_logical(p as usize));
            if d < best {
                best = d;
                entry = p;
            }
        }
        if ctx.stats_enabled {
            ctx.stats.dist_calls += self.entry_probes.len() as u64;
        }
        let ef = params.beam_width();
        let mut res = match params.patience {
            Some(p) => beam_search_early_term(store, &self.adj, entry, q, ef, p, ctx),
            None => beam_search_filtered(
                store,
                &self.adj,
                entry,
                q,
                ef,
                &AllLive,
                !params.scalar_kernels,
                ctx,
            ),
        };
        res.truncate(params.k);
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;

    #[test]
    fn knn_list_bounded_and_sorted() {
        let mut l = KnnList::new(3);
        for (d, id) in [(5.0, 1u32), (2.0, 2), (9.0, 3), (1.0, 4), (3.0, 5)] {
            l.offer(Neighbor { dist: d, id });
        }
        assert_eq!(l.items.len(), 3);
        let ids: Vec<u32> = l.items.iter().map(|x| x.id).collect();
        assert_eq!(ids, vec![4, 2, 5]);
    }

    #[test]
    fn knn_list_rejects_duplicates() {
        let mut l = KnnList::new(2);
        assert!(l.offer(Neighbor { dist: 1.0, id: 7 }));
        assert!(!l.offer(Neighbor { dist: 0.5, id: 7 }));
    }

    #[test]
    fn reasonable_recall_on_tiny() {
        let ds = tiny(31, 600, 16, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let g = NnDescent::build_with_store(&store, NnDescentParams::default());
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10).with_ef(80);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = g.search(&store, ds.queries.row(qi), &params, &mut ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        let avg = total / ds.queries.rows() as f64;
        assert!(avg > 0.8, "recall@10 = {avg}");
    }

    #[test]
    fn degrees_bounded() {
        let ds = tiny(32, 300, 8, Metric::L2);
        let p = NnDescentParams { degree: 10, ..Default::default() };
        let g = NnDescent::build(&ds.data, p);
        for u in 0..ds.data.rows() as u32 {
            assert!(g.adj.degree(u) <= 10);
        }
    }
}
