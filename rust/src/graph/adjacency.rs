//! Flat fixed-capacity adjacency storage. One contiguous `u32` buffer with
//! a per-node length; cache-friendly neighbor iteration and stable edge
//! slots, which the FINGER index keys its per-edge arrays on.

/// Fixed-capacity flat adjacency list.
#[derive(Clone, Debug)]
pub struct FlatAdj {
    neighbors: Vec<u32>,
    len: Vec<u32>,
    cap: usize,
}

impl FlatAdj {
    pub fn new(n: usize, cap: usize) -> Self {
        Self {
            neighbors: vec![u32::MAX; n * cap],
            len: vec![0; n],
            cap,
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.len.len()
    }

    #[inline]
    pub fn cap(&self) -> usize {
        self.cap
    }

    #[inline]
    pub fn degree(&self, u: u32) -> usize {
        self.len[u as usize] as usize
    }

    #[inline]
    pub fn neighbors(&self, u: u32) -> &[u32] {
        let base = u as usize * self.cap;
        &self.neighbors[base..base + self.degree(u)]
    }

    /// Stable global slot index of edge (u, j) — position j in u's list.
    #[inline]
    pub fn edge_slot(&self, u: u32, j: usize) -> usize {
        u as usize * self.cap + j
    }

    /// Total edge slots (n * cap) — sizing for per-edge side arrays.
    #[inline]
    pub fn total_slots(&self) -> usize {
        self.neighbors.len()
    }

    /// Append a neighbor; returns false if at capacity.
    pub fn push(&mut self, u: u32, v: u32) -> bool {
        let d = self.degree(u);
        if d >= self.cap {
            return false;
        }
        self.neighbors[u as usize * self.cap + d] = v;
        self.len[u as usize] = (d + 1) as u32;
        true
    }

    /// Replace u's neighbor list (truncated at capacity).
    pub fn set(&mut self, u: u32, list: &[u32]) {
        let k = list.len().min(self.cap);
        let base = u as usize * self.cap;
        self.neighbors[base..base + k].copy_from_slice(&list[..k]);
        self.len[u as usize] = k as u32;
    }

    /// Does u already link to v?
    pub fn contains(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).contains(&v)
    }

    /// Append one node with an empty neighbor list (online insertion).
    /// Its `cap` slots land at the tail of the buffer, so every existing
    /// edge slot — and the FINGER per-edge tables keyed on them — stays
    /// stable.
    pub fn add_node(&mut self) {
        self.neighbors.resize(self.neighbors.len() + self.cap, u32::MAX);
        self.len.push(0);
    }

    /// Total directed edge count.
    pub fn num_edges(&self) -> usize {
        self.len.iter().map(|&l| l as usize).sum()
    }

    /// Memory footprint in bytes.
    pub fn nbytes(&self) -> usize {
        self.neighbors.len() * 4 + self.len.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_read() {
        let mut a = FlatAdj::new(3, 2);
        assert!(a.push(0, 1));
        assert!(a.push(0, 2));
        assert!(!a.push(0, 1), "capacity respected");
        assert_eq!(a.neighbors(0), &[1, 2]);
        assert_eq!(a.neighbors(1), &[] as &[u32]);
        assert_eq!(a.num_edges(), 2);
    }

    #[test]
    fn set_replaces_and_truncates() {
        let mut a = FlatAdj::new(2, 3);
        a.set(1, &[5, 6, 7, 8]);
        assert_eq!(a.neighbors(1), &[5, 6, 7]);
        a.set(1, &[9]);
        assert_eq!(a.neighbors(1), &[9]);
    }

    #[test]
    fn add_node_keeps_existing_slots() {
        let mut a = FlatAdj::new(2, 3);
        a.set(0, &[1]);
        a.set(1, &[0]);
        let slot0 = a.edge_slot(0, 0);
        a.add_node();
        assert_eq!(a.n(), 3);
        assert_eq!(a.degree(2), 0);
        assert_eq!(a.edge_slot(0, 0), slot0, "old slots unchanged");
        assert_eq!(a.total_slots(), 9);
        assert!(a.push(2, 0));
        assert_eq!(a.neighbors(2), &[0]);
    }

    #[test]
    fn edge_slots_are_stable_and_disjoint() {
        let a = FlatAdj::new(4, 3);
        let mut seen = std::collections::HashSet::new();
        for u in 0..4u32 {
            for j in 0..3 {
                assert!(seen.insert(a.edge_slot(u, j)));
            }
        }
        assert_eq!(a.total_slots(), 12);
    }
}
