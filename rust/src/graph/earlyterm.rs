//! Adaptive early-termination baseline (after Li et al., SIGMOD 2020 —
//! cited as [30] in the FINGER paper's §3.1). Where FINGER cheapens the
//! wasted distance computations, early termination tries to *stop* the
//! search once progress stalls: the search ends after `patience`
//! consecutive node expansions that fail to improve the top-k upper
//! bound. We use a fixed patience (the paper's learned predictor is
//! approximated by its best static setting) — it is the natural
//! alternative strategy to FINGER and a useful comparison series.

use std::collections::BinaryHeap;

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::graph::adjacency::FlatAdj;
use crate::graph::search::{MinNeighbor, Neighbor, SearchStats};
use crate::graph::visited::VisitedSet;

/// Beam search with early termination after `patience` non-improving
/// expansions (Algorithm 1 + stall counter).
#[allow(clippy::too_many_arguments)]
pub fn beam_search_early_term(
    data: &Matrix,
    adj: &FlatAdj,
    entry: u32,
    q: &[f32],
    ef: usize,
    patience: usize,
    visited: &mut VisitedSet,
    mut stats: Option<&mut SearchStats>,
) -> Vec<Neighbor> {
    visited.clear();
    visited.insert(entry);
    let d0 = l2_sq(q, data.row(entry as usize));
    if let Some(s) = stats.as_deref_mut() {
        s.dist_calls += 1;
    }
    let mut cands = BinaryHeap::new();
    let mut top: BinaryHeap<Neighbor> = BinaryHeap::new();
    cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    top.push(Neighbor { dist: d0, id: entry });

    let mut stall = 0usize;
    while let Some(MinNeighbor(cur)) = cands.pop() {
        let ub = top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && top.len() >= ef {
            break;
        }
        if stall >= patience && top.len() >= ef {
            break; // early termination: no progress for `patience` hops
        }
        if let Some(s) = stats.as_deref_mut() {
            s.hops += 1;
        }
        let ub_before = top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        let mut improved = false;
        for &nb in adj.neighbors(cur.id) {
            if !visited.insert(nb) {
                continue;
            }
            let d = l2_sq(q, data.row(nb as usize));
            let ub_now = top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
            let full = top.len() >= ef;
            if let Some(s) = stats.as_deref_mut() {
                s.record(0, full && d > ub_now);
            }
            if !full || d < ub_now {
                cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                top.push(Neighbor { dist: d, id: nb });
                if top.len() > ef {
                    top.pop();
                }
                if top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY) < ub_before {
                    improved = true;
                }
            }
        }
        if improved {
            stall = 0;
        } else {
            stall += 1;
        }
    }
    let mut out: Vec<Neighbor> = top.into_vec();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;
    use crate::eval::recall::recall;
    use crate::graph::hnsw::{Hnsw, HnswParams};

    #[test]
    fn early_termination_trades_recall_for_speed() {
        let ds = tiny(501, 800, 32, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 12, ef_construction: 80, ..Default::default() });
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut vis = VisitedSet::new(ds.data.rows());

        let run = |patience: usize| {
            let mut stats = SearchStats::default();
            let mut rec = 0.0;
            let mut vis = VisitedSet::new(ds.data.rows());
            for qi in 0..ds.queries.rows() {
                let res = beam_search_early_term(
                    &ds.data, &h.base, h.entry, ds.queries.row(qi), 64, patience, &mut vis,
                    Some(&mut stats),
                );
                rec += recall(&res[..res.len().min(10)], &gt[qi]);
            }
            (rec / ds.queries.rows() as f64, stats.dist_calls)
        };

        let (rec_tight, calls_tight) = run(2);
        let (rec_loose, calls_loose) = run(1000); // effectively no early stop
        assert!(calls_tight < calls_loose, "{calls_tight} vs {calls_loose}");
        assert!(rec_loose >= rec_tight - 1e-9);
        assert!(rec_tight > 0.5, "patience=2 recall collapsed: {rec_tight}");
        let _ = &mut vis;
    }

    #[test]
    fn huge_patience_equals_plain_beam() {
        let ds = tiny(502, 300, 16, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let mut vis = VisitedSet::new(ds.data.rows());
        for qi in 0..5 {
            let q = ds.queries.row(qi);
            let a = beam_search_early_term(&ds.data, &h.base, h.entry, q, 32, usize::MAX, &mut vis, None);
            let b = crate::graph::search::beam_search(&ds.data, &h.base, h.entry, q, 32, &mut vis, None);
            let ai: Vec<u32> = a.iter().map(|n| n.id).collect();
            let bi: Vec<u32> = b.iter().map(|n| n.id).collect();
            assert_eq!(ai, bi, "query {qi}");
        }
    }
}
