//! Adaptive early-termination baseline (after Li et al., SIGMOD 2020 —
//! cited as [30] in the FINGER paper's §3.1). Where FINGER cheapens the
//! wasted distance computations, early termination tries to *stop* the
//! search once progress stalls: the search ends after `patience`
//! consecutive node expansions that fail to improve the top-k upper
//! bound. We use a fixed patience (the paper's learned predictor is
//! approximated by its best static setting) — it is the natural
//! alternative strategy to FINGER and a useful comparison series. Reach it
//! uniformly via `SearchParams::with_patience` on any graph family.
//! Scoring is deliberately scalar: this is a baseline, and the stall
//! counter is defined over per-neighbor admissions.

use crate::core::distance::l2_sq;
use crate::core::store::VectorStore;
use crate::graph::adjacency::FlatAdj;
use crate::graph::search::{MinNeighbor, Neighbor};
use crate::index::context::SearchContext;

/// Beam search with early termination after `patience` non-improving
/// expansions (Algorithm 1 + stall counter).
pub fn beam_search_early_term(
    store: &VectorStore,
    adj: &FlatAdj,
    entry: u32,
    q: &[f32],
    ef: usize,
    patience: usize,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(store.rows());
    ctx.visited.insert(entry);
    let d0 = l2_sq(q, store.row_logical(entry as usize));
    if ctx.stats_enabled {
        ctx.stats.dist_calls += 1;
    }
    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    ctx.top.push(Neighbor { dist: d0, id: entry });

    let mut stall = 0usize;
    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break;
        }
        if stall >= patience && ctx.top.len() >= ef {
            break; // early termination: no progress for `patience` hops
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }
        let ub_before = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        let mut improved = false;
        for &nb in adj.neighbors(cur.id) {
            if !ctx.visited.insert(nb) {
                continue;
            }
            let d = l2_sq(q, store.row_logical(nb as usize));
            let ub_now = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
            let full = ctx.top.len() >= ef;
            if ctx.stats_enabled {
                ctx.stats.record(0, full && d > ub_now);
            }
            if !full || d < ub_now {
                ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                ctx.top.push(Neighbor { dist: d, id: nb });
                if ctx.top.len() > ef {
                    ctx.top.pop();
                }
                if ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY) < ub_before {
                    improved = true;
                }
            }
        }
        if improved {
            stall = 0;
        } else {
            stall += 1;
        }
    }
    ctx.drain_top()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;
    use crate::eval::recall::recall;
    use crate::graph::hnsw::{Hnsw, HnswParams};
    use crate::index::context::SearchParams;

    #[test]
    fn early_termination_trades_recall_for_speed() {
        let ds = tiny(501, 800, 32, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build(&ds.data, HnswParams { m: 12, ef_construction: 80, ..Default::default() });
        let gt = exact_knn(&ds.data, &ds.queries, 10);

        let run = |patience: usize| {
            let mut ctx = SearchContext::new().with_stats();
            let mut rec = 0.0;
            for qi in 0..ds.queries.rows() {
                let res = beam_search_early_term(
                    &store, &h.base, h.entry, ds.queries.row(qi), 64, patience, &mut ctx,
                );
                rec += recall(&res[..res.len().min(10)], &gt[qi]);
            }
            (rec / ds.queries.rows() as f64, ctx.stats.dist_calls)
        };

        let (rec_tight, calls_tight) = run(2);
        let (rec_loose, calls_loose) = run(1000); // effectively no early stop
        assert!(calls_tight < calls_loose, "{calls_tight} vs {calls_loose}");
        assert!(rec_loose >= rec_tight - 1e-9);
        assert!(rec_tight > 0.5, "patience=2 recall collapsed: {rec_tight}");
    }

    #[test]
    fn huge_patience_equals_plain_beam() {
        let ds = tiny(502, 300, 16, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let mut ctx = SearchContext::new();
        for qi in 0..5 {
            let q = ds.queries.row(qi);
            let a = beam_search_early_term(&store, &h.base, h.entry, q, 32, usize::MAX, &mut ctx);
            let b = crate::graph::search::beam_search(&store, &h.base, h.entry, q, 32, &mut ctx);
            let ai: Vec<u32> = a.iter().map(|n| n.id).collect();
            let bi: Vec<u32> = b.iter().map(|n| n.id).collect();
            assert_eq!(ai, bi, "query {qi}");
        }
    }

    #[test]
    fn patience_reachable_through_params() {
        let ds = tiny(503, 400, 16, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 40, ..Default::default() });
        let mut ctx = SearchContext::new().with_stats();
        let plain = SearchParams::new(10).with_ef(64);
        h.search(&store, ds.queries.row(0), &plain, &mut ctx);
        let calls_plain = ctx.take_stats().dist_calls;
        let tight = SearchParams::new(10).with_ef(64).with_patience(1);
        h.search(&store, ds.queries.row(0), &tight, &mut ctx);
        let calls_tight = ctx.take_stats().dist_calls;
        assert!(calls_tight <= calls_plain);
    }
}
