//! Brute-force exact scan — baseline and correctness anchor.

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::graph::search::Neighbor;
use crate::index::mutable::LiveIds;

/// Exact top-k by linear scan (single query).
pub fn scan(data: &Matrix, q: &[f32], k: usize) -> Vec<Neighbor> {
    let k = k.min(data.rows());
    let mut best: Vec<Neighbor> = Vec::with_capacity(k + 1);
    let mut worst = f32::INFINITY;
    for i in 0..data.rows() {
        let d = l2_sq(q, data.row(i));
        if best.len() < k {
            best.push(Neighbor { dist: d, id: i as u32 });
            best.sort();
            worst = best.last().unwrap().dist;
        } else if d < worst {
            *best.last_mut().unwrap() = Neighbor { dist: d, id: i as u32 };
            best.sort();
            worst = best.last().unwrap().dist;
        }
    }
    best
}

/// Exact top-k over the live rows only, emitting **external** ids.
/// Tie-breaking matches [`scan`] exactly: candidates are ordered by
/// `(dist, row)` during the scan and rows are remapped to external ids at
/// the end — the remap is monotone (`LiveIds` keeps its map ascending), so
/// the result order equals a scan ordered by `(dist, external id)`.
pub fn scan_live(data: &Matrix, q: &[f32], k: usize, live: &LiveIds) -> Vec<Neighbor> {
    let k = k.min(live.live_len());
    let mut best: Vec<Neighbor> = Vec::with_capacity(k + 1);
    if k == 0 {
        return best;
    }
    let mut worst = f32::INFINITY;
    for row in 0..data.rows() {
        if live.is_dead_row(row) {
            continue;
        }
        let d = l2_sq(q, data.row(row));
        if best.len() < k {
            best.push(Neighbor { dist: d, id: row as u32 });
            best.sort();
            worst = best.last().unwrap().dist;
        } else if d < worst {
            *best.last_mut().unwrap() = Neighbor { dist: d, id: row as u32 };
            best.sort();
            worst = best.last().unwrap().dist;
        }
    }
    live.remap_rows_to_external(&mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    #[test]
    fn matches_full_sort() {
        let mut rng = Pcg32::new(1);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..200 {
            let row: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let q: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
        let got = scan(&data, &q, 7);
        let mut all: Vec<Neighbor> = (0..200)
            .map(|i| Neighbor { dist: l2_sq(&q, data.row(i)), id: i as u32 })
            .collect();
        all.sort();
        assert_eq!(got, all[..7].to_vec());
    }

    #[test]
    fn k_clamped_to_n() {
        let data = Matrix::from_rows(&[vec![1.0], vec![2.0]]);
        assert_eq!(scan(&data, &[0.0], 10).len(), 2);
    }

    #[test]
    fn scan_live_filters_and_remaps() {
        let data = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let mut live = LiveIds::fresh(4);
        // Fresh identity: equals the plain scan.
        assert_eq!(scan_live(&data, &[0.9], 2, &live), scan(&data, &[0.9], 2));
        // Tombstone the nearest row: runner-ups take over, dead id absent.
        live.kill_row(1);
        let got = scan_live(&data, &[0.9], 2, &live);
        let ids: Vec<u32> = got.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![0, 2]);
        // k clamps to the live count.
        live.kill_row(3);
        assert_eq!(scan_live(&data, &[0.9], 10, &live).len(), 2);
    }
}
