//! Brute-force exact scan — baseline and correctness anchor. Scans the
//! padded [`VectorStore`] 4 rows per kernel pass; admission stays in row
//! order, so results (ties included) are identical to a one-row-at-a-time
//! scan with the same kernel.

use crate::core::distance::{l2_sq, l2_sq_batch4};
use crate::core::store::VectorStore;
use crate::graph::search::Neighbor;
use crate::index::mutable::LiveIds;

/// Insert `(d, row)` into the bounded ascending best-list.
#[inline]
fn offer(best: &mut Vec<Neighbor>, worst: &mut f32, k: usize, d: f32, row: u32) {
    if best.len() < k {
        best.push(Neighbor { dist: d, id: row });
        best.sort();
        *worst = best.last().unwrap().dist;
    } else if d < *worst {
        *best.last_mut().unwrap() = Neighbor { dist: d, id: row };
        best.sort();
        *worst = best.last().unwrap().dist;
    }
}

/// Exact top-k by linear scan (single query), batched 4 rows per pass.
pub fn scan(store: &VectorStore, q: &[f32], k: usize) -> Vec<Neighbor> {
    let n = store.rows();
    let k = k.min(n);
    let mut best: Vec<Neighbor> = Vec::with_capacity(k + 1);
    if k == 0 {
        return best;
    }
    let mut qp = Vec::with_capacity(store.padded_cols());
    store.pad_query(q, &mut qp);
    let mut worst = f32::INFINITY;
    let mut i = 0;
    while i + 4 <= n {
        let d4 = l2_sq_batch4(
            &qp,
            store.row(i),
            store.row(i + 1),
            store.row(i + 2),
            store.row(i + 3),
        );
        for (t, &d) in d4.iter().enumerate() {
            offer(&mut best, &mut worst, k, d, (i + t) as u32);
        }
        i += 4;
    }
    for row in i..n {
        offer(&mut best, &mut worst, k, l2_sq(&qp, store.row(row)), row as u32);
    }
    best
}

/// Exact top-k over the live rows only, emitting **external** ids.
/// Tie-breaking matches [`scan`] exactly: candidates are ordered by
/// `(dist, row)` during the scan and rows are remapped to external ids at
/// the end — the remap is monotone (`LiveIds` keeps its map ascending), so
/// the result order equals a scan ordered by `(dist, external id)`.
pub fn scan_live(store: &VectorStore, q: &[f32], k: usize, live: &LiveIds) -> Vec<Neighbor> {
    let k = k.min(live.live_len());
    let mut best: Vec<Neighbor> = Vec::with_capacity(k + 1);
    if k == 0 {
        return best;
    }
    let mut qp = Vec::with_capacity(store.padded_cols());
    store.pad_query(q, &mut qp);
    let mut worst = f32::INFINITY;
    for row in 0..store.rows() {
        if live.is_dead_row(row) {
            continue;
        }
        offer(&mut best, &mut worst, k, l2_sq(&qp, store.row(row)), row as u32);
    }
    live.remap_rows_to_external(&mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::Matrix;
    use crate::core::rng::Pcg32;

    #[test]
    fn matches_full_sort() {
        let mut rng = Pcg32::new(1);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..200 {
            let row: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let store = VectorStore::from_matrix(&data);
        let q: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
        let got = scan(&store, &q, 7);
        let mut all: Vec<Neighbor> = (0..200)
            .map(|i| Neighbor { dist: l2_sq(&q, data.row(i)), id: i as u32 })
            .collect();
        all.sort();
        assert_eq!(got, all[..7].to_vec());
    }

    #[test]
    fn batched_scan_handles_ties_and_tails() {
        // Duplicate rows force distance ties across 4-row batch borders;
        // n not a multiple of 4 exercises the scalar remainder.
        let mut data = Matrix::zeros(0, 3);
        for i in 0..11 {
            data.push_row(&[(i % 4) as f32, 0.0, 0.0]);
        }
        let store = VectorStore::from_matrix(&data);
        let got = scan(&store, &[0.0, 0.0, 0.0], 5);
        let ids: Vec<u32> = got.iter().map(|n| n.id).collect();
        // dist 0: rows 0,4,8 (ascending ids); dist 1: rows 1,5.
        assert_eq!(ids, vec![0, 4, 8, 1, 5]);
    }

    #[test]
    fn k_clamped_to_n() {
        let data = Matrix::from_rows(&[vec![1.0], vec![2.0]]);
        let store = VectorStore::from_matrix(&data);
        assert_eq!(scan(&store, &[0.0], 10).len(), 2);
    }

    #[test]
    fn scan_live_filters_and_remaps() {
        let data = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let store = VectorStore::from_matrix(&data);
        let mut live = LiveIds::fresh(4);
        // Fresh identity: equals the plain scan.
        assert_eq!(scan_live(&store, &[0.9], 2, &live), scan(&store, &[0.9], 2));
        // Tombstone the nearest row: runner-ups take over, dead id absent.
        live.kill_row(1);
        let got = scan_live(&store, &[0.9], 2, &live);
        let ids: Vec<u32> = got.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![0, 2]);
        // k clamps to the live count.
        live.kill_row(3);
        assert_eq!(scan_live(&store, &[0.9], 10, &live).len(), 2);
    }
}
