//! Brute-force exact scan — baseline and correctness anchor.

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::graph::search::Neighbor;

/// Exact top-k by linear scan (single query).
pub fn scan(data: &Matrix, q: &[f32], k: usize) -> Vec<Neighbor> {
    let k = k.min(data.rows());
    let mut best: Vec<Neighbor> = Vec::with_capacity(k + 1);
    let mut worst = f32::INFINITY;
    for i in 0..data.rows() {
        let d = l2_sq(q, data.row(i));
        if best.len() < k {
            best.push(Neighbor { dist: d, id: i as u32 });
            best.sort();
            worst = best.last().unwrap().dist;
        } else if d < worst {
            *best.last_mut().unwrap() = Neighbor { dist: d, id: i as u32 };
            best.sort();
            worst = best.last().unwrap().dist;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    #[test]
    fn matches_full_sort() {
        let mut rng = Pcg32::new(1);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..200 {
            let row: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let q: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
        let got = scan(&data, &q, 7);
        let mut all: Vec<Neighbor> = (0..200)
            .map(|i| Neighbor { dist: l2_sq(&q, data.row(i)), id: i as u32 })
            .collect();
        all.sort();
        assert_eq!(got, all[..7].to_vec());
    }

    #[test]
    fn k_clamped_to_n() {
        let data = Matrix::from_rows(&[vec![1.0], vec![2.0]]);
        assert_eq!(scan(&data, &[0.0], 10).len(), 2);
    }
}
