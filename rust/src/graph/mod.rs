//! Graph substrates: adjacency storage, Algorithm 1 search, and the three
//! graph-construction families the paper benchmarks (HNSW, Vamana,
//! NN-descent) plus brute force.

pub mod adjacency;
pub mod bruteforce;
pub mod earlyterm;
pub mod hnsw;
pub mod nndescent;
pub mod search;
pub mod vamana;
pub mod visited;

pub use adjacency::FlatAdj;
pub use search::{MinNeighbor, Neighbor, SearchStats};
pub use visited::VisitedSet;
