//! Algorithm 1: greedy best-first graph search, with the instrumentation
//! that produces the paper's Figure 2 observation (what fraction of
//! distance computations exceed the current upper bound and therefore
//! cannot influence the search).
//!
//! All searches run over a pooled [`SearchContext`] (visited set + both
//! heaps + stats) and score against a padded, aligned
//! [`VectorStore`] — no per-query heap allocation and no tail loops in
//! the distance kernels.
//!
//! There is exactly **one** copy of the hot loop,
//! [`beam_search_filtered`], generic over a [`LiveFilter`] (the
//! tombstone-aware online variant is the same code with a bitset filter
//! at result emission) and switchable between scalar and 4-row-batched
//! scoring. The two scoring modes make identical admission decisions —
//! the batched kernels return bitwise-equal distances and admissions are
//! applied sequentially against the same evolving upper bound — so their
//! result streams (and stats) are bitwise identical; `rust/tests/
//! ann_index.rs` pins this end to end.

use std::cmp::Ordering;

use crate::core::distance::{l2_sq, l2_sq_batch4, l2_sq_scalar, prefetch_l1};
use crate::core::store::VectorStore;
use crate::graph::adjacency::FlatAdj;
use crate::index::context::SearchContext;
use crate::index::mutable::LiveIds;

/// (distance, id) with max-heap ordering by distance.
///
/// Ordering is `f32::total_cmp`, so NaN distances (e.g. from corrupt input
/// vectors) sort deterministically *after* every real distance instead of
/// silently corrupting heap order the way `partial_cmp(..).unwrap_or(Equal)`
/// did — a NaN candidate can never shadow a real one at the heap top.
#[derive(Clone, Copy, Debug)]
pub struct Neighbor {
    pub dist: f32,
    pub id: u32,
}

/// Equality must agree with `Ord` (total order), so it also goes through
/// `total_cmp` — two NaN-distance neighbors with the same id are equal.
impl PartialEq for Neighbor {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Neighbor {}

impl Ord for Neighbor {
    fn cmp(&self, other: &Self) -> Ordering {
        self.dist
            .total_cmp(&other.dist)
            .then_with(|| self.id.cmp(&other.id))
    }
}

impl PartialOrd for Neighbor {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap adapter. The single source of ordering truth is
/// [`Neighbor::cmp`]; this only flips the operand order, so the two heaps
/// can never disagree on how ties or NaNs rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MinNeighbor(pub Neighbor);

impl Ord for MinNeighbor {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.cmp(&self.0)
    }
}

impl PartialOrd for MinNeighbor {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-search instrumentation. `per_hop` buckets (total, non-influential)
/// distance-computation counts by node-expansion index — Figure 2's x-axis.
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    /// Full (m-dimensional) distance computations.
    pub dist_calls: u64,
    /// Approximate (r-dimensional) computations (FINGER path only).
    pub approx_calls: u64,
    /// Distance computations that exceeded the upper bound while the top
    /// queue was full (could not influence results).
    pub wasted: u64,
    /// Node expansions.
    pub hops: u64,
    /// (total, wasted) full-distance counts per expansion index.
    pub per_hop: Vec<(u64, u64)>,
}

impl SearchStats {
    /// Record one full-distance computation at expansion index `hop`.
    /// Every screened/filtered search path counts through here so
    /// `per_hop`/`wasted` (the Figure 2 data) stay populated uniformly.
    pub fn record(&mut self, hop: usize, wasted: bool) {
        self.dist_calls += 1;
        if self.per_hop.len() <= hop {
            self.per_hop.resize(hop + 1, (0, 0));
        }
        self.per_hop[hop].0 += 1;
        if wasted {
            self.wasted += 1;
            self.per_hop[hop].1 += 1;
        }
    }

    /// Record one approximate (rank-r) scoring — the FINGER screening
    /// counterpart of [`SearchStats::record`].
    pub fn record_approx(&mut self) {
        self.approx_calls += 1;
    }

    pub fn merge(&mut self, other: &SearchStats) {
        self.dist_calls += other.dist_calls;
        self.approx_calls += other.approx_calls;
        self.wasted += other.wasted;
        self.hops += other.hops;
        if self.per_hop.len() < other.per_hop.len() {
            self.per_hop.resize(other.per_hop.len(), (0, 0));
        }
        for (i, &(t, w)) in other.per_hop.iter().enumerate() {
            self.per_hop[i].0 += t;
            self.per_hop[i].1 += w;
        }
    }

    /// Effective number of full-distance calls given approximation rank r
    /// and data dimension m (the paper's Figure 6 x-axis: a + b·r/m).
    pub fn effective_dist_calls(&self, r: usize, m: usize) -> f64 {
        self.dist_calls as f64 + self.approx_calls as f64 * (r as f64 / m as f64)
    }
}

/// Which rows may be *emitted* (admitted to the top-results queue).
/// Traversal ignores it — dead nodes keep routing, live filtering happens
/// at emission only, so connectivity through tombstones survives.
pub trait LiveFilter {
    fn emits(&self, row: u32) -> bool;
}

/// Every row emits (the static-index case); optimizes out entirely.
pub struct AllLive;

impl LiveFilter for AllLive {
    #[inline]
    fn emits(&self, _row: u32) -> bool {
        true
    }
}

impl LiveFilter for LiveIds {
    #[inline]
    fn emits(&self, row: u32) -> bool {
        !self.is_dead_row(row as usize)
    }
}

/// Greedy best-first search (Algorithm 1) over one adjacency layer —
/// the single hot loop behind [`beam_search`], [`beam_search_live`], and
/// the scalar-kernel mode of both.
///
/// Per expanded node the unvisited neighbors are gathered first, then
/// scored — in blocks of 4 via [`l2_sq_batch4`] when `batched`, one at a
/// time otherwise — and finally admitted sequentially against a locally
/// cached upper bound (refreshed only when the top queue actually
/// changes, instead of a `peek` per neighbor). Because the batch kernel
/// is bitwise-equal to the scalar kernel per row and admission order is
/// unchanged, both modes produce identical result streams and stats.
#[allow(clippy::too_many_arguments)]
pub fn beam_search_filtered<F: LiveFilter + ?Sized>(
    store: &VectorStore,
    adj: &FlatAdj,
    entry: u32,
    q: &[f32],
    ef: usize,
    filter: &F,
    batched: bool,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(store.rows());
    // Pooled scratch, taken out so the heaps stay borrowable through ctx.
    let mut qp = std::mem::take(&mut ctx.qbuf);
    let mut block = std::mem::take(&mut ctx.block);
    let mut dists = std::mem::take(&mut ctx.dists);
    store.pad_query(q, &mut qp);

    // Unbatched mode doubles as the full fallback: it scores through the
    // portable scalar kernels directly, bypassing the SIMD dispatch
    // (bitwise-identical results either way — that is the contract).
    let exact: fn(&[f32], &[f32]) -> f32 = if batched { l2_sq } else { l2_sq_scalar };

    ctx.visited.insert(entry);
    let d0 = exact(&qp, store.row(entry as usize));
    if ctx.stats_enabled {
        ctx.stats.dist_calls += 1;
    }
    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    if filter.emits(entry) {
        ctx.top.push(Neighbor { dist: d0, id: entry });
    }

    let mut hop = 0usize;
    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let mut ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break; // Algorithm 1 line 5: nearest candidate beyond the bound
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }

        // Phase 1: gather this node's unvisited neighbors.
        block.clear();
        for &nb in adj.neighbors(cur.id) {
            if ctx.visited.insert(nb) {
                block.push(nb);
            }
        }

        // Phase 2: score the block (distances do not depend on admission
        // order, so they batch freely).
        dists.clear();
        if batched {
            let mut i = 0;
            while i + 4 <= block.len() {
                // Prefetch: start the next block's cache lines toward L1
                // while this block's FMAs retire (`prefetcht0` /
                // `prfm pldl1keep` behind the kernel dispatch).
                if i + 8 <= block.len() {
                    for t in i + 4..i + 8 {
                        prefetch_l1(store.row(block[t] as usize).as_ptr());
                    }
                }
                let d4 = l2_sq_batch4(
                    &qp,
                    store.row(block[i] as usize),
                    store.row(block[i + 1] as usize),
                    store.row(block[i + 2] as usize),
                    store.row(block[i + 3] as usize),
                );
                dists.extend_from_slice(&d4);
                i += 4;
            }
            for &nb in &block[i..] {
                dists.push(exact(&qp, store.row(nb as usize)));
            }
        } else {
            for &nb in &block[..] {
                dists.push(exact(&qp, store.row(nb as usize)));
            }
        }

        // Phase 3: sequential admission — identical decisions to the
        // one-at-a-time loop, with the upper bound kept in a local that is
        // refreshed only when the top queue changes.
        for (j, &nb) in block.iter().enumerate() {
            let d = dists[j];
            let full = ctx.top.len() >= ef;
            if ctx.stats_enabled {
                ctx.stats.record(hop, full && d > ub);
            }
            if !full || d < ub {
                ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                if filter.emits(nb) {
                    ctx.top.push(Neighbor { dist: d, id: nb });
                    if ctx.top.len() > ef {
                        ctx.top.pop();
                    }
                    ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
                }
            }
        }
        hop += 1;
    }

    ctx.qbuf = qp;
    ctx.block = block;
    ctx.dists = dists;
    ctx.drain_top()
}

/// Per-query approximate scorer the quantized beam search traverses on:
/// one call per candidate row, returning a distance *surrogate* that is
/// monotone-comparable across rows (SQ8 rescaled integer L2, PQ ADC
/// lookups). Implementations hold their own per-query state (encoded
/// query codes / ADC table), built once before the beam starts.
pub trait ApproxScorer {
    fn dist(&mut self, row: usize) -> f32;
}

/// Quantized variant of [`beam_search_filtered`]: the beam is driven by
/// [`ApproxScorer`] distances (counted as `approx_calls`), full-precision
/// rows are never touched in the loop. Admission logic is byte-identical
/// to the exact core — same heaps, same upper-bound refresh, same
/// tie-break through [`Neighbor`] total order — so for a fixed scorer the
/// result stream is deterministic across kernels and thread counts.
/// Callers restore exact ordering with [`rerank_exact`] over the full
/// returned pool.
#[allow(clippy::too_many_arguments)]
pub fn beam_search_approx_filtered<F: LiveFilter + ?Sized, S: ApproxScorer>(
    n_rows: usize,
    adj: &FlatAdj,
    entry: u32,
    ef: usize,
    filter: &F,
    scorer: &mut S,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    ctx.begin(n_rows);
    let mut block = std::mem::take(&mut ctx.block);

    ctx.visited.insert(entry);
    let d0 = scorer.dist(entry as usize);
    if ctx.stats_enabled {
        ctx.stats.record_approx();
    }
    ctx.cands.push(MinNeighbor(Neighbor { dist: d0, id: entry }));
    if filter.emits(entry) {
        ctx.top.push(Neighbor { dist: d0, id: entry });
    }

    while let Some(MinNeighbor(cur)) = ctx.cands.pop() {
        let mut ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
        if cur.dist > ub && ctx.top.len() >= ef {
            break;
        }
        if ctx.stats_enabled {
            ctx.stats.hops += 1;
        }

        block.clear();
        for &nb in adj.neighbors(cur.id) {
            if ctx.visited.insert(nb) {
                block.push(nb);
            }
        }

        for &nb in &block[..] {
            let d = scorer.dist(nb as usize);
            if ctx.stats_enabled {
                ctx.stats.record_approx();
            }
            let full = ctx.top.len() >= ef;
            if !full || d < ub {
                ctx.cands.push(MinNeighbor(Neighbor { dist: d, id: nb }));
                if filter.emits(nb) {
                    ctx.top.push(Neighbor { dist: d, id: nb });
                    if ctx.top.len() > ef {
                        ctx.top.pop();
                    }
                    ub = ctx.top.peek().map(|n| n.dist).unwrap_or(f32::INFINITY);
                }
            }
        }
    }

    ctx.block = block;
    ctx.drain_top()
}

/// The re-rank half of the quantized-traversal contract: rescore *every*
/// candidate the approximate beam returned with the exact f32 kernel
/// (counted as `dist_calls`), then restore [`Neighbor`] total order. The
/// pool is re-ranked in full — never pre-truncated on approximate
/// distances — so a candidate mis-ranked by quantization can still win;
/// callers truncate to `k` afterwards. `qp` must be padded to the store
/// stride (see `VectorStore::pad_query`).
pub fn rerank_exact(
    store: &VectorStore,
    qp: &[f32],
    cands: &mut Vec<Neighbor>,
    batched: bool,
    ctx: &mut SearchContext,
) {
    let exact: fn(&[f32], &[f32]) -> f32 = if batched { l2_sq } else { l2_sq_scalar };
    for nb in cands.iter_mut() {
        nb.dist = exact(qp, store.row(nb.id as usize));
    }
    if ctx.stats_enabled {
        ctx.stats.dist_calls += cands.len() as u64;
    }
    cands.sort();
}

/// Greedy best-first search (Algorithm 1) over one adjacency layer.
/// Returns up to `ef` nearest (ascending). `entry` must be a valid node.
pub fn beam_search(
    store: &VectorStore,
    adj: &FlatAdj,
    entry: u32,
    q: &[f32],
    ef: usize,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    beam_search_filtered(store, adj, entry, q, ef, &AllLive, true, ctx)
}

/// Tombstone-aware beam search (the online-update variant of Algorithm 1):
/// deleted nodes are *traversed* — they stay in the candidate queue so
/// graph connectivity through them survives — but never *emitted*: the
/// top-results queue only ever admits live rows, so a deleted id cannot
/// appear in the output, and the upper bound driving termination comes
/// from live results only. Returns up to `ef` nearest live rows
/// (ascending), still in the graph's row id space — callers remap rows to
/// external ids.
pub fn beam_search_live(
    store: &VectorStore,
    adj: &FlatAdj,
    entry: u32,
    q: &[f32],
    ef: usize,
    live: &LiveIds,
    ctx: &mut SearchContext,
) -> Vec<Neighbor> {
    beam_search_filtered(store, adj, entry, q, ef, live, true, ctx)
}

/// Greedy descent: walk to the locally nearest node (ef = 1). Used for
/// HNSW upper layers (tiny — scalar scoring is fine there).
pub fn greedy_descent(
    store: &VectorStore,
    adj: &FlatAdj,
    entry: u32,
    q: &[f32],
    ctx: &mut SearchContext,
) -> Neighbor {
    let mut cur = Neighbor {
        dist: l2_sq(q, store.row_logical(entry as usize)),
        id: entry,
    };
    let mut calls = 1u64;
    loop {
        let mut improved = false;
        for &nb in adj.neighbors(cur.id) {
            let d = l2_sq(q, store.row_logical(nb as usize));
            calls += 1;
            if d < cur.dist {
                cur = Neighbor { dist: d, id: nb };
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    if ctx.stats_enabled {
        ctx.stats.dist_calls += calls;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::Matrix;
    use crate::core::rng::Pcg32;

    fn store_of(data: &Matrix) -> VectorStore {
        VectorStore::from_matrix(data)
    }

    /// Fully-connected small graph: beam search must find the exact NN.
    #[test]
    fn exact_on_complete_graph() {
        let mut rng = Pcg32::new(1);
        let n = 60;
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..n {
            let row: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let store = store_of(&data);
        let mut adj = FlatAdj::new(n, n - 1);
        for u in 0..n as u32 {
            for v in 0..n as u32 {
                if u != v {
                    adj.push(u, v);
                }
            }
        }
        let mut ctx = SearchContext::new();
        let q: Vec<f32> = (0..6).map(|_| rng.next_gaussian()).collect();
        let res = beam_search(&store, &adj, 0, &q, 5, &mut ctx);
        // Naive top-5
        let mut all: Vec<Neighbor> = (0..n)
            .map(|i| Neighbor {
                dist: l2_sq(&q, data.row(i)),
                id: i as u32,
            })
            .collect();
        all.sort();
        let want: Vec<u32> = all[..5].iter().map(|x| x.id).collect();
        let got: Vec<u32> = res[..5].iter().map(|x| x.id).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn results_sorted_ascending() {
        let mut rng = Pcg32::new(2);
        let n = 40;
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..n {
            data.push_row(&[rng.next_gaussian(), rng.next_gaussian()]);
        }
        let store = store_of(&data);
        let mut adj = FlatAdj::new(n, 6);
        for u in 0..n as u32 {
            for k in 1..=6u32 {
                adj.push(u, (u + k) % n as u32);
            }
        }
        let mut ctx = SearchContext::new();
        let res = beam_search(&store, &adj, 0, &[0.0, 0.0], 10, &mut ctx);
        for w in res.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
        assert!(res.len() <= 10);
    }

    /// The acceptance property at this layer: batched and scalar scoring
    /// return bitwise-identical (dist, id) streams — seeded random graphs,
    /// non-lane-multiple dims, a NaN row, and tombstones included.
    #[test]
    fn batched_and_scalar_streams_bitwise_identical() {
        for seed in [3u64, 4, 5] {
            let mut rng = Pcg32::new(seed);
            let n = 300;
            let dim = 13; // forces the lane-folded tail path
            let mut data = Matrix::zeros(0, 0);
            for _ in 0..n {
                let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
                data.push_row(&row);
            }
            data.row_mut(17)[4] = f32::NAN; // corrupt row must tie-break identically
            let store = store_of(&data);
            let mut adj = FlatAdj::new(n, 9);
            for u in 0..n as u32 {
                for k in 1..=9u32 {
                    adj.push(u, (u * 7 + k * 13) % n as u32);
                }
            }
            let mut live = LiveIds::fresh(n);
            live.kill_row(5);
            live.kill_row(42);
            let mut ctx = SearchContext::new().with_stats();
            for qi in 0..6 {
                let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
                for ef in [3usize, 16, 64] {
                    let b = beam_search_filtered(&store, &adj, 0, &q, ef, &AllLive, true, &mut ctx);
                    let sb = ctx.take_stats();
                    let s = beam_search_filtered(&store, &adj, 0, &q, ef, &AllLive, false, &mut ctx);
                    let ss = ctx.take_stats();
                    // Neighbor eq goes through total_cmp: equal streams are
                    // bitwise-equal distances and ids, NaN included.
                    assert_eq!(b, s, "seed {seed} q{qi} ef={ef}");
                    assert_eq!(sb.dist_calls, ss.dist_calls, "seed {seed} ef={ef}");
                    assert_eq!(sb.wasted, ss.wasted, "seed {seed} ef={ef}");
                    assert_eq!(sb.per_hop, ss.per_hop, "seed {seed} ef={ef}");
                    let bl = beam_search_filtered(&store, &adj, 0, &q, ef, &live, true, &mut ctx);
                    let sl = beam_search_filtered(&store, &adj, 0, &q, ef, &live, false, &mut ctx);
                    assert_eq!(bl, sl, "live seed {seed} q{qi} ef={ef}");
                }
            }
        }
    }

    #[test]
    fn live_beam_traverses_tombstones_but_never_emits_them() {
        // Path graph on a line: 0 - 1 - 2 - 3. Tombstone the middle node
        // 1; nodes 2 and 3 are only reachable through it.
        let data = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let store = store_of(&data);
        let mut adj = FlatAdj::new(4, 2);
        for u in 0..4u32 {
            if u > 0 {
                adj.push(u, u - 1);
            }
            if u < 3 {
                adj.push(u, u + 1);
            }
        }
        let mut live = LiveIds::fresh(4);
        live.kill_row(1);
        let mut ctx = SearchContext::new();
        let res = beam_search_live(&store, &adj, 0, &[1.0], 4, &live, &mut ctx);
        assert!(res.iter().all(|n| n.id != 1), "tombstoned id emitted");
        assert!(
            res.iter().any(|n| n.id == 2) && res.iter().any(|n| n.id == 3),
            "connectivity through the tombstone lost: {res:?}"
        );
        let ids: Vec<u32> = res.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![0, 2, 3], "(dist, id) ascending over live rows");
    }

    #[test]
    fn live_beam_with_nothing_dead_matches_plain() {
        let mut rng = Pcg32::new(11);
        let n = 80;
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..n {
            let row: Vec<f32> = (0..4).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let store = store_of(&data);
        let mut adj = FlatAdj::new(n, 6);
        for u in 0..n as u32 {
            for k in 1..=6u32 {
                adj.push(u, (u * 5 + k * 11) % n as u32);
            }
        }
        let live = LiveIds::fresh(n);
        let mut ctx = SearchContext::new();
        let q: Vec<f32> = (0..4).map(|_| rng.next_gaussian()).collect();
        let a = beam_search_live(&store, &adj, 0, &q, 8, &live, &mut ctx);
        let b = beam_search(&store, &adj, 0, &q, 8, &mut ctx);
        assert_eq!(a, b);
    }

    #[test]
    fn stats_count_wasted_computations() {
        let mut rng = Pcg32::new(3);
        let n = 200;
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..n {
            let row: Vec<f32> = (0..8).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let store = store_of(&data);
        let mut adj = FlatAdj::new(n, 8);
        for u in 0..n as u32 {
            for k in 1..=8u32 {
                adj.push(u, (u * 7 + k * 13) % n as u32);
            }
        }
        let mut ctx = SearchContext::new().with_stats();
        let q: Vec<f32> = (0..8).map(|_| rng.next_gaussian()).collect();
        beam_search(&store, &adj, 0, &q, 4, &mut ctx);
        let stats = ctx.take_stats();
        assert!(stats.dist_calls > 0);
        assert!(stats.hops > 0);
        assert!(stats.wasted <= stats.dist_calls);
        let bucket_total: u64 = stats.per_hop.iter().map(|x| x.0).sum();
        assert_eq!(bucket_total + 1, stats.dist_calls); // +1 for the entry
    }

    #[test]
    fn disabled_stats_stay_zero() {
        let data = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let store = store_of(&data);
        let mut adj = FlatAdj::new(3, 2);
        adj.push(0, 1);
        adj.push(1, 2);
        adj.push(2, 0);
        let mut ctx = SearchContext::new();
        beam_search(&store, &adj, 0, &[1.5], 2, &mut ctx);
        assert_eq!(ctx.stats.dist_calls, 0);
        assert_eq!(ctx.stats.hops, 0);
    }

    #[test]
    fn greedy_descent_reaches_local_min() {
        // A path graph embedded on a line: descent from one end must walk
        // toward the query's side.
        let n = 20;
        let mut data = Matrix::zeros(0, 0);
        for i in 0..n {
            data.push_row(&[i as f32]);
        }
        let store = store_of(&data);
        let mut adj = FlatAdj::new(n, 2);
        for u in 0..n as u32 {
            if u > 0 {
                adj.push(u, u - 1);
            }
            if (u as usize) < n - 1 {
                adj.push(u, u + 1);
            }
        }
        let mut ctx = SearchContext::new();
        let got = greedy_descent(&store, &adj, 0, &[17.2], &mut ctx);
        assert_eq!(got.id, 17);
    }

    #[test]
    fn effective_calls_formula() {
        let s = SearchStats {
            dist_calls: 100,
            approx_calls: 200,
            ..Default::default()
        };
        let eff = s.effective_dist_calls(16, 128);
        assert!((eff - (100.0 + 200.0 * 0.125)).abs() < 1e-9);
    }

    #[test]
    fn record_approx_counts() {
        let mut s = SearchStats::default();
        s.record_approx();
        s.record_approx();
        s.record(0, true);
        assert_eq!(s.approx_calls, 2);
        assert_eq!(s.dist_calls, 1);
        assert_eq!(s.wasted, 1);
        assert_eq!(s.per_hop, vec![(1, 1)]);
    }

    /// With a scorer that *is* the exact kernel, the approx core must
    /// reproduce the exact core's stream bit-for-bit (same admission
    /// logic), and `rerank_exact` must be a no-op on the ordering.
    #[test]
    fn approx_core_with_exact_scorer_matches_exact_core() {
        struct ExactShim<'a> {
            store: &'a VectorStore,
            qp: Vec<f32>,
        }
        impl ApproxScorer for ExactShim<'_> {
            fn dist(&mut self, row: usize) -> f32 {
                l2_sq(&self.qp, self.store.row(row))
            }
        }
        let mut rng = Pcg32::new(21);
        let n = 150;
        let dim = 9;
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..n {
            let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let store = store_of(&data);
        let mut adj = FlatAdj::new(n, 7);
        for u in 0..n as u32 {
            for k in 1..=7u32 {
                adj.push(u, (u * 11 + k * 5) % n as u32);
            }
        }
        let mut live = LiveIds::fresh(n);
        live.kill_row(3);
        let mut ctx = SearchContext::new().with_stats();
        for qi in 0..4 {
            let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let mut qp = Vec::new();
            store.pad_query(&q, &mut qp);
            for ef in [4usize, 20] {
                let want = beam_search_filtered(&store, &adj, 0, &q, ef, &live, true, &mut ctx);
                ctx.take_stats();
                let mut shim = ExactShim { store: &store, qp: qp.clone() };
                let mut got =
                    beam_search_approx_filtered(n, &adj, 0, ef, &live, &mut shim, &mut ctx);
                let st = ctx.take_stats();
                assert!(st.approx_calls > 0 && st.dist_calls == 0, "q{qi} ef={ef}");
                assert_eq!(got, want, "pre-rerank q{qi} ef={ef}");
                rerank_exact(&store, &qp, &mut got, true, &mut ctx);
                assert_eq!(got, want, "post-rerank q{qi} ef={ef}");
                let st2 = ctx.take_stats();
                assert_eq!(st2.dist_calls, want.len() as u64, "rerank counts exact calls");
            }
        }
    }

    #[test]
    fn nan_distance_sorts_last() {
        let a = Neighbor { dist: 1.0, id: 1 };
        let b = Neighbor { dist: f32::NAN, id: 0 };
        assert_eq!(a.cmp(&b), Ordering::Less);
        assert_eq!(b.cmp(&a), Ordering::Greater);
        // Min-heap adapter mirrors the same order.
        assert_eq!(MinNeighbor(a).cmp(&MinNeighbor(b)), Ordering::Greater);
        // Eq agrees with Ord even on NaN (total order).
        assert_eq!(b, Neighbor { dist: f32::NAN, id: 0 });
        assert_ne!(a, b);
        let mut v = vec![b, a];
        v.sort();
        assert_eq!(v[0].id, 1, "real distance ranks before NaN");
    }

    #[test]
    fn nan_query_still_terminates() {
        // A NaN query poisons every distance; the search must terminate
        // and return finite-length output instead of corrupting the heap.
        let data = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0], vec![3.0]]);
        let store = store_of(&data);
        let mut adj = FlatAdj::new(4, 3);
        for u in 0..4u32 {
            for v in 0..4u32 {
                if u != v {
                    adj.push(u, v);
                }
            }
        }
        let mut ctx = SearchContext::new();
        let res = beam_search(&store, &adj, 0, &[f32::NAN], 2, &mut ctx);
        assert!(res.len() <= 2);
    }
}
