//! HNSW (Malkov & Yashunin, TPAMI 2018) — the base graph FINGER is built
//! on in the paper. Standard construction: geometric level assignment,
//! greedy descent through upper layers, beam search + neighbor-selection
//! heuristic at each level, bidirectional linking with pruning. All
//! distance work — construction and query — runs against a padded,
//! aligned [`VectorStore`].
//!
//! ## Deterministic parallel construction
//!
//! The static build ([`Hnsw::build_with_store`]) processes insertions in
//! geometric-ramp batches with a **search-parallel / commit-serial**
//! scheme: every item of a batch runs its greedy descent, per-level
//! candidate beam searches, *and* neighbor selection concurrently against
//! the frozen graph prefix (the graph as of the batch start) into
//! per-item plans; then the plans are committed — links set, backward
//! edges pruned, entry point updated — strictly serially in ascending id
//! order. Each plan is a pure function of (frozen graph, store, id) and
//! the commit order is fixed, so a build with `params.threads = T` is
//! **bitwise identical** for every T (adjacency, levels, entry, and
//! therefore persisted bytes) — pinned by `rust/tests/kernel_dispatch.rs`.
//! The online [`Hnsw::insert_node`] path runs the same plan+commit pair
//! back-to-back on the live graph, which is exactly the old sequential
//! insertion.

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::core::rng::{Pcg32, SplitMix64};
use crate::core::store::VectorStore;
use crate::core::threads::{parallel_map_with, resolve_threads};
use crate::graph::adjacency::FlatAdj;
use crate::graph::earlyterm::beam_search_early_term;
use crate::graph::search::{beam_search_filtered, greedy_descent, AllLive, Neighbor};
use crate::index::context::{ContextPool, SearchContext, SearchParams};
use crate::index::mutable::LiveIds;

/// HNSW build parameters.
#[derive(Clone, Debug)]
pub struct HnswParams {
    /// Max out-degree at upper layers; layer 0 allows 2M.
    pub m: usize,
    pub ef_construction: usize,
    pub seed: u64,
    /// Use the diversity heuristic (Algorithm 4 of the HNSW paper) for
    /// neighbor selection rather than plain nearest.
    pub heuristic: bool,
    /// Build worker threads (0 = `FINGER_THREADS`/auto). The built graph
    /// is bitwise identical for every value (see the module docs), so this
    /// is never persisted.
    pub threads: usize,
}

impl Default for HnswParams {
    fn default() -> Self {
        Self {
            m: 16,
            ef_construction: 100,
            seed: 42,
            heuristic: true,
            threads: 0,
        }
    }
}

/// Batch size of the parallel build at `committed` already-inserted
/// nodes: double until 16, then grow as `committed / 4`. Early batches
/// are small while the beam can still sweep the whole prefix, and the
/// steady state bounds candidate staleness at 25% of the graph while
/// keeping batches large enough to feed every worker. A pure function of
/// `committed`, so the schedule (and thus the build) is thread-count
/// independent.
fn build_batch(committed: usize) -> usize {
    committed.min((committed / 4).max(16))
}

/// Per-item output of the parallel search phase: the neighbor lists
/// selected for each level, computed entirely against the frozen prefix.
struct InsertPlan {
    /// Highest level the item links at (`node_level.min(frozen max)`).
    top_level: usize,
    /// Selected neighbor ids per level, from `top_level` down to 0.
    selected: Vec<Vec<u32>>,
}

/// A built HNSW index.
pub struct Hnsw {
    pub params: HnswParams,
    /// Layer 0 adjacency (capacity 2M).
    pub base: FlatAdj,
    /// Upper layers, index 0 = layer 1.
    pub upper: Vec<FlatAdj>,
    pub levels: Vec<u8>,
    pub entry: u32,
    pub max_level: usize,
}

impl Hnsw {
    /// Build over `data` (rows are points). Convenience wrapper that pads
    /// the data into a throwaway [`VectorStore`]; callers that keep a
    /// store (the `AnnIndex` wrappers) use [`Hnsw::build_with_store`].
    pub fn build(data: &Matrix, params: HnswParams) -> Hnsw {
        let store = VectorStore::from_matrix(data);
        Hnsw::build_with_store(&store, params)
    }

    /// Build over an existing padded store.
    pub fn build_with_store(store: &VectorStore, params: HnswParams) -> Hnsw {
        let n = store.rows();
        assert!(n > 0, "empty dataset");
        let m = params.m;
        let ml = 1.0 / (m as f64).ln().max(1e-9);
        let mut rng = Pcg32::new(params.seed);

        // Pre-assign levels so layer storage can be allocated once.
        let levels: Vec<u8> = (0..n)
            .map(|_| {
                let u = rng.next_f64().max(1e-12);
                ((-u.ln() * ml).floor() as usize).min(12) as u8
            })
            .collect();
        let max_level = levels.iter().copied().max().unwrap_or(0) as usize;

        let mut g = Hnsw {
            base: FlatAdj::new(n, 2 * m),
            upper: (0..max_level).map(|_| FlatAdj::new(n, m)).collect(),
            levels,
            entry: 0,
            max_level: 0,
            params,
        };

        // Search-parallel / commit-serial batches (see module docs).
        // Point 0 initializes the graph; every batch plans its insertions
        // concurrently against the frozen prefix (per-worker pooled
        // contexts), then commits serially in ascending id order.
        let threads = resolve_threads(g.params.threads);
        let pool = ContextPool::new(threads, n);
        g.max_level = g.levels[0] as usize;
        let mut committed = 1usize;
        while committed < n {
            let batch = build_batch(committed).min(n - committed);
            let plans: Vec<InsertPlan> = {
                let frozen = &g;
                parallel_map_with(
                    batch,
                    threads,
                    || pool.checkout(),
                    |ctx, bi| frozen.plan_insert(store, (committed + bi) as u32, ctx),
                )
            };
            for (bi, plan) in plans.into_iter().enumerate() {
                g.commit_insert(store, (committed + bi) as u32, plan);
            }
            committed += batch;
        }
        g
    }

    fn layer(&self, l: usize) -> &FlatAdj {
        if l == 0 {
            &self.base
        } else {
            &self.upper[l - 1]
        }
    }

    fn layer_mut(&mut self, l: usize) -> &mut FlatAdj {
        if l == 0 {
            &mut self.base
        } else {
            &mut self.upper[l - 1]
        }
    }

    /// Search phase of one insertion, read-only against the current (for
    /// the batched build: frozen) graph: greedy descent, the per-level
    /// candidate beam searches, and neighbor selection. A pure function
    /// of `(self, store, id)` — this is what a batch fans out in
    /// parallel, one pooled context per worker.
    fn plan_insert(&self, store: &VectorStore, id: u32, ctx: &mut SearchContext) -> InsertPlan {
        let q = store.row_logical(id as usize);
        let node_level = self.levels[id as usize] as usize;
        let mut cur = self.entry;

        // Descend from the top to node_level+1 greedily.
        let top = self.max_level;
        for l in (node_level + 1..=top).rev() {
            cur = greedy_descent(store, self.layer(l), cur, q, ctx).id;
        }

        let top_level = node_level.min(top);
        let mut selected_per_level = Vec::with_capacity(top_level + 1);
        for l in (0..=top_level).rev() {
            let found = beam_search_filtered(
                store,
                self.layer(l),
                cur,
                q,
                self.params.ef_construction,
                &AllLive,
                true,
                ctx,
            );
            cur = found.first().map(|n| n.id).unwrap_or(cur);
            let cap = if l == 0 { 2 * self.params.m } else { self.params.m };
            // Selection depends only on the item's own candidate list, so
            // it runs here (parallel) rather than in the serial commit.
            let selected = if self.params.heuristic {
                select_heuristic(store, &found, cap)
            } else {
                found.iter().take(cap).copied().collect()
            };
            selected_per_level.push(selected.iter().map(|n| n.id).collect());
        }
        InsertPlan {
            top_level,
            selected: selected_per_level,
        }
    }

    /// Commit phase of one insertion: write the planned neighbor lists,
    /// back-link with pruning, force the base-layer reachability in-link,
    /// and update the entry point. The batched build calls this serially
    /// in ascending id order. Returns the base-layer nodes whose neighbor
    /// lists changed — `id` itself plus every back-linked neighbor — so
    /// side indexes keyed on base edge slots (FINGER) can refresh exactly
    /// the touched rows.
    fn commit_insert(&mut self, store: &VectorStore, id: u32, plan: InsertPlan) -> Vec<u32> {
        let node_level = self.levels[id as usize] as usize;
        let mut base_touched: Vec<u32> = Vec::new();
        for (li, l) in (0..=plan.top_level).rev().enumerate() {
            let cap = if l == 0 { 2 * self.params.m } else { self.params.m };
            let list = &plan.selected[li];
            self.layer_mut(l).set(id, list);
            for &nb in list {
                self.link_with_prune(store, l, nb, id, cap);
            }
            if l == 0 {
                // Reachability guarantee (FreshDiskANN-style): if pruning
                // dropped every backward edge, the new node would be
                // unreachable at the base layer. Force one in-link from
                // its nearest selected neighbor — after an overflow
                // re-selection that list sits below capacity (slack), so
                // a plain push always fits.
                if let Some(&u0) = list.first() {
                    if !self.base.contains(u0, id) {
                        let pushed = self.base.push(u0, id);
                        debug_assert!(pushed, "slack-pruned list has room");
                    }
                }
                base_touched.push(id);
                base_touched.extend(list);
            }
        }

        if node_level > self.max_level {
            self.max_level = node_level;
            self.entry = id;
        }
        base_touched
    }

    /// Insert `id` into the graph structure (storage for it must already
    /// exist at every layer): the sequential plan+commit pair, used by the
    /// online [`Hnsw::insert_node`] path. Returns the touched base-layer
    /// nodes (see [`Hnsw::commit_insert`]).
    fn insert(&mut self, store: &VectorStore, id: u32, ctx: &mut SearchContext) -> Vec<u32> {
        let plan = self.plan_insert(store, id, ctx);
        self.commit_insert(store, id, plan)
    }

    /// Deterministic geometric level for an online-inserted node: a
    /// private SplitMix64 stream keyed on (seed, id), so the same id
    /// always draws the same level regardless of operation order.
    fn sample_level(&self, id: u32) -> u8 {
        let ml = 1.0 / (self.params.m as f64).ln().max(1e-9);
        let key = self
            .params
            .seed
            .wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(id as u64 + 1));
        let mut sm = SplitMix64::new(key);
        let u = ((sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)).max(1e-12);
        ((-u.ln() * ml).floor() as usize).min(12) as u8
    }

    /// Online insertion: grow every layer's storage by one node (its edge
    /// slots land at the buffer tails, so existing slots stay stable),
    /// sample its level, and run the standard construction-time insertion
    /// reusing the pooled beam search. `store` must already contain the
    /// new row, and row ids are append-only. Returns the base-layer nodes
    /// whose adjacency changed (including `id`).
    pub fn insert_node(
        &mut self,
        store: &VectorStore,
        id: u32,
        ctx: &mut SearchContext,
    ) -> Vec<u32> {
        assert_eq!(id as usize, self.levels.len(), "graph ids are append-only");
        assert!(
            (id as usize) < store.rows(),
            "data row must be appended before graph insertion"
        );
        let level = self.sample_level(id) as usize;
        self.levels.push(level as u8);
        self.base.add_node();
        for l in self.upper.iter_mut() {
            l.add_node();
        }
        let n = self.levels.len();
        while self.upper.len() < level {
            self.upper.push(FlatAdj::new(n, self.params.m));
        }
        self.insert(store, id, ctx)
    }

    /// Tombstone-aware search: identical routing to [`Hnsw::search`], but
    /// the base-layer beam traverses deleted nodes without ever emitting
    /// them (see [`crate::graph::search::beam_search_live`]).
    /// `params.patience` is ignored —
    /// early termination's stall counter is not defined over a filtered
    /// emission stream. Returns row ids; callers remap to external ids.
    pub fn search_live(
        &self,
        store: &VectorStore,
        q: &[f32],
        params: &SearchParams,
        live: &LiveIds,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let mut cur = self.entry;
        for l in (1..=self.max_level).rev() {
            cur = greedy_descent(store, self.layer(l), cur, q, ctx).id;
        }
        let mut res = beam_search_filtered(
            store,
            &self.base,
            cur,
            q,
            params.beam_width(),
            live,
            !params.scalar_kernels,
            ctx,
        );
        res.truncate(params.k);
        res
    }

    /// Add edge u->v; if over capacity, re-select neighbors.
    ///
    /// Perf note (EXPERIMENTS.md §Perf): on overflow we prune down to
    /// `cap - slack` rather than exactly `cap`, leaving headroom so the
    /// O(cap²)-distance heuristic runs once per ~slack insertions instead
    /// of on every backward edge. This cut high-dimensional build time
    /// ~4-5x at equal search recall (degree bound unchanged).
    fn link_with_prune(&mut self, store: &VectorStore, l: usize, u: u32, v: u32, cap: usize) {
        if self.layer(l).contains(u, v) {
            return;
        }
        if self.layer_mut(l).push(u, v) {
            return;
        }
        // Over capacity: gather current + v, re-select with slack.
        let slack = (cap / 8).max(1);
        let target = cap.saturating_sub(slack).max(1);
        let xu = store.row(u as usize);
        let mut cands: Vec<Neighbor> = self
            .layer(l)
            .neighbors(u)
            .iter()
            .map(|&w| Neighbor {
                dist: l2_sq(xu, store.row(w as usize)),
                id: w,
            })
            .collect();
        cands.push(Neighbor {
            dist: l2_sq(xu, store.row(v as usize)),
            id: v,
        });
        cands.sort();
        let selected = if self.params.heuristic {
            select_heuristic(store, &cands, target)
        } else {
            cands.into_iter().take(target).collect()
        };
        let list: Vec<u32> = selected.iter().map(|n| n.id).collect();
        self.layer_mut(l).set(u, &list);
    }

    /// Search: greedy descent through upper layers, beam at layer 0.
    /// Honors `params.patience` (early termination) and
    /// `params.scalar_kernels` (forces unbatched scoring) when set.
    pub fn search(
        &self,
        store: &VectorStore,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let mut cur = self.entry;
        for l in (1..=self.max_level).rev() {
            cur = greedy_descent(store, self.layer(l), cur, q, ctx).id;
        }
        let ef = params.beam_width();
        let mut res = match params.patience {
            Some(p) => beam_search_early_term(store, &self.base, cur, q, ef, p, ctx),
            None => beam_search_filtered(
                store,
                &self.base,
                cur,
                q,
                ef,
                &AllLive,
                !params.scalar_kernels,
                ctx,
            ),
        };
        res.truncate(params.k);
        res
    }

    /// Index memory footprint in bytes (adjacency only; data stored apart).
    pub fn nbytes(&self) -> usize {
        self.base.nbytes() + self.upper.iter().map(|l| l.nbytes()).sum::<usize>()
    }
}

/// HNSW's neighbor-selection heuristic: keep a candidate only if it is
/// closer to the query point than to every already-kept neighbor
/// (diversity pruning). Falls back to nearest-fill if underfull.
pub fn select_heuristic(store: &VectorStore, cands: &[Neighbor], cap: usize) -> Vec<Neighbor> {
    let mut kept: Vec<Neighbor> = Vec::with_capacity(cap);
    for &c in cands {
        if kept.len() >= cap {
            break;
        }
        let xc = store.row(c.id as usize);
        let diverse = kept
            .iter()
            .all(|k| l2_sq(xc, store.row(k.id as usize)) > c.dist);
        if diverse {
            kept.push(c);
        }
    }
    if kept.len() < cap {
        for &c in cands {
            if kept.len() >= cap {
                break;
            }
            if !kept.iter().any(|k| k.id == c.id) {
                kept.push(c);
            }
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;

    fn recall(found: &[Neighbor], gt: &[u32]) -> f64 {
        let hits = found.iter().filter(|n| gt.contains(&n.id)).count();
        hits as f64 / gt.len() as f64
    }

    #[test]
    fn high_recall_on_tiny_dataset() {
        let ds = tiny(7, 800, 24, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build_with_store(&store, HnswParams { m: 12, ef_construction: 80, ..Default::default() });
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10).with_ef(80);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = h.search(&store, ds.queries.row(qi), &params, &mut ctx);
            total += recall(&res, &gt[qi]);
        }
        let avg = total / ds.queries.rows() as f64;
        assert!(avg > 0.9, "recall@10 = {avg}");
    }

    #[test]
    fn build_with_store_matches_build_from_matrix() {
        // The two construction entries share the insertion path, so the
        // graphs must be identical edge-for-edge.
        let ds = tiny(15, 300, 12, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let a = Hnsw::build(&ds.data, HnswParams::default());
        let b = Hnsw::build_with_store(&store, HnswParams::default());
        assert_eq!(a.entry, b.entry);
        assert_eq!(a.max_level, b.max_level);
        for u in 0..300u32 {
            assert_eq!(a.base.neighbors(u), b.base.neighbors(u), "node {u}");
        }
    }

    #[test]
    fn search_returns_k_sorted() {
        let ds = tiny(8, 300, 16, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build_with_store(&store, HnswParams::default());
        let mut ctx = SearchContext::new();
        let res = h.search(&store, ds.queries.row(0), &SearchParams::new(5).with_ef(50), &mut ctx);
        assert_eq!(res.len(), 5);
        for w in res.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn degrees_bounded() {
        let ds = tiny(9, 400, 8, Metric::L2);
        let p = HnswParams { m: 8, ef_construction: 40, ..Default::default() };
        let h = Hnsw::build(&ds.data, p.clone());
        for u in 0..ds.data.rows() as u32 {
            assert!(h.base.degree(u) <= 2 * p.m);
            for l in &h.upper {
                assert!(l.degree(u) <= p.m);
            }
        }
    }

    #[test]
    fn entry_point_has_max_level() {
        let ds = tiny(10, 500, 8, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams::default());
        assert_eq!(h.levels[h.entry as usize] as usize, h.max_level);
    }

    #[test]
    fn heuristic_prefers_diverse_neighbors() {
        // Three collinear points: b between a and target. Heuristic should
        // drop the redundant farther point along the same direction.
        let data = Matrix::from_rows(&[
            vec![0.0, 0.0],  // query point (id 0)
            vec![1.0, 0.0],  // close
            vec![2.0, 0.0],  // same direction, farther
            vec![0.0, 1.2],  // different direction
        ]);
        let store = VectorStore::from_matrix(&data);
        let q = data.row(0);
        let mut cands: Vec<Neighbor> = (1..4u32)
            .map(|i| Neighbor { dist: l2_sq(q, data.row(i as usize)), id: i })
            .collect();
        cands.sort();
        let kept = select_heuristic(&store, &cands, 2);
        let ids: Vec<u32> = kept.iter().map(|n| n.id).collect();
        assert!(ids.contains(&1));
        assert!(ids.contains(&3), "diverse direction kept: {ids:?}");
    }

    #[test]
    fn incremental_insert_matches_recall_of_static_build() {
        // Build over a prefix, stream the rest in one by one: the grown
        // graph must stay a working HNSW (bounded degrees, high recall,
        // new points findable).
        let ds = tiny(12, 500, 16, Metric::L2);
        let n = ds.data.rows();
        let prefix = 400;
        let mut head = Matrix::zeros(0, ds.data.cols());
        for i in 0..prefix {
            head.push_row(ds.data.row(i));
        }
        let mut store = VectorStore::from_matrix(&head);
        let p = HnswParams { m: 12, ef_construction: 80, ..Default::default() };
        let mut h = Hnsw::build_with_store(&store, p.clone());
        let mut ctx = SearchContext::for_universe(n);
        for i in prefix..n {
            store.push_row(ds.data.row(i));
            let touched = h.insert_node(&store, i as u32, &mut ctx);
            assert!(touched.contains(&(i as u32)));
            assert!(touched.iter().all(|&u| (u as usize) <= i));
        }
        assert_eq!(h.levels.len(), n);
        for u in 0..n as u32 {
            assert!(h.base.degree(u) <= 2 * p.m);
            for l in &h.upper {
                assert!(l.degree(u) <= p.m);
            }
        }
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let params = SearchParams::new(10).with_ef(80);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = h.search(&store, ds.queries.row(qi), &params, &mut ctx);
            total += recall(&res, &gt[qi]);
        }
        let avg = total / ds.queries.rows() as f64;
        assert!(avg > 0.85, "incremental recall@10 = {avg}");
    }

    #[test]
    fn incremental_insert_is_deterministic() {
        let ds = tiny(13, 200, 8, Metric::L2);
        let grow = |()| {
            let mut m = Matrix::zeros(0, ds.data.cols());
            for i in 0..150 {
                m.push_row(ds.data.row(i));
            }
            let mut store = VectorStore::from_matrix(&m);
            let mut h = Hnsw::build_with_store(&store, HnswParams::default());
            let mut ctx = SearchContext::new();
            for i in 150..200 {
                store.push_row(ds.data.row(i));
                h.insert_node(&store, i as u32, &mut ctx);
            }
            h
        };
        let a = grow(());
        let b = grow(());
        assert_eq!(a.entry, b.entry);
        assert_eq!(a.max_level, b.max_level);
        for u in 0..200u32 {
            assert_eq!(a.base.neighbors(u), b.base.neighbors(u), "node {u}");
        }
    }

    #[test]
    fn search_live_skips_tombstones() {
        let ds = tiny(14, 300, 8, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build_with_store(&store, HnswParams { m: 8, ef_construction: 60, ..Default::default() });
        let mut live = LiveIds::fresh(300);
        // Tombstone the exact nearest neighbor of query 0.
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(5).with_ef(300);
        let q = ds.queries.row(0);
        let before = h.search_live(&store, q, &params, &live, &mut ctx);
        let nearest = before[0].id;
        live.kill_row(nearest as usize);
        let after = h.search_live(&store, q, &params, &live, &mut ctx);
        assert!(after.iter().all(|n| n.id != nearest));
        assert_eq!(after.len(), 5);
        assert_eq!(
            after[0], before[1],
            "runner-up becomes nearest once the winner is tombstoned"
        );
    }

    #[test]
    fn deterministic_build() {
        let ds = tiny(11, 200, 8, Metric::L2);
        let a = Hnsw::build(&ds.data, HnswParams::default());
        let b = Hnsw::build(&ds.data, HnswParams::default());
        assert_eq!(a.entry, b.entry);
        assert_eq!(a.base.num_edges(), b.base.num_edges());
    }
}
