//! Vamana graph (DiskANN, Jayaram Subramanya et al., NeurIPS 2019) — the
//! flat-graph baseline in the paper's Figures 1/5/8. Random R-regular
//! initialization, then two refinement passes of greedy-search +
//! alpha-robust pruning from the dataset medoid.
//!
//! Refinement is batch-parallel and deterministic: each batch of the
//! shuffled pass order runs its medoid beam searches + alpha-robust
//! prunes concurrently against the frozen graph (the adjacency as of the
//! batch start), then commits the new lists and pruned backward edges
//! serially in pass order — so the built graph is bitwise identical for
//! every `params.threads` (pinned by `rust/tests/kernel_dispatch.rs`).

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::core::store::VectorStore;
use crate::core::threads::{parallel_map_with, resolve_threads};
use crate::graph::adjacency::FlatAdj;
use crate::graph::earlyterm::beam_search_early_term;
use crate::graph::search::{beam_search_filtered, AllLive, Neighbor};
use crate::index::context::{ContextPool, SearchContext, SearchParams};

#[derive(Clone, Debug)]
pub struct VamanaParams {
    /// Max out-degree R.
    pub r: usize,
    /// Construction beam width L.
    pub l: usize,
    /// Pruning slack alpha >= 1.
    pub alpha: f32,
    pub seed: u64,
    pub passes: usize,
    /// Build worker threads (0 = `FINGER_THREADS`/auto); the built graph
    /// is identical for every value, so this is never persisted.
    pub threads: usize,
}

impl Default for VamanaParams {
    fn default() -> Self {
        Self {
            r: 32,
            l: 80,
            alpha: 1.2,
            seed: 42,
            passes: 2,
            threads: 0,
        }
    }
}

/// Refinement batch size: big enough to feed every worker, small enough
/// that in-pass staleness (a batch searches the graph as of its start)
/// stays a small fraction of a pass.
const REFINE_BATCH: usize = 128;

pub struct Vamana {
    pub params: VamanaParams,
    pub adj: FlatAdj,
    pub medoid: u32,
}

impl Vamana {
    /// Build over `data`, padding it into a throwaway store; callers that
    /// keep a [`VectorStore`] use [`Vamana::build_with_store`].
    pub fn build(data: &Matrix, params: VamanaParams) -> Vamana {
        let store = VectorStore::from_matrix(data);
        Vamana::build_with_store(&store, params)
    }

    pub fn build_with_store(store: &VectorStore, params: VamanaParams) -> Vamana {
        let n = store.rows();
        assert!(n > 0);
        let mut rng = Pcg32::new(params.seed);

        // Random R-regular initialization.
        let mut adj = FlatAdj::new(n, params.r);
        for u in 0..n as u32 {
            let mut picks = Vec::with_capacity(params.r);
            while picks.len() < params.r.min(n - 1) {
                let v = rng.gen_range(n) as u32;
                if v != u && !picks.contains(&v) {
                    picks.push(v);
                }
            }
            adj.set(u, &picks);
        }

        let medoid = find_medoid(store, &mut rng);
        let mut g = Vamana { params, adj, medoid };

        let threads = resolve_threads(g.params.threads);
        let pool = ContextPool::new(threads, n);
        let mut order: Vec<u32> = (0..n as u32).collect();
        for _pass in 0..g.params.passes {
            rng.shuffle(&mut order);
            // Search-parallel / commit-serial batches over the pass order:
            // the expensive medoid beam search + alpha-robust prune of
            // each item is a pure function of the frozen adjacency, so it
            // fans out (workers reuse pooled contexts across batches); the
            // list writes and backward-edge prunes commit serially in pass
            // order.
            for chunk in order.chunks(REFINE_BATCH) {
                let plans: Vec<Vec<u32>> = {
                    let frozen = &g;
                    parallel_map_with(
                        chunk.len(),
                        threads,
                        || pool.checkout(),
                        |ctx, i| {
                            let u = chunk[i];
                            let q = store.row_logical(u as usize);
                            let mut found = beam_search_filtered(
                                store,
                                &frozen.adj,
                                frozen.medoid,
                                q,
                                frozen.params.l,
                                &AllLive,
                                true,
                                ctx,
                            );
                            found.retain(|c| c.id != u);
                            let p = &frozen.params;
                            let pruned = robust_prune(store, u, &found, p.alpha, p.r);
                            pruned.iter().map(|c| c.id).collect()
                        },
                    )
                };
                for (i, list) in plans.into_iter().enumerate() {
                    let u = chunk[i];
                    g.adj.set(u, &list);
                    // Backward edges with pruning on overflow.
                    for v in list {
                        g.add_edge_with_prune(store, v, u);
                    }
                }
            }
        }
        g
    }

    fn add_edge_with_prune(&mut self, store: &VectorStore, u: u32, v: u32) {
        if self.adj.contains(u, v) {
            return;
        }
        if self.adj.push(u, v) {
            return;
        }
        let xu = store.row(u as usize);
        let mut cands: Vec<Neighbor> = self
            .adj
            .neighbors(u)
            .iter()
            .map(|&w| Neighbor {
                dist: l2_sq(xu, store.row(w as usize)),
                id: w,
            })
            .collect();
        cands.push(Neighbor {
            dist: l2_sq(xu, store.row(v as usize)),
            id: v,
        });
        cands.sort();
        let pruned = robust_prune(store, u, &cands, self.params.alpha, self.params.r);
        let list: Vec<u32> = pruned.iter().map(|c| c.id).collect();
        self.adj.set(u, &list);
    }

    /// Beam search from the medoid; honors `params.patience` and
    /// `params.scalar_kernels` when set.
    pub fn search(
        &self,
        store: &VectorStore,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let ef = params.beam_width();
        let mut res = match params.patience {
            Some(p) => beam_search_early_term(store, &self.adj, self.medoid, q, ef, p, ctx),
            None => beam_search_filtered(
                store,
                &self.adj,
                self.medoid,
                q,
                ef,
                &AllLive,
                !params.scalar_kernels,
                ctx,
            ),
        };
        res.truncate(params.k);
        res
    }
}

/// Approximate medoid: the sample point minimizing distance to a random
/// probe set (exact medoid is O(n^2)).
fn find_medoid(store: &VectorStore, rng: &mut Pcg32) -> u32 {
    let n = store.rows();
    let probes: Vec<usize> = (0..64.min(n)).map(|_| rng.gen_range(n)).collect();
    let cands: Vec<usize> = (0..256.min(n)).map(|_| rng.gen_range(n)).collect();
    let mut best = (f32::INFINITY, 0u32);
    for &c in &cands {
        let s: f32 = probes.iter().map(|&p| l2_sq(store.row(c), store.row(p))).sum();
        if s < best.0 {
            best = (s, c as u32);
        }
    }
    best.1
}

/// DiskANN's alpha-RobustPrune over a candidate list sorted ascending.
pub fn robust_prune(
    store: &VectorStore,
    u: u32,
    cands: &[Neighbor],
    alpha: f32,
    r: usize,
) -> Vec<Neighbor> {
    let mut kept: Vec<Neighbor> = Vec::with_capacity(r);
    let mut pool: Vec<Neighbor> = cands.to_vec();
    pool.sort();
    pool.dedup_by_key(|c| c.id);
    let mut removed = vec![false; pool.len()];
    for i in 0..pool.len() {
        if removed[i] || pool[i].id == u {
            continue;
        }
        kept.push(pool[i]);
        if kept.len() >= r {
            break;
        }
        let xp = store.row(pool[i].id as usize);
        for (j, c) in pool.iter().enumerate().skip(i + 1) {
            if removed[j] {
                continue;
            }
            // Remove c if p is sufficiently closer to c than u is.
            if alpha * l2_sq(xp, store.row(c.id as usize)) <= c.dist {
                removed[j] = true;
            }
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;

    #[test]
    fn reasonable_recall_on_tiny() {
        let ds = tiny(21, 600, 16, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        let v = Vamana::build_with_store(&store, VamanaParams::default());
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10).with_ef(80);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let res = v.search(&store, ds.queries.row(qi), &params, &mut ctx);
            let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
            total += hits as f64 / 10.0;
        }
        let avg = total / ds.queries.rows() as f64;
        assert!(avg > 0.85, "recall@10 = {avg}");
    }

    #[test]
    fn degree_bounded_by_r() {
        let ds = tiny(22, 300, 8, Metric::L2);
        let p = VamanaParams { r: 12, ..Default::default() };
        let v = Vamana::build(&ds.data, p);
        for u in 0..ds.data.rows() as u32 {
            assert!(v.adj.degree(u) <= 12);
        }
    }

    #[test]
    fn no_self_loops() {
        let ds = tiny(23, 200, 8, Metric::L2);
        let v = Vamana::build(&ds.data, VamanaParams::default());
        for u in 0..ds.data.rows() as u32 {
            assert!(!v.adj.neighbors(u).contains(&u));
        }
    }

    #[test]
    fn robust_prune_keeps_nearest() {
        let data = Matrix::from_rows(&[
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![1.1, 0.0],
            vec![0.0, 2.0],
        ]);
        let store = VectorStore::from_matrix(&data);
        let q = data.row(0);
        let mut cands: Vec<Neighbor> = (1..4u32)
            .map(|i| Neighbor { dist: l2_sq(q, data.row(i as usize)), id: i })
            .collect();
        cands.sort();
        let kept = robust_prune(&store, 0, &cands, 1.2, 2);
        // Nearest (id 1) always kept; id 2 dominated by id 1.
        assert_eq!(kept[0].id, 1);
        assert!(kept.iter().any(|c| c.id == 3));
    }
}
