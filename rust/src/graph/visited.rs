//! Epoch-stamped visited set — O(1) clear between queries, no hashing on
//! the hot path (DESIGN.md §7: one of the L3 optimizations; a HashSet here
//! costs ~2x end-to-end search latency).

/// Visited marker over a fixed universe of node ids.
#[derive(Clone, Debug)]
pub struct VisitedSet {
    stamp: Vec<u32>,
    epoch: u32,
}

impl VisitedSet {
    pub fn new(n: usize) -> Self {
        Self {
            stamp: vec![0; n],
            epoch: 0,
        }
    }

    /// Begin a new query: invalidates all marks in O(1) (amortized).
    pub fn clear(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Wrapped: must actually reset the stamps once every 2^32 queries.
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
    }

    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        self.stamp[id as usize] == self.epoch
    }

    /// Mark visited. Returns true if it was newly inserted.
    #[inline]
    pub fn insert(&mut self, id: u32) -> bool {
        let s = &mut self.stamp[id as usize];
        if *s == self.epoch {
            false
        } else {
            *s = self.epoch;
            true
        }
    }

    pub fn len_universe(&self) -> usize {
        self.stamp.len()
    }

    /// Grow the universe to cover node ids `< n` (no-op if large enough).
    /// New slots are unstamped, so they read as unvisited in the current
    /// epoch. Lets one pooled set serve indexes of different sizes.
    pub fn ensure_universe(&mut self, n: usize) {
        if n > self.stamp.len() {
            self.stamp.resize(n, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_contains() {
        let mut v = VisitedSet::new(10);
        v.clear();
        assert!(!v.contains(3));
        assert!(v.insert(3));
        assert!(v.contains(3));
        assert!(!v.insert(3));
    }

    #[test]
    fn clear_resets() {
        let mut v = VisitedSet::new(4);
        v.clear();
        v.insert(1);
        v.clear();
        assert!(!v.contains(1));
        assert!(v.insert(1));
    }

    #[test]
    fn ensure_universe_grows_unvisited() {
        let mut v = VisitedSet::new(2);
        v.clear();
        v.insert(1);
        v.ensure_universe(8);
        assert_eq!(v.len_universe(), 8);
        assert!(v.contains(1), "existing marks survive growth");
        assert!(!v.contains(7));
        assert!(v.insert(7));
        v.ensure_universe(4); // shrink request is a no-op
        assert_eq!(v.len_universe(), 8);
    }

    #[test]
    fn epoch_wraparound_safe() {
        let mut v = VisitedSet::new(2);
        v.epoch = u32::MAX - 1;
        v.clear(); // -> MAX
        v.insert(0);
        v.clear(); // wraps -> full reset -> 1
        assert!(!v.contains(0));
        v.insert(1);
        assert!(v.contains(1));
    }
}
