//! WAL record format: checksummed, length-prefixed, block-aligned.
//!
//! The log is a sequence of 32 KiB blocks (LevelDB's `log_format`). Each
//! block holds physical records back to back:
//!
//! ```text
//!   crc u32 | len u16 | type u8 | payload (len bytes)
//! ```
//!
//! `crc` is CRC-32 (IEEE) over `type || payload`, so a bit flip anywhere
//! in the stored bytes is detected. A logical record larger than the
//! space left in a block is fragmented (`First`/`Middle`/`Last`); small
//! ones are a single `Full` fragment. When fewer than `HEADER_SIZE`
//! bytes remain in a block the tail is zero-filled — the reader
//! recognizes the padding unambiguously because fragment type `0` is
//! reserved, and skips to the next block boundary.
//!
//! Because every fragment is verified independently, a torn write — the
//! crash leaving only a prefix of the final `write(2)` on disk — is
//! detected at the first fragment whose bytes are short or whose CRC
//! mismatches, and recovery truncates to the last complete *logical*
//! record (a dangling `First` without its `Last` is dropped too).
//!
//! Logical payloads are the mutation ops ([`WalOp`]): the exact verbs
//! the router serves, each prefixed with its monotone op sequence
//! number so replay can assert contiguity against the snapshot it
//! starts from.

/// Block size; fragment boundaries never straddle it.
pub const BLOCK_SIZE: usize = 32 * 1024;
/// Physical fragment header: crc u32 + len u16 + type u8.
pub const HEADER_SIZE: usize = 7;

/// Fragment types. `0` is reserved so block-tail zero padding can never
/// parse as a fragment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FragType {
    Full = 1,
    First = 2,
    Middle = 3,
    Last = 4,
}

impl FragType {
    pub fn from_u8(v: u8) -> Option<FragType> {
        match v {
            1 => Some(FragType::Full),
            2 => Some(FragType::First),
            3 => Some(FragType::Middle),
            4 => Some(FragType::Last),
            _ => None,
        }
    }
}

// ------------------------------------------------------------- CRC-32

/// CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) — the zero-dep
/// table-driven implementation; matches `zlib.crc32`.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 == 1 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

fn frag_crc(ty: FragType, payload: &[u8]) -> u32 {
    let mut buf = Vec::with_capacity(1 + payload.len());
    buf.push(ty as u8);
    buf.extend_from_slice(payload);
    crc32(&buf)
}

// --------------------------------------------------- physical framing

/// Append one logical record to `out`, fragmenting against the current
/// block offset `block_off` (bytes already used in the current block).
/// Returns the new block offset. Purely deterministic: the emitted bytes
/// depend only on `(block_off, payload)`.
pub fn encode_record(out: &mut Vec<u8>, mut block_off: usize, payload: &[u8]) -> usize {
    let mut rest = payload;
    let mut first = true;
    loop {
        let leftover = BLOCK_SIZE - block_off;
        if leftover < HEADER_SIZE {
            // Zero-fill the unusable tail; the reader skips it.
            out.resize(out.len() + leftover, 0);
            block_off = 0;
            continue;
        }
        let avail = leftover - HEADER_SIZE;
        let take = rest.len().min(avail);
        let end = take == rest.len();
        let ty = match (first, end) {
            (true, true) => FragType::Full,
            (true, false) => FragType::First,
            (false, false) => FragType::Middle,
            (false, true) => FragType::Last,
        };
        let (chunk, tail) = rest.split_at(take);
        out.extend_from_slice(&frag_crc(ty, chunk).to_le_bytes());
        out.extend_from_slice(&(take as u16).to_le_bytes());
        out.push(ty as u8);
        out.extend_from_slice(chunk);
        block_off += HEADER_SIZE + take;
        if block_off == BLOCK_SIZE {
            block_off = 0;
        }
        if end {
            return block_off;
        }
        rest = tail;
        first = false;
    }
}

// ------------------------------------------------------- logical ops

/// One durable mutation: exactly the verbs the router serves. `Compact`
/// is logged even when the threshold gate declines — the gate is
/// deterministic, so replay declines identically and the recovered
/// bytes stay identical to the uninterrupted run. `SetThreshold` logs
/// the compaction threshold itself, so replay (and replica apply) gates
/// later compacts at the log-time threshold instead of assuming the
/// default — without it, a recovered index could compact where the live
/// run declined (or vice versa) and the bundles would diverge.
#[derive(Clone, Debug, PartialEq)]
pub enum WalOp {
    Insert { vector: Vec<f32> },
    Delete { key: u32 },
    Compact,
    SetThreshold { frac: f64 },
}

const OP_INSERT: u8 = 1;
const OP_DELETE: u8 = 2;
const OP_COMPACT: u8 = 3;
const OP_SETTHRESHOLD: u8 = 4;

impl WalOp {
    /// Short verb name (`wal dump`, reports).
    pub fn name(&self) -> &'static str {
        match self {
            WalOp::Insert { .. } => "insert",
            WalOp::Delete { .. } => "delete",
            WalOp::Compact => "compact",
            WalOp::SetThreshold { .. } => "set_threshold",
        }
    }

    /// Serialize with the op sequence number: `seq u64 | op u8 | body`.
    pub fn encode(&self, seq: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        out.extend_from_slice(&seq.to_le_bytes());
        match self {
            WalOp::Insert { vector } => {
                out.push(OP_INSERT);
                out.extend_from_slice(&(vector.len() as u32).to_le_bytes());
                for &x in vector {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            WalOp::Delete { key } => {
                out.push(OP_DELETE);
                out.extend_from_slice(&key.to_le_bytes());
            }
            WalOp::Compact => out.push(OP_COMPACT),
            WalOp::SetThreshold { frac } => {
                out.push(OP_SETTHRESHOLD);
                out.extend_from_slice(&frac.to_le_bytes());
            }
        }
        out
    }

    /// Decode one logical payload. Errors (short body, unknown op byte,
    /// length mismatch) are strings the recovery report carries — a
    /// corrupt payload that still passed CRC is treated like any other
    /// corruption point: replay stops there.
    pub fn decode(buf: &[u8]) -> Result<(u64, WalOp), String> {
        if buf.len() < 9 {
            return Err(format!("logical record too short ({} bytes)", buf.len()));
        }
        let seq = u64::from_le_bytes(buf[..8].try_into().unwrap());
        let body = &buf[9..];
        let op = match buf[8] {
            OP_INSERT => {
                if body.len() < 4 {
                    return Err("insert record missing dim".into());
                }
                let dim = u32::from_le_bytes(body[..4].try_into().unwrap()) as usize;
                let data = &body[4..];
                if data.len() != dim * 4 {
                    return Err(format!(
                        "insert record body {} bytes, want {} (dim {dim})",
                        data.len(),
                        dim * 4
                    ));
                }
                let vector = data
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                WalOp::Insert { vector }
            }
            OP_DELETE => {
                if body.len() != 4 {
                    return Err("delete record wants exactly a u32 key".into());
                }
                WalOp::Delete { key: u32::from_le_bytes(body.try_into().unwrap()) }
            }
            OP_COMPACT => {
                if !body.is_empty() {
                    return Err("compact record carries unexpected bytes".into());
                }
                WalOp::Compact
            }
            OP_SETTHRESHOLD => {
                if body.len() != 8 {
                    return Err("set_threshold record wants exactly an f64".into());
                }
                let frac = f64::from_le_bytes(body.try_into().unwrap());
                if !frac.is_finite() || !(0.0..=1.0).contains(&frac) || frac == 0.0 {
                    return Err(format!("set_threshold fraction {frac} outside (0, 1]"));
                }
                WalOp::SetThreshold { frac }
            }
            other => return Err(format!("unknown op byte {other}")),
        };
        Ok((seq, op))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard check value: CRC-32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn op_encoding_roundtrips() {
        for (seq, op) in [
            (1u64, WalOp::Insert { vector: vec![1.5, -2.0, 0.0] }),
            (2, WalOp::Delete { key: 77 }),
            (3, WalOp::Compact),
            (4, WalOp::SetThreshold { frac: 0.25 }),
            (5, WalOp::SetThreshold { frac: 1.0 }),
            (u64::MAX, WalOp::Insert { vector: vec![] }),
        ] {
            let bytes = op.encode(seq);
            let (s, back) = WalOp::decode(&bytes).unwrap();
            assert_eq!(s, seq);
            assert_eq!(back, op);
        }
    }

    #[test]
    fn op_decoding_rejects_corruption() {
        assert!(WalOp::decode(&[]).is_err());
        assert!(WalOp::decode(&[0; 8]).is_err());
        let mut bytes = WalOp::Insert { vector: vec![1.0] }.encode(4);
        bytes.pop(); // short body
        assert!(WalOp::decode(&bytes).is_err());
        let mut bytes = WalOp::Compact.encode(4);
        bytes[8] = 99; // unknown verb
        assert!(WalOp::decode(&bytes).is_err());
        let mut bytes = WalOp::SetThreshold { frac: 0.5 }.encode(4);
        bytes.pop(); // short f64 body
        assert!(WalOp::decode(&bytes).is_err());
        // A bit pattern outside (0, 1] passed CRC but is still rejected.
        let bytes = WalOp::SetThreshold { frac: 0.5 }.encode(4);
        let mut neg = bytes.clone();
        neg[9..17].copy_from_slice(&(-0.5f64).to_le_bytes());
        assert!(WalOp::decode(&neg).is_err());
        let mut zero = bytes.clone();
        zero[9..17].copy_from_slice(&0.0f64.to_le_bytes());
        assert!(WalOp::decode(&zero).is_err());
        let mut nan = bytes;
        nan[9..17].copy_from_slice(&f64::NAN.to_le_bytes());
        assert!(WalOp::decode(&nan).is_err());
    }

    #[test]
    fn encode_record_fragments_across_blocks() {
        // Payload bigger than a block must fragment First/Middle.../Last.
        let payload = vec![0xABu8; BLOCK_SIZE + 100];
        let mut out = Vec::new();
        let off = encode_record(&mut out, 0, &payload);
        assert!(out.len() > payload.len());
        assert_eq!(out[6], FragType::First as u8);
        assert_eq!(off, out.len() % BLOCK_SIZE);
        // A small record near the block end forces zero padding first.
        let mut out2 = Vec::new();
        let off2 = encode_record(&mut out2, BLOCK_SIZE - 3, b"xy");
        assert_eq!(&out2[..3], &[0, 0, 0], "unusable tail zero-filled");
        assert_eq!(off2, HEADER_SIZE + 2);
    }
}
