//! Durable mutation plane: write-ahead log + snapshot checkpoints.
//!
//! A WAL directory holds exactly one *generation* at a time (plus, after
//! a crash mid-checkpoint, debris from the previous one, which recovery
//! ignores because it always picks the highest sequence number):
//!
//! ```text
//!   wal-dir/
//!     snapshot-{seq:08}.idx   # full v5 bundle after `seq` logged ops
//!     wal-{seq:08}.log        # ops seq+1, seq+2, ... since that snapshot
//! ```
//!
//! The op sequence number is monotone across rotations: a snapshot at
//! seq `N` bakes in ops `1..=N`, and its log carries `N+1, ...`. Replay
//! asserts this contiguity — a log whose first op does not extend its
//! snapshot is treated as wholly corrupt rather than silently applied.
//!
//! Recovery = load the newest snapshot (plain v5 `load_index`, format
//! unchanged), scan its log ([`scan_log`]), truncate the file to the
//! durable prefix, and replay the ops through the live
//! `MutableAnnIndex` verbs. The PR 5 determinism contract (same ops in
//! the same order from the same state ⇒ byte-identical persisted
//! bundles) upgrades this from "approximately restored" to *provably
//! restored*: `wal_props.rs` asserts the recovered bundle is
//! byte-identical to one from an uninterrupted run.

pub mod reader;
pub mod record;
pub mod writer;

pub use reader::{scan_log, ScanResult};
pub use record::{crc32, WalOp, BLOCK_SIZE};
pub use writer::{FsyncPolicy, WalWriter};

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::persist::{load_index, save_index, sync_dir};
use crate::index::{AnnIndex, SearchContext};

/// What recovery did, for the serve banner and the smoke tests.
#[derive(Debug)]
pub struct RecoveryReport {
    /// Sequence baked into the snapshot that was loaded.
    pub snapshot_seq: u64,
    /// Ops replayed from the log on top of it.
    pub replayed: usize,
    /// Last op sequence now applied (snapshot_seq when the log was empty).
    pub last_seq: u64,
    /// Bytes past the durable prefix that were cut off.
    pub dropped_bytes: u64,
    /// Why the scan stopped early, when it did.
    pub corruption: Option<String>,
}

impl RecoveryReport {
    pub fn summary(&self) -> String {
        let mut s = format!(
            "recovered snapshot seq {} + {} replayed op(s) (last seq {})",
            self.snapshot_seq, self.replayed, self.last_seq
        );
        match &self.corruption {
            Some(why) => {
                s.push_str(&format!(
                    "; dropped {} torn byte(s): {why}",
                    self.dropped_bytes
                ));
            }
            None => s.push_str("; log tail clean"),
        }
        s
    }
}

pub fn snapshot_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("snapshot-{seq:08}.idx"))
}

pub fn log_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:08}.log"))
}

/// Highest snapshot sequence present in `dir`, if any.
pub fn latest_snapshot_seq(dir: &Path) -> io::Result<Option<u64>> {
    let mut best: Option<u64> = None;
    for entry in std::fs::read_dir(dir)? {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(seq) = name
            .strip_prefix("snapshot-")
            .and_then(|s| s.strip_suffix(".idx"))
            .and_then(|s| s.parse::<u64>().ok())
        {
            best = Some(best.map_or(seq, |b: u64| b.max(seq)));
        }
    }
    Ok(best)
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// What [`Wal::catchup_since`] hands a (re)connecting replica: an
/// optional full snapshot (`(base_seq, bundle bytes)`) and the log ops
/// past the replica's position, in order.
pub struct Catchup {
    pub snapshot: Option<(u64, Vec<u8>)>,
    pub ops: Vec<(u64, WalOp)>,
}

impl Catchup {
    /// Last sequence this catch-up brings the replica to.
    pub fn last_seq(&self, from: u64) -> u64 {
        self.ops
            .last()
            .map(|(s, _)| *s)
            .or(self.snapshot.as_ref().map(|(s, _)| *s))
            .unwrap_or(from)
            .max(from)
    }
}

/// The durable mutation plane for one serving index: owns the WAL
/// directory, the current log writer, and the checkpoint path. Thread
/// safety mirrors the router: appends happen under the index write lock
/// (which orders them against checkpoints), commits happen outside it on
/// the writer handle `append` returns.
pub struct Wal {
    dir: PathBuf,
    policy: FsyncPolicy,
    writer: Mutex<Arc<WalWriter>>,
    snapshot_seq: AtomicU64,
}

impl Wal {
    /// Does `dir` already hold a recoverable generation?
    pub fn has_snapshot(dir: &Path) -> bool {
        matches!(latest_snapshot_seq(dir), Ok(Some(_)))
    }

    /// Start a fresh WAL directory around `index`: snapshot at seq 0 plus
    /// an empty log. Refuses a directory that already has a snapshot —
    /// that state wants [`Wal::recover`], and clobbering it would destroy
    /// the only durable copy.
    pub fn bootstrap(dir: &Path, index: &dyn AnnIndex, policy: FsyncPolicy) -> io::Result<Wal> {
        Wal::bootstrap_at(dir, index, policy, 0)
    }

    /// [`Wal::bootstrap`] with an explicit starting sequence: the snapshot
    /// claims `seq` ops are already baked in and the log carries
    /// `seq + 1, ...`. A replica installing a primary snapshot uses this
    /// so its local generation numbering mirrors the primary's.
    pub fn bootstrap_at(
        dir: &Path,
        index: &dyn AnnIndex,
        policy: FsyncPolicy,
        seq: u64,
    ) -> io::Result<Wal> {
        std::fs::create_dir_all(dir)?;
        if Wal::has_snapshot(dir) {
            return Err(invalid(format!(
                "{} already holds a snapshot; recover instead of bootstrapping",
                dir.display()
            )));
        }
        save_index(&snapshot_path(dir, seq), index)?;
        let writer = WalWriter::create(&log_path(dir, seq), policy, seq)?;
        sync_dir(dir);
        Ok(Wal {
            dir: dir.to_path_buf(),
            policy,
            writer: Mutex::new(Arc::new(writer)),
            snapshot_seq: AtomicU64::new(seq),
        })
    }

    /// Replace whatever generation `dir` holds with a received snapshot:
    /// the `bundle` bytes are written verbatim as `snapshot-{seq}.idx`
    /// (byte-identity with the sender's snapshot is the point), a fresh
    /// log is created at `seq`, and any older generation is deleted
    /// afterwards. Crash-safe in the same way checkpointing is: the new
    /// generation is durable before the old one goes, and recovery picks
    /// the highest seq. The caller validates the bundle (it loads the
    /// index from the same bytes before calling this).
    pub fn reinstall(dir: &Path, seq: u64, bundle: &[u8], policy: FsyncPolicy) -> io::Result<Wal> {
        std::fs::create_dir_all(dir)?;
        let snap = snapshot_path(dir, seq);
        let mut tmp = snap.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        std::fs::write(&tmp, bundle)?;
        std::fs::File::open(&tmp)?.sync_all()?;
        std::fs::rename(&tmp, &snap)?;
        let lp = log_path(dir, seq);
        std::fs::remove_file(&lp).ok(); // stale same-seq log from a torn install
        let writer = WalWriter::create(&lp, policy, seq)?;
        sync_dir(dir);
        // New generation durable: clear out every other one.
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let other = name
                .strip_prefix("snapshot-")
                .and_then(|s| s.strip_suffix(".idx"))
                .or_else(|| name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log")))
                .and_then(|s| s.parse::<u64>().ok());
            if matches!(other, Some(o) if o != seq) {
                std::fs::remove_file(entry.path()).ok();
            }
        }
        sync_dir(dir);
        Ok(Wal {
            dir: dir.to_path_buf(),
            policy,
            writer: Mutex::new(Arc::new(writer)),
            snapshot_seq: AtomicU64::new(seq),
        })
    }

    /// [`Wal::reinstall`] in place: swap this handle over to the freshly
    /// received generation instead of constructing a new `Wal`. Cluster
    /// followers share one `Wal` between the serving plane and the
    /// replication stream; reinstalling through the shared handle keeps
    /// every holder on the new generation (two writers on one directory
    /// would corrupt it).
    pub fn reinstall_into(&self, seq: u64, bundle: &[u8]) -> io::Result<()> {
        let fresh = Wal::reinstall(&self.dir, seq, bundle, self.policy)?;
        *self.writer.lock().unwrap_or_else(|e| e.into_inner()) = fresh.writer();
        self.snapshot_seq.store(seq, Ordering::Release);
        Ok(())
    }

    /// Load the newest snapshot, repair the log tail, replay the durable
    /// ops, and resume appending where the log left off.
    pub fn recover(
        dir: &Path,
        policy: FsyncPolicy,
    ) -> io::Result<(Box<dyn AnnIndex>, Wal, RecoveryReport)> {
        let snap_seq = latest_snapshot_seq(dir)?.ok_or_else(|| {
            invalid(format!("no snapshot-*.idx in {}", dir.display()))
        })?;
        let mut index = load_index(&snapshot_path(dir, snap_seq))?;

        let lp = log_path(dir, snap_seq);
        let mut scan = if lp.exists() {
            scan_log(&std::fs::read(&lp)?)
        } else {
            // Crash between snapshot rename and log creation: the
            // snapshot alone is the whole durable state.
            ScanResult { ops: Vec::new(), durable_len: 0, dropped_bytes: 0, corruption: None }
        };
        // The log must extend *this* snapshot. A first op that does not
        // follow snap_seq means the prefix is not replayable at all.
        if let Some((first, _)) = scan.ops.first() {
            if *first != snap_seq + 1 {
                scan.corruption = Some(format!(
                    "log starts at seq {first}, snapshot ends at {snap_seq}"
                ));
                scan.dropped_bytes += scan.durable_len;
                scan.durable_len = 0;
                scan.ops.clear();
            }
        }

        // Repair: cut the file back to the durable prefix so resumed
        // appends extend valid bytes, not torn ones.
        if lp.exists() {
            let actual = std::fs::metadata(&lp)?.len();
            if actual != scan.durable_len {
                let f = std::fs::OpenOptions::new().write(true).open(&lp)?;
                f.set_len(scan.durable_len)?;
                f.sync_all()?;
            }
        }

        // Replay through the live mutation verbs. Ops were only logged
        // when they succeeded (or, for compact, when the deterministic
        // threshold gate ran), so failure here means the snapshot and log
        // disagree — corrupt state, not a torn tail; refuse to serve it.
        let replayed = scan.ops.len();
        if replayed > 0 {
            let family = index.name().to_string();
            let m = index.as_mutable().ok_or_else(|| {
                invalid(format!("index family '{family}' is not mutable; cannot replay"))
            })?;
            let mut ctx = SearchContext::new();
            for (seq, op) in &scan.ops {
                let r = match op {
                    WalOp::Insert { vector } => m.insert(vector, &mut ctx).map(|_| ()),
                    WalOp::Delete { key } => m.remove(*key).map(|_| ()),
                    WalOp::Compact => m.compact(&mut ctx).map(|_| ()),
                    WalOp::SetThreshold { frac } => {
                        m.set_compact_threshold(*frac);
                        Ok(())
                    }
                };
                r.map_err(|e| invalid(format!("replay failed at seq {seq}: {e:?}")))?;
            }
        }

        let last_seq = scan.last_seq().unwrap_or(snap_seq);
        let writer = if lp.exists() {
            WalWriter::resume(&lp, policy, last_seq, scan.durable_len)?
        } else {
            WalWriter::create(&lp, policy, snap_seq)?
        };
        let report = RecoveryReport {
            snapshot_seq: snap_seq,
            replayed,
            last_seq,
            dropped_bytes: scan.dropped_bytes,
            corruption: scan.corruption,
        };
        let wal = Wal {
            dir: dir.to_path_buf(),
            policy,
            writer: Mutex::new(Arc::new(writer)),
            snapshot_seq: AtomicU64::new(snap_seq),
        };
        Ok((index, wal, report))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn policy(&self) -> FsyncPolicy {
        self.policy
    }

    pub fn snapshot_seq(&self) -> u64 {
        self.snapshot_seq.load(Ordering::Acquire)
    }

    /// Current log writer (the handle to `commit` on after releasing the
    /// index lock — pinning it here keeps the ack tied to the same log
    /// even if a checkpoint rotates underneath).
    pub fn writer(&self) -> Arc<WalWriter> {
        Arc::clone(&self.writer.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Append one op; returns the writer it landed in and its sequence.
    /// Call under the same lock that serialized applying the op, commit
    /// on the returned writer after dropping it.
    pub fn append(&self, op: &WalOp) -> io::Result<(Arc<WalWriter>, u64)> {
        let w = self.writer();
        let seq = w.append(op)?;
        Ok((w, seq))
    }

    /// Fsync everything appended so far, regardless of policy.
    pub fn sync(&self) -> io::Result<()> {
        self.writer().sync()
    }

    /// Everything a replica at `last_seq` needs to catch up to the
    /// current generation: a full snapshot when it is behind the
    /// generation's base (or has no state at all), plus the log ops past
    /// its position. Reads race benignly with both appenders and
    /// checkpoints: a torn in-flight record makes [`scan_log`] stop at
    /// the durable prefix (the racing op is published live once its
    /// append completes), and a rotation mid-read is detected by
    /// re-checking the generation seq and retrying.
    pub fn catchup_since(&self, last_seq: u64, need_snapshot: bool) -> io::Result<Catchup> {
        for _ in 0..16 {
            let base = self.snapshot_seq();
            let snapshot = if need_snapshot || last_seq < base {
                match std::fs::read(snapshot_path(&self.dir, base)) {
                    Ok(bytes) => Some((base, bytes)),
                    // Rotated away between the seq read and the file
                    // read: retry against the new generation.
                    Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
                    Err(e) => return Err(e),
                }
            } else {
                None
            };
            let floor = snapshot.as_ref().map_or(last_seq, |(s, _)| (*s).max(last_seq));
            let lp = log_path(&self.dir, base);
            let scan = match std::fs::read(&lp) {
                Ok(bytes) => scan_log(&bytes),
                Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e),
            };
            if self.snapshot_seq() != base {
                continue; // rotated under us; the tail we read is stale
            }
            let ops: Vec<(u64, WalOp)> =
                scan.ops.into_iter().filter(|(seq, _)| *seq > floor).collect();
            return Ok(Catchup { snapshot, ops });
        }
        Err(invalid(format!(
            "catch-up raced checkpoint rotation 16 times in {}",
            self.dir.display()
        )))
    }

    /// Checkpoint: persist `index` as a fresh snapshot, rotate to a new
    /// log, delete the old generation. The caller MUST hold the index
    /// write lock — that is what guarantees no op is applied-but-unlogged
    /// or logged-but-unapplied while the snapshot is cut. Returns the new
    /// snapshot sequence. Crash-safe at every step: both generations
    /// coexist on disk until the new one is durable, and recovery always
    /// picks the newest.
    pub fn checkpoint(&self, index: &dyn AnnIndex) -> io::Result<u64> {
        let old = self.writer();
        old.sync()?;
        let seq = old.appended_seq();
        save_index(&snapshot_path(&self.dir, seq), index)?;
        let fresh = WalWriter::create(&log_path(&self.dir, seq), self.policy, seq)?;
        sync_dir(&self.dir);
        let old_seq = self.snapshot_seq.swap(seq, Ordering::AcqRel);
        *self.writer.lock().unwrap_or_else(|e| e.into_inner()) = Arc::new(fresh);
        if old_seq != seq {
            std::fs::remove_file(log_path(&self.dir, old_seq)).ok();
            std::fs::remove_file(snapshot_path(&self.dir, old_seq)).ok();
            sync_dir(&self.dir);
        }
        Ok(seq)
    }

    /// Scan the current generation's log without touching it (CLI
    /// `wal dump`). Returns the snapshot seq it extends and the scan.
    pub fn dump(dir: &Path) -> io::Result<(u64, ScanResult)> {
        let snap_seq = latest_snapshot_seq(dir)?.ok_or_else(|| {
            invalid(format!("no snapshot-*.idx in {}", dir.display()))
        })?;
        let lp = log_path(dir, snap_seq);
        let bytes = if lp.exists() { std::fs::read(&lp)? } else { Vec::new() };
        Ok((snap_seq, scan_log(&bytes)))
    }

    /// Repair the current generation's log in place: truncate to the
    /// durable prefix (CLI `wal truncate`). Returns the snapshot seq and
    /// the scan that justified the cut.
    pub fn repair(dir: &Path) -> io::Result<(u64, ScanResult)> {
        let (snap_seq, scan) = Wal::dump(dir)?;
        let lp = log_path(dir, snap_seq);
        if lp.exists() && std::fs::metadata(&lp)?.len() != scan.durable_len {
            let f = std::fs::OpenOptions::new().write(true).open(&lp)?;
            f.set_len(scan.durable_len)?;
            f.sync_all()?;
        }
        Ok((snap_seq, scan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::Matrix;
    use crate::index::impls::BruteForce;
    use crate::index::SearchContext;
    use std::io::Write as _;

    fn base_matrix() -> Matrix {
        let mut m = Matrix::zeros(0, 3);
        for i in 0..6 {
            let row: Vec<f32> = (0..3).map(|j| (i * 3 + j) as f32 * 0.5 - 4.0).collect();
            m.push_row(&row);
        }
        m
    }

    fn fresh_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("finger_walmgr_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    fn bundle_bytes(index: &dyn AnnIndex, name: &str) -> Vec<u8> {
        let p = std::env::temp_dir().join(format!("finger_walmgr_b_{}_{name}", std::process::id()));
        save_index(&p, index).unwrap();
        let b = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        b
    }

    /// Apply an op to the index and log it — the router's ordering.
    fn apply_and_log(index: &mut Box<dyn AnnIndex>, wal: &Wal, op: &WalOp) {
        let mut ctx = SearchContext::new();
        let m = index.as_mutable().unwrap();
        match op {
            WalOp::Insert { vector } => {
                m.insert(vector, &mut ctx).unwrap();
            }
            WalOp::Delete { key } => {
                m.remove(*key).unwrap();
            }
            WalOp::Compact => {
                m.compact(&mut ctx).unwrap();
            }
            WalOp::SetThreshold { frac } => m.set_compact_threshold(*frac),
        }
        let (w, seq) = wal.append(op).unwrap();
        w.commit(seq).unwrap();
    }

    fn ops() -> Vec<WalOp> {
        vec![
            WalOp::Insert { vector: vec![1.0, -1.0, 0.5] },
            WalOp::Delete { key: 2 },
            WalOp::Compact,
            WalOp::Insert { vector: vec![0.0, 3.0, -2.5] },
        ]
    }

    #[test]
    fn bootstrap_append_recover_is_byte_identical() {
        let dir = fresh_dir("roundtrip");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        for op in &ops() {
            apply_and_log(&mut index, &wal, op);
        }
        drop(wal); // "crash": nothing synced under Never, same-process reads still see it

        let (recovered, wal2, report) = Wal::recover(&dir, FsyncPolicy::Never).unwrap();
        assert_eq!(report.snapshot_seq, 0);
        assert_eq!(report.replayed, 4);
        assert_eq!(report.last_seq, 4);
        assert!(report.corruption.is_none(), "{report:?}");
        assert_eq!(
            bundle_bytes(recovered.as_ref(), "rec"),
            bundle_bytes(index.as_ref(), "orig"),
            "recovered bundle must be byte-identical"
        );
        // The resumed writer continues the sequence.
        let (_, seq) = wal2.append(&WalOp::Compact).unwrap();
        assert_eq!(seq, 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bootstrap_refuses_an_existing_generation() {
        let dir = fresh_dir("refuse");
        let index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let _wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        assert!(Wal::has_snapshot(&dir));
        assert!(Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_rotates_and_recovery_resumes_from_it() {
        let dir = fresh_dir("ckpt");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        let all = ops();
        for op in &all[..3] {
            apply_and_log(&mut index, &wal, op);
        }
        let seq = wal.checkpoint(index.as_ref()).unwrap();
        assert_eq!(seq, 3);
        assert_eq!(wal.snapshot_seq(), 3);
        assert!(snapshot_path(&dir, 3).exists());
        assert!(log_path(&dir, 3).exists());
        assert!(!snapshot_path(&dir, 0).exists(), "old generation deleted");
        assert!(!log_path(&dir, 0).exists());

        apply_and_log(&mut index, &wal, &all[3]);
        drop(wal);
        let (recovered, _wal2, report) = Wal::recover(&dir, FsyncPolicy::Never).unwrap();
        assert_eq!(report.snapshot_seq, 3);
        assert_eq!(report.replayed, 1, "only the post-checkpoint op replays");
        assert_eq!(report.last_seq, 4);
        assert_eq!(
            bundle_bytes(recovered.as_ref(), "rec2"),
            bundle_bytes(index.as_ref(), "orig2"),
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_truncates_a_torn_tail_and_resumes() {
        let dir = fresh_dir("torn");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Always).unwrap();
        for op in &ops()[..2] {
            apply_and_log(&mut index, &wal, op);
        }
        drop(wal);
        // Tear the tail: a half-written record (valid header prefix, cut
        // payload) as the crash would leave it.
        let lp = log_path(&dir, 0);
        let durable = std::fs::metadata(&lp).unwrap().len();
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&lp).unwrap();
            let torn = WalOp::Insert { vector: vec![9.0; 8] }.encode(3);
            let mut framed = Vec::new();
            record::encode_record(&mut framed, (durable % BLOCK_SIZE as u64) as usize, &torn);
            f.write_all(&framed[..framed.len() - 5]).unwrap();
        }

        let (recovered, wal2, report) = Wal::recover(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(report.replayed, 2);
        assert!(report.corruption.is_some());
        assert!(report.dropped_bytes > 0);
        assert_eq!(std::fs::metadata(&lp).unwrap().len(), durable, "file repaired");
        assert_eq!(
            bundle_bytes(recovered.as_ref(), "rec3"),
            bundle_bytes(index.as_ref(), "orig3"),
        );
        // Appends resume on the repaired file and survive another recovery.
        let (w, seq) = wal2.append(&WalOp::Delete { key: 0 }).unwrap();
        assert_eq!(seq, 3);
        w.commit(seq).unwrap();
        drop(wal2);
        let (_, _, report) = Wal::recover(&dir, FsyncPolicy::Always).unwrap();
        assert_eq!(report.replayed, 3);
        assert!(report.corruption.is_none(), "{report:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dump_and_repair_cli_paths() {
        let dir = fresh_dir("dump");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        for op in &ops()[..2] {
            apply_and_log(&mut index, &wal, op);
        }
        wal.sync().unwrap();
        drop(wal);
        let (seq, scan) = Wal::dump(&dir).unwrap();
        assert_eq!(seq, 0);
        assert_eq!(scan.ops.len(), 2);
        assert!(scan.is_clean());

        // Corrupt the tail, then repair cuts it.
        let lp = log_path(&dir, 0);
        let mut f = std::fs::OpenOptions::new().append(true).open(&lp).unwrap();
        f.write_all(&[0xDE, 0xAD, 0xBE, 0xEF, 1, 2, 3, 4]).unwrap();
        drop(f);
        let (_, scan) = Wal::repair(&dir).unwrap();
        assert!(!scan.is_clean());
        assert_eq!(scan.ops.len(), 2);
        assert_eq!(std::fs::metadata(&lp).unwrap().len(), scan.durable_len);
        let (_, scan) = Wal::dump(&dir).unwrap();
        assert!(scan.is_clean(), "repaired log scans clean");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The PR 6 caveat, closed: a non-default compact threshold is an op
    /// in the log, so replay gates compaction exactly as the live run
    /// did. Threshold 1/6 makes one tombstone in six rows cross the
    /// gate — the default 0.3 would decline — so without the logged op
    /// the recovered bundle would differ.
    #[test]
    fn logged_threshold_reaches_replay() {
        let dir = fresh_dir("thresh");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        for op in [
            WalOp::SetThreshold { frac: 1.0 / 6.0 },
            WalOp::Delete { key: 4 },
            WalOp::Compact,
        ] {
            apply_and_log(&mut index, &wal, &op);
        }
        assert_eq!(index.len(), 5, "compaction must have rebuilt over the live set");
        drop(wal);
        let (recovered, _w, report) = Wal::recover(&dir, FsyncPolicy::Never).unwrap();
        assert_eq!(report.replayed, 3);
        assert_eq!(recovered.len(), 5, "replayed compact honors the logged threshold");
        assert_eq!(
            bundle_bytes(recovered.as_ref(), "trec"),
            bundle_bytes(index.as_ref(), "torig"),
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reinstall_replaces_the_generation_with_received_bytes() {
        let dir = fresh_dir("reinst");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        for op in &ops()[..2] {
            apply_and_log(&mut index, &wal, op);
        }
        drop(wal);
        // A "primary snapshot" at seq 10 arrives as bundle bytes.
        let bundle = bundle_bytes(index.as_ref(), "src");
        let wal2 = Wal::reinstall(&dir, 10, &bundle, FsyncPolicy::Never).unwrap();
        assert_eq!(wal2.snapshot_seq(), 10);
        assert_eq!(std::fs::read(snapshot_path(&dir, 10)).unwrap(), bundle, "verbatim bytes");
        assert!(!snapshot_path(&dir, 0).exists(), "old generation deleted");
        assert!(!log_path(&dir, 0).exists());
        let (_, seq) = wal2.append(&WalOp::Compact).unwrap();
        assert_eq!(seq, 11, "appends continue the installed numbering");
        drop(wal2);
        let (rec, _, report) = Wal::recover(&dir, FsyncPolicy::Never).unwrap();
        assert_eq!(report.snapshot_seq, 10);
        assert_eq!(bundle_bytes(rec.as_ref(), "rrec"), bundle_bytes(index.as_ref(), "rorig"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn catchup_since_returns_snapshot_and_tail_as_needed() {
        let dir = fresh_dir("catchup");
        let mut index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let wal = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        let all = ops();
        for op in &all[..3] {
            apply_and_log(&mut index, &wal, op);
        }
        // Caught-up replica: nothing to send.
        let c = wal.catchup_since(3, false).unwrap();
        assert!(c.snapshot.is_none());
        assert!(c.ops.is_empty());
        assert_eq!(c.last_seq(3), 3);
        // Replica at 1: just the tail.
        let c = wal.catchup_since(1, false).unwrap();
        assert!(c.snapshot.is_none());
        assert_eq!(c.ops.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(c.last_seq(1), 3);
        // Fresh replica: full snapshot + whole log.
        let c = wal.catchup_since(0, true).unwrap();
        let (base, bytes) = c.snapshot.expect("fresh replica gets the snapshot");
        assert_eq!(base, 0);
        assert_eq!(bytes, std::fs::read(snapshot_path(&dir, 0)).unwrap());
        assert_eq!(c.ops.len(), 3);
        // After a rotation, a replica behind the new base needs the
        // snapshot even without asking for it.
        let seq = wal.checkpoint(index.as_ref()).unwrap();
        assert_eq!(seq, 3);
        apply_and_log(&mut index, &wal, &all[3]);
        let c = wal.catchup_since(1, false).unwrap();
        let (base, _) = c.snapshot.expect("behind the generation base");
        assert_eq!(base, 3);
        assert_eq!(c.ops.iter().map(|(s, _)| *s).collect::<Vec<_>>(), vec![4]);
        assert_eq!(c.last_seq(1), 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recovery_rejects_a_log_that_skips_its_snapshot() {
        let dir = fresh_dir("skip");
        let index: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(base_matrix())));
        let _ = Wal::bootstrap(&dir, index.as_ref(), FsyncPolicy::Never).unwrap();
        // Hand-write a log whose first op claims seq 5 (snapshot is 0).
        let lp = log_path(&dir, 0);
        let mut bytes = Vec::new();
        record::encode_record(&mut bytes, 0, &WalOp::Compact.encode(5));
        std::fs::write(&lp, &bytes).unwrap();
        let (_, _, report) = Wal::recover(&dir, FsyncPolicy::Never).unwrap();
        assert_eq!(report.replayed, 0, "non-contiguous log must not replay");
        assert!(report.corruption.is_some());
        assert_eq!(std::fs::metadata(&lp).unwrap().len(), 0, "cut to empty");
        std::fs::remove_dir_all(&dir).ok();
    }
}
