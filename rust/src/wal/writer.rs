//! `WalWriter`: append mutation records, group-commit the fsyncs.
//!
//! Appends are serialized on one internal lock and write straight
//! through to the OS (`write(2)` per logical record); durability is a
//! separate [`WalWriter::commit`] step governed by the
//! [`FsyncPolicy`]. The split is what makes **group commit** work: the
//! router applies a mutation and appends its record while holding the
//! index write lock, then releases the lock *before* committing. While
//! one connection's `commit` sits in `fsync(2)`, other connections keep
//! appending; when the fsync returns it covers every record appended
//! before it started, so the later committers observe
//! `synced_seq >= their seq` and return without issuing an fsync of
//! their own — N acknowledgements, one disk flush.

use std::fs::{File, OpenOptions};
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::wal::record::{encode_record, WalOp, BLOCK_SIZE};

/// When an acknowledged mutation is actually on disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Every acknowledgement waits for an fsync covering its record
    /// (group-committed: concurrent mutations share one flush).
    Always,
    /// Fsync once per `n` appended records.
    EveryN(u64),
    /// Fsync when at least this many milliseconds passed since the last.
    IntervalMs(u64),
    /// Never fsync (the OS page cache decides; survives process crashes
    /// but not power loss).
    Never,
}

impl FsyncPolicy {
    /// Parse the CLI spelling: `always | every_n:<N> | interval_ms:<M> |
    /// never`.
    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        match s {
            "always" => return Some(FsyncPolicy::Always),
            "never" => return Some(FsyncPolicy::Never),
            _ => {}
        }
        let (kind, arg) = s.split_once(':')?;
        let v: u64 = arg.parse().ok()?;
        match kind {
            "every_n" if v > 0 => Some(FsyncPolicy::EveryN(v)),
            "interval_ms" => Some(FsyncPolicy::IntervalMs(v)),
            _ => None,
        }
    }

    /// The CLI spelling back.
    pub fn name(&self) -> String {
        match self {
            FsyncPolicy::Always => "always".into(),
            FsyncPolicy::EveryN(n) => format!("every_n:{n}"),
            FsyncPolicy::IntervalMs(m) => format!("interval_ms:{m}"),
            FsyncPolicy::Never => "never".into(),
        }
    }
}

/// Lock that shrugs off poisoning: a panicked mutation handler must not
/// take the log down with it (the bytes already written are still
/// well-formed — an interrupted append leaves a torn tail, which is
/// exactly what recovery handles).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct LogState {
    file: Arc<File>,
    /// Bytes used in the current 32 KiB block.
    block_off: usize,
    /// Total bytes written (the durable-prefix byte length on a clean
    /// sync; `wal truncate` repairs to the scanner's version of this).
    len: u64,
}

struct SyncState {
    /// Highest op seq covered by a completed fsync.
    synced_seq: u64,
    last_sync: Instant,
}

/// Appender over one log file. See the module docs for the locking
/// discipline that yields group commit.
pub struct WalWriter {
    path: PathBuf,
    policy: FsyncPolicy,
    log: Mutex<LogState>,
    sync: Mutex<SyncState>,
    /// Last op seq handed out by `append` (reads don't need the log lock).
    appended_seq: AtomicU64,
    /// Completed `fsync(2)` calls — the observable group-commit ratio.
    syncs: AtomicU64,
}

impl WalWriter {
    /// Create a fresh log at `path` (fails if it already exists: logs are
    /// only ever created by bootstrap/rotation, never overwritten). Ops
    /// appended here get sequence numbers `start_seq + 1, start_seq + 2,
    /// ...` — `start_seq` is the op count baked into the snapshot this
    /// log extends.
    pub fn create(path: &Path, policy: FsyncPolicy, start_seq: u64) -> io::Result<WalWriter> {
        let file = OpenOptions::new().write(true).create_new(true).open(path)?;
        Ok(WalWriter::from_file(path, policy, start_seq, file, 0))
    }

    /// Resume appending to a scanned log: `len` is the durable prefix
    /// length the scanner validated and `last_seq` the last op it
    /// replayed. The caller has already truncated the file to `len`.
    pub fn resume(
        path: &Path,
        policy: FsyncPolicy,
        last_seq: u64,
        len: u64,
    ) -> io::Result<WalWriter> {
        let file = OpenOptions::new().write(true).append(true).open(path)?;
        Ok(WalWriter::from_file(path, policy, last_seq, file, len))
    }

    fn from_file(
        path: &Path,
        policy: FsyncPolicy,
        last_seq: u64,
        file: File,
        len: u64,
    ) -> WalWriter {
        WalWriter {
            path: path.to_path_buf(),
            policy,
            log: Mutex::new(LogState {
                file: Arc::new(file),
                block_off: (len % BLOCK_SIZE as u64) as usize,
                len,
            }),
            sync: Mutex::new(SyncState { synced_seq: last_seq, last_sync: Instant::now() }),
            appended_seq: AtomicU64::new(last_seq),
            syncs: AtomicU64::new(0),
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn policy(&self) -> FsyncPolicy {
        self.policy
    }

    /// Last op seq appended (not necessarily durable yet).
    pub fn appended_seq(&self) -> u64 {
        self.appended_seq.load(Ordering::Acquire)
    }

    /// Highest op seq a completed fsync covers.
    pub fn synced_seq(&self) -> u64 {
        lock(&self.sync).synced_seq
    }

    /// Completed fsyncs (bench/test observability).
    pub fn sync_count(&self) -> u64 {
        self.syncs.load(Ordering::Relaxed)
    }

    /// Bytes appended so far.
    pub fn len(&self) -> u64 {
        lock(&self.log).len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one op; returns its sequence number. The record reaches the
    /// OS before this returns (single `write_all`), but is only durable
    /// once a `commit` at or past the returned seq completes.
    pub fn append(&self, op: &WalOp) -> io::Result<u64> {
        let mut log = lock(&self.log);
        let seq = self.appended_seq.load(Ordering::Acquire) + 1;
        let payload = op.encode(seq);
        let mut bytes = Vec::with_capacity(payload.len() + 64);
        log.block_off = encode_record(&mut bytes, log.block_off, &payload);
        (&*log.file).write_all(&bytes)?;
        log.len += bytes.len() as u64;
        self.appended_seq.store(seq, Ordering::Release);
        Ok(seq)
    }

    /// Make the record at `seq` durable per the policy. Call this
    /// *after* releasing whatever lock serialized the append — that's
    /// what lets concurrent committers share one fsync.
    pub fn commit(&self, seq: u64) -> io::Result<()> {
        match self.policy {
            FsyncPolicy::Never => Ok(()),
            FsyncPolicy::Always => self.sync_to(seq),
            FsyncPolicy::EveryN(n) => {
                let s = lock(&self.sync);
                let pending = self.appended_seq.load(Ordering::Acquire) - s.synced_seq;
                if pending >= n {
                    self.sync_locked(s)?;
                }
                Ok(())
            }
            FsyncPolicy::IntervalMs(ms) => {
                let s = lock(&self.sync);
                if s.last_sync.elapsed().as_millis() as u64 >= ms
                    && self.appended_seq.load(Ordering::Acquire) > s.synced_seq
                {
                    self.sync_locked(s)?;
                }
                Ok(())
            }
        }
    }

    /// Unconditional fsync of everything appended so far (checkpointing,
    /// shutdown).
    pub fn sync(&self) -> io::Result<()> {
        self.sync_to(self.appended_seq.load(Ordering::Acquire))
    }

    /// Ensure a completed fsync covers `seq`; returns without syncing
    /// when another committer's flush already did (the group-commit hit).
    fn sync_to(&self, seq: u64) -> io::Result<()> {
        let s = lock(&self.sync);
        if s.synced_seq >= seq {
            return Ok(());
        }
        self.sync_locked(s)
    }

    /// Fsync covering every append that completed before the flush
    /// starts. Holds only the sync lock, so appends keep flowing.
    fn sync_locked(&self, mut s: MutexGuard<'_, SyncState>) -> io::Result<()> {
        let covered = self.appended_seq.load(Ordering::Acquire);
        let file = Arc::clone(&lock(&self.log).file);
        file.sync_data()?;
        self.syncs.fetch_add(1, Ordering::Relaxed);
        s.synced_seq = covered;
        s.last_sync = Instant::now();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("finger_walw_{}_{name}", std::process::id()))
    }

    #[test]
    fn fsync_policy_parses_and_prints() {
        assert_eq!(FsyncPolicy::parse("always"), Some(FsyncPolicy::Always));
        assert_eq!(FsyncPolicy::parse("never"), Some(FsyncPolicy::Never));
        assert_eq!(FsyncPolicy::parse("every_n:8"), Some(FsyncPolicy::EveryN(8)));
        assert_eq!(FsyncPolicy::parse("interval_ms:50"), Some(FsyncPolicy::IntervalMs(50)));
        assert_eq!(FsyncPolicy::parse("every_n:0"), None);
        assert_eq!(FsyncPolicy::parse("every_n"), None);
        assert_eq!(FsyncPolicy::parse("sometimes"), None);
        for p in ["always", "never", "every_n:3", "interval_ms:250"] {
            assert_eq!(FsyncPolicy::parse(p).unwrap().name(), p);
        }
    }

    #[test]
    fn append_assigns_contiguous_seqs_and_refuses_clobbering() {
        let path = tmp("seq.log");
        std::fs::remove_file(&path).ok();
        let w = WalWriter::create(&path, FsyncPolicy::Never, 10).unwrap();
        assert_eq!(w.append(&WalOp::Compact).unwrap(), 11);
        assert_eq!(w.append(&WalOp::Delete { key: 3 }).unwrap(), 12);
        assert_eq!(w.appended_seq(), 12);
        assert!(w.len() > 0);
        // A second create over a live log must fail, not truncate it.
        assert!(WalWriter::create(&path, FsyncPolicy::Never, 0).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn commit_policies_gate_fsyncs() {
        let run = |policy: FsyncPolicy, n_ops: u64| -> (u64, u64) {
            let path = tmp(&format!("pol_{}.log", policy.name().replace(':', "_")));
            std::fs::remove_file(&path).ok();
            let w = WalWriter::create(&path, policy, 0).unwrap();
            for _ in 0..n_ops {
                let seq = w.append(&WalOp::Compact).unwrap();
                w.commit(seq).unwrap();
            }
            let out = (w.sync_count(), w.synced_seq());
            std::fs::remove_file(&path).ok();
            out
        };
        let (syncs, synced) = run(FsyncPolicy::Always, 10);
        assert_eq!(syncs, 10, "single-threaded always = one fsync per op");
        assert_eq!(synced, 10);
        let (syncs, synced) = run(FsyncPolicy::EveryN(4), 10);
        assert_eq!(syncs, 2, "fsync at op 4 and 8");
        assert_eq!(synced, 8);
        let (syncs, _) = run(FsyncPolicy::Never, 10);
        assert_eq!(syncs, 0);
        let (syncs, _) = run(FsyncPolicy::IntervalMs(3_600_000), 10);
        assert_eq!(syncs, 0, "hour-long interval never fires in-test");
    }

    #[test]
    fn group_commit_shares_fsyncs_across_threads() {
        let path = tmp("group.log");
        std::fs::remove_file(&path).ok();
        let w = Arc::new(WalWriter::create(&path, FsyncPolicy::Always, 0).unwrap());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let w = Arc::clone(&w);
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        let seq = w.append(&WalOp::Compact).unwrap();
                        w.commit(seq).unwrap();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(w.appended_seq(), 100);
        assert_eq!(w.synced_seq(), 100, "every ack is covered by a flush");
        assert!(
            w.sync_count() <= 100,
            "never more fsyncs than ops ({})",
            w.sync_count()
        );
        std::fs::remove_file(&path).ok();
    }
}
