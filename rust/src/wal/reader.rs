//! Log scanner: walk a WAL file, verify every fragment, surface the
//! durable prefix.
//!
//! The scanner never fails on a damaged log — damage at the tail is the
//! *expected* post-crash state. It walks fragments until the first
//! anomaly (short header, short payload, CRC mismatch, bad fragment
//! type, broken First/Middle/Last chain, undecodable logical payload)
//! and reports everything before that point as the durable prefix:
//! the replayable ops, the byte length a repair should truncate to, and
//! a human-readable reason for whatever stopped the scan.

use crate::wal::record::{crc32, FragType, WalOp, BLOCK_SIZE, HEADER_SIZE};

/// Outcome of scanning one log file. `ops` is the durable prefix in
/// order; `durable_len` is the byte offset right after the last complete
/// logical record (what `wal truncate` cuts to); anything between
/// `durable_len` and the file end is `dropped_bytes` explained by
/// `corruption`.
#[derive(Debug)]
pub struct ScanResult {
    pub ops: Vec<(u64, WalOp)>,
    pub durable_len: u64,
    pub dropped_bytes: u64,
    /// `None` means the file ended cleanly at a record boundary.
    pub corruption: Option<String>,
}

impl ScanResult {
    pub fn is_clean(&self) -> bool {
        self.corruption.is_none()
    }

    pub fn last_seq(&self) -> Option<u64> {
        self.ops.last().map(|(seq, _)| *seq)
    }
}

/// Scan the raw bytes of one log file (see module docs). Deterministic
/// and total: any byte string yields a `ScanResult`, never a panic.
pub fn scan_log(bytes: &[u8]) -> ScanResult {
    let mut ops: Vec<(u64, WalOp)> = Vec::new();
    // Byte offset after the last *complete logical record* — partial
    // fragment chains past this point are casualties of the crash.
    let mut durable_len = 0u64;
    let mut pos = 0usize;
    // In-flight fragment chain (First seen, Last pending).
    let mut partial: Option<Vec<u8>> = None;
    let mut corruption: Option<String> = None;

    'scan: while pos < bytes.len() {
        let block_off = pos % BLOCK_SIZE;
        let leftover = BLOCK_SIZE - block_off;
        if leftover < HEADER_SIZE {
            // Writer zero-pads unusable tails; verify and skip.
            let pad = &bytes[pos..bytes.len().min(pos + leftover)];
            if pad.iter().any(|&b| b != 0) {
                corruption = Some(format!("nonzero block padding at byte {pos}"));
                break;
            }
            pos += pad.len();
            continue;
        }
        if pos + HEADER_SIZE > bytes.len() {
            // Torn mid-header: everything written so far is whole records
            // plus this stub.
            corruption = Some(format!(
                "torn fragment header at byte {pos} ({} of {HEADER_SIZE} bytes)",
                bytes.len() - pos
            ));
            break;
        }
        let header = &bytes[pos..pos + HEADER_SIZE];
        if header.iter().all(|&b| b == 0) {
            // All-zero header: writer preallocation or padding that was
            // never overwritten. Clean end of log.
            let tail = &bytes[pos..];
            if tail.iter().any(|&b| b != 0) {
                corruption = Some(format!("garbage after zero header at byte {pos}"));
            }
            break;
        }
        let stored_crc = u32::from_le_bytes(header[..4].try_into().unwrap());
        let len = u16::from_le_bytes(header[4..6].try_into().unwrap()) as usize;
        let Some(ty) = FragType::from_u8(header[6]) else {
            corruption = Some(format!("bad fragment type {} at byte {pos}", header[6]));
            break;
        };
        if HEADER_SIZE + len > leftover {
            corruption = Some(format!(
                "fragment length {len} at byte {pos} overruns the block"
            ));
            break;
        }
        if pos + HEADER_SIZE + len > bytes.len() {
            corruption = Some(format!(
                "torn fragment payload at byte {pos} ({} of {len} bytes)",
                bytes.len() - pos - HEADER_SIZE
            ));
            break;
        }
        let payload = &bytes[pos + HEADER_SIZE..pos + HEADER_SIZE + len];
        let mut check = Vec::with_capacity(1 + len);
        check.push(header[6]);
        check.extend_from_slice(payload);
        if crc32(&check) != stored_crc {
            corruption = Some(format!("crc mismatch on fragment at byte {pos}"));
            break;
        }
        pos += HEADER_SIZE + len;

        // Fragment chain state machine.
        let complete: Option<Vec<u8>> = match (ty, partial.take()) {
            (FragType::Full, None) => Some(payload.to_vec()),
            (FragType::First, None) => {
                partial = Some(payload.to_vec());
                None
            }
            (FragType::Middle, Some(mut acc)) => {
                acc.extend_from_slice(payload);
                partial = Some(acc);
                None
            }
            (FragType::Last, Some(mut acc)) => {
                acc.extend_from_slice(payload);
                Some(acc)
            }
            (ty, state) => {
                corruption = Some(format!(
                    "fragment chain broken at byte {}: {:?} while {}",
                    pos - HEADER_SIZE - len,
                    ty,
                    if state.is_some() { "a record was open" } else { "no record was open" },
                ));
                break 'scan;
            }
        };
        if let Some(logical) = complete {
            match WalOp::decode(&logical) {
                Ok((seq, op)) => {
                    if let Some((prev, _)) = ops.last() {
                        if seq != prev + 1 {
                            corruption = Some(format!(
                                "op sequence jumped {prev} -> {seq} at byte {pos}"
                            ));
                            break;
                        }
                    }
                    ops.push((seq, op));
                    durable_len = pos as u64;
                }
                Err(e) => {
                    corruption = Some(format!("undecodable logical record: {e}"));
                    break;
                }
            }
        }
    }

    if corruption.is_none() {
        if let Some(acc) = partial {
            corruption = Some(format!(
                "log ends inside a fragmented record ({} bytes accumulated)",
                acc.len()
            ));
        } else {
            // Clean end: trailing zero padding after the last record is
            // durable too (rewriting it is a no-op), but truncating to the
            // last record boundary is always safe, so keep durable_len.
        }
    }

    ScanResult {
        ops,
        durable_len,
        dropped_bytes: bytes.len() as u64 - durable_len,
        corruption,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wal::record::encode_record;

    fn log_of(ops: &[(u64, WalOp)]) -> Vec<u8> {
        let mut out = Vec::new();
        let mut off = 0;
        for (seq, op) in ops {
            off = encode_record(&mut out, off, &op.encode(*seq));
        }
        out
    }

    fn three_ops() -> Vec<(u64, WalOp)> {
        vec![
            (1, WalOp::Insert { vector: vec![1.0, 2.0] }),
            (2, WalOp::Delete { key: 0 }),
            (3, WalOp::Compact),
        ]
    }

    #[test]
    fn clean_log_scans_fully() {
        let ops = three_ops();
        let bytes = log_of(&ops);
        let r = scan_log(&bytes);
        assert!(r.is_clean(), "{:?}", r.corruption);
        assert_eq!(r.ops, ops);
        assert_eq!(r.durable_len, bytes.len() as u64);
        assert_eq!(r.dropped_bytes, 0);
        assert_eq!(r.last_seq(), Some(3));
    }

    #[test]
    fn empty_log_is_clean_and_empty() {
        let r = scan_log(&[]);
        assert!(r.is_clean());
        assert!(r.ops.is_empty());
        assert_eq!(r.durable_len, 0);
    }

    #[test]
    fn every_truncation_point_recovers_a_prefix() {
        let ops = three_ops();
        let bytes = log_of(&ops);
        // Boundaries after each complete record.
        let mut boundaries = vec![0u64];
        {
            let mut out = Vec::new();
            let mut off = 0;
            for (seq, op) in &ops {
                off = encode_record(&mut out, off, &op.encode(*seq));
                boundaries.push(out.len() as u64);
            }
        }
        for cut in 0..bytes.len() {
            let r = scan_log(&bytes[..cut]);
            let expect_n = boundaries.iter().filter(|&&b| b <= cut as u64 && b > 0).count();
            assert_eq!(r.ops.len(), expect_n, "cut at {cut}");
            assert_eq!(r.ops[..], ops[..expect_n], "cut at {cut}");
            assert_eq!(r.durable_len, boundaries[expect_n], "cut at {cut}");
            if cut as u64 != boundaries[expect_n] {
                assert!(!r.is_clean(), "cut at {cut} inside a record must report");
            }
        }
    }

    #[test]
    fn bit_flips_are_detected_and_stop_the_scan() {
        let ops = three_ops();
        let bytes = log_of(&ops);
        for flip in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[flip] ^= 0x40;
            let r = scan_log(&bad);
            // Never a panic, never all three ops *plus* garbage; a flip in
            // record i's bytes surfaces at or before record i.
            assert!(r.ops.len() <= ops.len(), "flip at {flip}");
            for (got, want) in r.ops.iter().zip(&ops) {
                if got != want {
                    // Only tolerable if the scan also flagged corruption
                    // before this op... which it can't: CRC covers every
                    // payload byte. So any surfaced op must be intact.
                    panic!("flip at {flip} surfaced a corrupted op");
                }
            }
            // Every byte of this log is either CRC-covered or the CRC
            // itself, so a flip can never scan clean.
            assert!(!r.is_clean(), "flip at {flip} silently accepted");
        }
    }

    #[test]
    fn sequence_gaps_are_corruption() {
        let bytes = log_of(&[(1, WalOp::Compact), (3, WalOp::Compact)]);
        let r = scan_log(&bytes);
        assert!(!r.is_clean());
        assert_eq!(r.ops.len(), 1);
        assert_eq!(r.last_seq(), Some(1));
    }

    #[test]
    fn fragmented_records_reassemble_and_tear_cleanly() {
        // One giant insert spanning blocks, then a small op.
        let big = WalOp::Insert { vector: vec![0.25f32; 20_000] };
        let ops = vec![(1, big), (2, WalOp::Delete { key: 9 })];
        let bytes = log_of(&ops);
        assert!(bytes.len() > 2 * BLOCK_SIZE);
        let r = scan_log(&bytes);
        assert!(r.is_clean(), "{:?}", r.corruption);
        assert_eq!(r.ops, ops);
        // Cut inside the giant record: zero ops, corruption reported.
        let r = scan_log(&bytes[..BLOCK_SIZE + 10]);
        assert_eq!(r.ops.len(), 0);
        assert!(!r.is_clean());
        assert_eq!(r.durable_len, 0);
    }

    #[test]
    fn zero_tail_preallocation_is_a_clean_end() {
        let ops = vec![(1, WalOp::Compact)];
        let mut bytes = log_of(&ops);
        let record_end = bytes.len() as u64;
        bytes.resize(bytes.len() + 256, 0);
        let r = scan_log(&bytes);
        assert!(r.is_clean(), "{:?}", r.corruption);
        assert_eq!(r.ops, ops);
        assert_eq!(r.durable_len, record_end);
    }
}
