//! Hand-rolled CLI argument parsing (no clap in the offline environment).

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` / `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&argv(&[
            "bench", "figure5", "--scale", "0.5", "--out=results", "--verbose",
        ]));
        assert_eq!(a.positional, vec!["bench", "figure5"]);
        assert_eq!(a.get("scale"), Some("0.5"));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.get_f64("scale", 1.0), 0.5);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = Args::parse(&argv(&["serve", "--dry-run"]));
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn flag_before_positional_consumes_value() {
        // Documented behavior: `--key value` greedily binds the next
        // non-`--` token, so boolean flags belong after positionals.
        let a = Args::parse(&argv(&["--rerank", "serve"]));
        assert_eq!(a.get("rerank"), Some("serve"));
    }
}
