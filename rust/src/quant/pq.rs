//! Product Quantization (Jégou et al., TPAMI 2011) with ADC lookup —
//! substrate for the IVF-PQ baseline of Figure 7.
//!
//! The feature space is split into `n_sub` contiguous subspaces, each
//! quantized by its own 2^nbits-codeword k-means codebook. A query builds
//! a (n_sub × k) distance table once; per-candidate scoring is then n_sub
//! table lookups — the "fast-scan" style arithmetic-intensity reduction
//! the paper's quantization comparators (ScaNN, Faiss-IVFPQFS) rely on.

use crate::core::matrix::Matrix;
use crate::quant::kmeans::KMeans;

#[derive(Clone, Debug)]
pub struct PqParams {
    /// Number of subquantizers (must divide dim... or last gets remainder).
    pub n_sub: usize,
    /// Codebook bits per subquantizer (k = 2^nbits, typically 4 or 8).
    pub nbits: usize,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for PqParams {
    fn default() -> Self {
        Self {
            n_sub: 8,
            nbits: 8,
            kmeans_iters: 15,
            seed: 42,
        }
    }
}

pub struct Pq {
    pub params: PqParams,
    /// Per-subspace codebooks.
    pub books: Vec<KMeans>,
    /// Subspace column ranges.
    pub ranges: Vec<(usize, usize)>,
    /// Encoded dataset: n × n_sub codes.
    pub codes: Vec<u8>,
    pub n: usize,
}

impl Pq {
    pub fn train(data: &Matrix, params: PqParams) -> Pq {
        let m = data.cols();
        let n_sub = params.n_sub.min(m);
        let k = 1usize << params.nbits;
        assert!(k <= 256, "codes stored as u8");

        // Contiguous ranges, remainder to the last subspace.
        let base = m / n_sub;
        let mut ranges = Vec::with_capacity(n_sub);
        for s in 0..n_sub {
            let lo = s * base;
            let hi = if s == n_sub - 1 { m } else { lo + base };
            ranges.push((lo, hi));
        }

        let books: Vec<KMeans> = ranges
            .iter()
            .enumerate()
            .map(|(s, &(lo, hi))| {
                KMeans::train_subspace(data, lo, hi, k, params.kmeans_iters, params.seed + s as u64)
            })
            .collect();

        // Encode.
        let n = data.rows();
        let mut codes = vec![0u8; n * n_sub];
        for i in 0..n {
            for (s, &(lo, hi)) in ranges.iter().enumerate() {
                codes[i * n_sub + s] = books[s].assign(&data.row(i)[lo..hi]) as u8;
            }
        }

        Pq {
            params,
            books,
            ranges,
            codes,
            n,
        }
    }

    /// Encode one vector with the *frozen* codebooks (length `n_sub`).
    /// The online-insert path of the quantized tier: codebooks are never
    /// retrained, so replay and compaction stay deterministic.
    pub fn encode_row(&self, v: &[f32]) -> Vec<u8> {
        self.ranges
            .iter()
            .enumerate()
            .map(|(s, &(lo, hi))| self.books[s].assign(&v[lo..hi]) as u8)
            .collect()
    }

    /// Append one pre-encoded row (pairs with [`Pq::encode_row`]).
    pub fn push_codes(&mut self, codes: &[u8]) {
        assert_eq!(codes.len(), self.ranges.len(), "code width mismatch");
        self.codes.extend_from_slice(codes);
        self.n += 1;
    }

    /// Build the ADC table for a query: (n_sub × k) squared distances from
    /// each query sub-vector to each codeword.
    pub fn adc_table(&self, q: &[f32]) -> Vec<f32> {
        let mut table = Vec::new();
        self.adc_table_into(q, &mut table);
        table
    }

    /// [`Pq::adc_table`] into a caller-pooled buffer (search hot path).
    pub fn adc_table_into(&self, q: &[f32], table: &mut Vec<f32>) {
        let k = 1usize << self.params.nbits;
        let n_sub = self.ranges.len();
        table.clear();
        table.resize(n_sub * k, 0.0);
        for (s, &(lo, hi)) in self.ranges.iter().enumerate() {
            let sub = &q[lo..hi];
            let book = &self.books[s];
            for c in 0..book.k() {
                table[s * k + c] = crate::core::distance::l2_sq(sub, book.centroids.row(c));
            }
        }
    }

    /// Approximate squared distance of encoded point `i` via the ADC table.
    #[inline]
    pub fn adc_dist(&self, table: &[f32], i: usize) -> f32 {
        let k = 1usize << self.params.nbits;
        let n_sub = self.ranges.len();
        let codes = &self.codes[i * n_sub..(i + 1) * n_sub];
        let mut acc = 0.0f32;
        for (s, &c) in codes.iter().enumerate() {
            acc += table[s * k + c as usize];
        }
        acc
    }

    /// Bytes per encoded vector.
    pub fn code_bytes(&self) -> usize {
        self.ranges.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::l2_sq;
    use crate::core::rng::Pcg32;
    use crate::data::synth::tiny;
    use crate::core::distance::Metric;

    #[test]
    fn adc_approximates_true_distance() {
        let ds = tiny(91, 500, 32, Metric::L2);
        let pq = Pq::train(&ds.data, PqParams { n_sub: 8, nbits: 6, ..Default::default() });
        let q = ds.queries.row(0);
        let table = pq.adc_table(q);
        let mut adc = Vec::new();
        let mut exact = Vec::new();
        for i in 0..ds.data.rows() {
            adc.push(pq.adc_dist(&table, i));
            exact.push(l2_sq(q, ds.data.row(i)));
        }
        let corr = crate::core::stats::pearson(&adc, &exact);
        assert!(corr > 0.9, "ADC correlation = {corr}");
    }

    #[test]
    fn codes_in_range() {
        let ds = tiny(92, 200, 16, Metric::L2);
        let pq = Pq::train(&ds.data, PqParams { n_sub: 4, nbits: 4, ..Default::default() });
        assert!(pq.codes.iter().all(|&c| (c as usize) < 16));
        assert_eq!(pq.codes.len(), 200 * 4);
    }

    #[test]
    fn ragged_dim_handled() {
        // dim 10 with 4 subspaces -> ranges 2,2,2,4
        let mut rng = Pcg32::new(1);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..64 {
            let row: Vec<f32> = (0..10).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let pq = Pq::train(&data, PqParams { n_sub: 4, nbits: 4, ..Default::default() });
        assert_eq!(pq.ranges.last().unwrap().1, 10);
        let q: Vec<f32> = (0..10).map(|_| rng.next_gaussian()).collect();
        let t = pq.adc_table(&q);
        assert!(pq.adc_dist(&t, 0).is_finite());
    }

    #[test]
    fn reconstruction_better_with_more_bits() {
        let ds = tiny(93, 400, 16, Metric::L2);
        let q = ds.queries.row(0);
        let err = |nbits: usize| {
            let pq = Pq::train(&ds.data, PqParams { n_sub: 4, nbits, ..Default::default() });
            let t = pq.adc_table(q);
            let mut e = 0.0f64;
            for i in 0..ds.data.rows() {
                let d = l2_sq(q, ds.data.row(i));
                e += (pq.adc_dist(&t, i) - d).abs() as f64 / (1.0 + d as f64);
            }
            e
        };
        assert!(err(6) < err(2), "6-bit should beat 2-bit");
    }
}
