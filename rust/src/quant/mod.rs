//! Quantization baselines for Figure 7: k-means, Product Quantization
//! with ADC, and IVF-PQ with exact re-ranking.

pub mod ivfpq;
pub mod kmeans;
pub mod pq;

pub use ivfpq::{IvfPq, IvfPqParams};
pub use kmeans::KMeans;
pub use pq::{Pq, PqParams};
