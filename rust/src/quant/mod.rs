//! Quantization plane: k-means, Product Quantization with ADC, IVF-PQ
//! with exact re-ranking (the Figure 7 baselines), and the SQ8/PQ
//! quantized traversal tier the beam-search cores run on.

pub mod ivfpq;
pub mod kmeans;
pub mod pq;
pub mod sq8;

pub use ivfpq::{IvfPq, IvfPqParams};
pub use kmeans::KMeans;
pub use pq::{Pq, PqParams};
pub use sq8::{Precision, QuantTier, Sq8Codec, TierScorer};
