//! IVF-PQ: coarse inverted-file quantizer + PQ residual scoring with exact
//! re-ranking — the stand-in for ScaNN / Faiss-IVFPQFS in Figure 7
//! (DESIGN.md §5: same algorithmic family, same tradeoff shape).

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::graph::search::Neighbor;
use crate::index::context::{SearchContext, SearchParams};
use crate::quant::kmeans::KMeans;
use crate::quant::pq::{Pq, PqParams};

#[derive(Clone, Debug)]
pub struct IvfPqParams {
    /// Number of coarse cells.
    pub n_list: usize,
    pub pq: PqParams,
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for IvfPqParams {
    fn default() -> Self {
        Self {
            n_list: 64,
            pq: PqParams::default(),
            kmeans_iters: 15,
            seed: 42,
        }
    }
}

pub struct IvfPq {
    pub params: IvfPqParams,
    pub coarse: KMeans,
    /// Inverted lists: point ids per cell.
    pub lists: Vec<Vec<u32>>,
    pub pq: Pq,
}

impl IvfPq {
    pub fn train(data: &Matrix, params: IvfPqParams) -> IvfPq {
        let coarse = KMeans::train(data, params.n_list, params.kmeans_iters, params.seed);
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); coarse.k()];
        for i in 0..data.rows() {
            lists[coarse.assign(data.row(i))].push(i as u32);
        }
        // PQ trained on raw vectors (residual encoding would be slightly
        // better; raw keeps the ADC table query-global, which is what the
        // fast-scan variants exploit).
        let pq = Pq::train(data, params.pq.clone());
        IvfPq {
            params,
            coarse,
            lists,
            pq,
        }
    }

    /// Search: probe `params.n_probe` nearest cells, score members by ADC
    /// (counted as `approx_calls`), keep the best `params.rerank_width()`,
    /// re-rank those exactly when `params.rerank` (counted as
    /// `dist_calls`), return top-k. The ADC shortlist lives in the pooled
    /// `ctx.pool`, so the scoring loop does not allocate once warm.
    pub fn search(
        &self,
        data: &Matrix,
        q: &[f32],
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Neighbor> {
        let k = params.k;
        // Rank cells by centroid distance.
        let mut cells: Vec<(f32, usize)> = (0..self.coarse.k())
            .map(|c| (l2_sq(q, self.coarse.centroids.row(c)), c))
            .collect();
        cells.sort_by(|a, b| a.0.total_cmp(&b.0));

        let table = self.pq.adc_table(q);
        ctx.pool.clear();
        let mut scored = 0u64;
        for &(_, cell) in cells.iter().take(params.n_probe.max(1)) {
            for &id in &self.lists[cell] {
                ctx.pool.push(Neighbor {
                    dist: self.pq.adc_dist(&table, id as usize),
                    id,
                });
                scored += 1;
            }
        }
        if ctx.stats_enabled {
            ctx.stats.approx_calls += scored;
        }
        ctx.pool.sort();

        if !params.rerank {
            // Pure ADC ranking — no exact distance computations at all.
            ctx.pool.truncate(k);
            return ctx.pool.clone();
        }
        ctx.pool.truncate(params.rerank_width());

        // Exact re-rank (this is the path the Rust runtime can offload to
        // the PJRT rerank artifact; see runtime::engine).
        let mut exact: Vec<Neighbor> = ctx
            .pool
            .iter()
            .map(|c| Neighbor {
                dist: l2_sq(q, data.row(c.id as usize)),
                id: c.id,
            })
            .collect();
        if ctx.stats_enabled {
            ctx.stats.dist_calls += exact.len() as u64;
        }
        exact.sort();
        exact.truncate(k);
        exact
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;

    #[test]
    fn all_points_indexed_once() {
        let ds = tiny(95, 300, 16, Metric::L2);
        let ivf = IvfPq::train(&ds.data, IvfPqParams { n_list: 16, ..Default::default() });
        let total: usize = ivf.lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 300);
        let mut seen = vec![false; 300];
        for l in &ivf.lists {
            for &id in l {
                assert!(!seen[id as usize]);
                seen[id as usize] = true;
            }
        }
    }

    #[test]
    fn recall_improves_with_probes() {
        let ds = tiny(96, 800, 24, Metric::L2);
        let ivf = IvfPq::train(&ds.data, IvfPqParams { n_list: 32, ..Default::default() });
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let mut ctx = SearchContext::new();
        let mut recall_at = |n_probe: usize| {
            let params = SearchParams::new(10).with_probes(n_probe).with_rerank_depth(100);
            let mut total = 0.0;
            for qi in 0..ds.queries.rows() {
                let res = ivf.search(&ds.data, ds.queries.row(qi), &params, &mut ctx);
                let hits = res.iter().filter(|n| gt[qi].contains(&n.id)).count();
                total += hits as f64 / 10.0;
            }
            total / ds.queries.rows() as f64
        };
        let r1 = recall_at(1);
        let r16 = recall_at(16);
        assert!(r16 > r1, "recall@1probe {r1} vs @16probe {r16}");
        assert!(r16 > 0.85, "r16 = {r16}");
    }

    #[test]
    fn scored_counts_probed_cells_only() {
        let ds = tiny(97, 200, 8, Metric::L2);
        let ivf = IvfPq::train(&ds.data, IvfPqParams { n_list: 8, ..Default::default() });
        let mut ctx = SearchContext::new().with_stats();
        let p = SearchParams::new(5).with_rerank_depth(20);
        ivf.search(&ds.data, ds.queries.row(0), &p.clone().with_probes(1), &mut ctx);
        let scored_1 = ctx.take_stats().approx_calls;
        ivf.search(&ds.data, ds.queries.row(0), &p.with_probes(8), &mut ctx);
        let scored_all = ctx.take_stats().approx_calls;
        assert!(scored_1 < scored_all);
        assert_eq!(scored_all, 200);
    }

    #[test]
    fn rerank_toggle_controls_exact_calls() {
        let ds = tiny(98, 300, 16, Metric::L2);
        let ivf = IvfPq::train(&ds.data, IvfPqParams { n_list: 8, ..Default::default() });
        let mut ctx = SearchContext::new().with_stats();
        let base = SearchParams::new(5).with_probes(4);
        ivf.search(&ds.data, ds.queries.row(0), &base, &mut ctx);
        let with_rerank = ctx.take_stats();
        assert_eq!(with_rerank.dist_calls, base.rerank_width() as u64);
        ivf.search(&ds.data, ds.queries.row(0), &base.with_rerank(false), &mut ctx);
        let without = ctx.take_stats();
        assert_eq!(without.dist_calls, 0, "rerank off must not touch raw vectors");
        assert!(without.approx_calls > 0);
    }
}
