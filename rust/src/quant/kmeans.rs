//! Lloyd's k-means with k-means++ seeding — substrate for PQ / IVF.

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct KMeans {
    /// k × m centroids.
    pub centroids: Matrix,
}

impl KMeans {
    /// Train on rows of `data` restricted to columns [col_lo, col_hi).
    pub fn train_subspace(
        data: &Matrix,
        col_lo: usize,
        col_hi: usize,
        k: usize,
        iters: usize,
        seed: u64,
    ) -> KMeans {
        let n = data.rows();
        let m = col_hi - col_lo;
        assert!(n > 0 && k > 0);
        let k = k.min(n);
        let mut rng = Pcg32::new(seed);

        let row = |i: usize| &data.row(i)[col_lo..col_hi];

        // k-means++ seeding.
        let mut centroids = Matrix::zeros(0, 0);
        centroids.push_row(row(rng.gen_range(n)));
        let mut d2: Vec<f32> = (0..n)
            .map(|i| l2_sq(row(i), centroids.row(0)))
            .collect();
        while centroids.rows() < k {
            let total: f64 = d2.iter().map(|&x| x as f64).sum();
            let pick = if total <= 0.0 {
                rng.gen_range(n)
            } else {
                let mut target = rng.next_f64() * total;
                let mut idx = n - 1;
                for (i, &x) in d2.iter().enumerate() {
                    target -= x as f64;
                    if target <= 0.0 {
                        idx = i;
                        break;
                    }
                }
                idx
            };
            centroids.push_row(row(pick));
            let c = centroids.rows() - 1;
            for i in 0..n {
                let d = l2_sq(row(i), centroids.row(c));
                if d < d2[i] {
                    d2[i] = d;
                }
            }
        }

        // Lloyd iterations.
        let mut assign = vec![0usize; n];
        for _ in 0..iters {
            let mut changed = false;
            for i in 0..n {
                let mut best = (f32::INFINITY, 0usize);
                for c in 0..centroids.rows() {
                    let d = l2_sq(row(i), centroids.row(c));
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                if assign[i] != best.1 {
                    assign[i] = best.1;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
            let mut sums = vec![0.0f64; centroids.rows() * m];
            let mut counts = vec![0usize; centroids.rows()];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for (j, &v) in row(i).iter().enumerate() {
                    sums[c * m + j] += v as f64;
                }
            }
            for c in 0..centroids.rows() {
                if counts[c] == 0 {
                    // Re-seed empty cluster at a random point.
                    let p = rng.gen_range(n);
                    centroids.row_mut(c).copy_from_slice(row(p));
                    continue;
                }
                for j in 0..m {
                    centroids.row_mut(c)[j] = (sums[c * m + j] / counts[c] as f64) as f32;
                }
            }
        }
        KMeans { centroids }
    }

    pub fn train(data: &Matrix, k: usize, iters: usize, seed: u64) -> KMeans {
        Self::train_subspace(data, 0, data.cols(), k, iters, seed)
    }

    /// Nearest centroid index for `x` (in the trained subspace's width).
    pub fn assign(&self, x: &[f32]) -> usize {
        let mut best = (f32::INFINITY, 0usize);
        for c in 0..self.centroids.rows() {
            let d = l2_sq(x, self.centroids.row(c));
            if d < best.0 {
                best = (d, c);
            }
        }
        best.1
    }

    pub fn k(&self) -> usize {
        self.centroids.rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blob_data(seed: u64) -> Matrix {
        let mut rng = Pcg32::new(seed);
        let mut m = Matrix::zeros(0, 0);
        for i in 0..200 {
            let base = if i % 2 == 0 { -5.0 } else { 5.0 };
            m.push_row(&[base + 0.3 * rng.next_gaussian(), 0.3 * rng.next_gaussian()]);
        }
        m
    }

    #[test]
    fn separates_two_blobs() {
        let data = two_blob_data(1);
        let km = KMeans::train(&data, 2, 20, 7);
        let c0 = km.centroids.row(0)[0];
        let c1 = km.centroids.row(1)[0];
        assert!(c0 * c1 < 0.0, "centroids on opposite sides: {c0} {c1}");
        assert!((c0.abs() - 5.0).abs() < 0.5);
    }

    #[test]
    fn assignment_is_nearest() {
        let data = two_blob_data(2);
        let km = KMeans::train(&data, 2, 20, 3);
        let a = km.assign(&[-5.0, 0.0]);
        let b = km.assign(&[5.0, 0.0]);
        assert_ne!(a, b);
    }

    #[test]
    fn k_clamped_to_n() {
        let data = Matrix::from_rows(&[vec![0.0], vec![1.0]]);
        let km = KMeans::train(&data, 10, 5, 1);
        assert!(km.k() <= 2);
    }

    #[test]
    fn subspace_training_ignores_other_columns() {
        let mut rng = Pcg32::new(4);
        let mut m = Matrix::zeros(0, 0);
        for i in 0..100 {
            let x = if i % 2 == 0 { -3.0 } else { 3.0 };
            m.push_row(&[1000.0 * rng.next_gaussian(), x + 0.1 * rng.next_gaussian()]);
        }
        let km = KMeans::train_subspace(&m, 1, 2, 2, 20, 5);
        assert_eq!(km.centroids.cols(), 1);
        let spread = (km.centroids.row(0)[0] - km.centroids.row(1)[0]).abs();
        assert!(spread > 4.0, "spread {spread}");
    }
}
