//! SQ8 scalar quantization and the per-index quantized traversal tier.
//!
//! The beam-search cores can traverse on approximate distances instead of
//! full-precision f32 rows (see `graph::search::beam_search_approx_filtered`):
//! [`Sq8Codec`] maps each vector to one byte per dimension, [`Sq8Store`]
//! (in `core::store`) holds the codes lane-padded and cache-aligned, and
//! the runtime-dispatched u8 kernel scores 16 codes per instruction —
//! 4x less bandwidth than the f32 rows that used to stream through the
//! hot loop. An exact f32 re-rank of the final candidate pool restores
//! ordering (`graph::search::rerank_exact`).
//!
//! ## Codec
//!
//! Per-dimension min/max with one **shared** step size:
//!
//! ```text
//! delta = max_j (maxs[j] - mins[j]) / 255
//! code[j] = round((x[j] - mins[j]) / delta) clamped to [0, 255]
//! ```
//!
//! A shared `delta` (rather than per-dim steps) keeps the approximate
//! distance a single rescale of the integer kernel output:
//! `approx_l2 = delta² · Σ (code_a[j] - code_b[j])²` — no per-dim weights
//! in the loop. All training arithmetic is plain f32 so the codec (and
//! therefore every persisted byte) is identical across kernels and
//! thread counts.
//!
//! ## Freeze discipline
//!
//! Codec parameters are trained **once at build** and never retrained:
//! online inserts encode with the frozen codec, compaction gathers the
//! surviving code rows verbatim. That keeps WAL replay and
//! compact-vs-rebuild byte-identical, at the cost of inserts far outside
//! the trained range clamping to the [0, 255] edge (they still re-rank
//! exactly). `rust/tests/mutation_props.rs` pins the lockstep invariant:
//! `codes(i) == encode(row(i))` for every live row at every step.

use crate::core::distance::u8_l2_sq;
use crate::core::matrix::Matrix;
use crate::core::store::Sq8Store;
use crate::graph::search::ApproxScorer;
use crate::quant::pq::Pq;

/// Which distance tier a family's beam search traverses on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Full-precision f32 rows (the default; no quantized tier is built).
    F32,
    /// SQ8 codes drive the beam; exact f32 re-rank of the final pool.
    Sq8,
    /// PQ ADC-table lookups drive the beam; exact f32 re-rank.
    Pq,
}

impl Precision {
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "full" => Some(Precision::F32),
            "sq8" => Some(Precision::Sq8),
            "pq" => Some(Precision::Pq),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Sq8 => "sq8",
            Precision::Pq => "pq",
        }
    }

    /// Stable on-disk tag (format v6 quant section).
    pub fn tag(&self) -> u64 {
        match self {
            Precision::F32 => 0,
            Precision::Sq8 => 1,
            Precision::Pq => 2,
        }
    }

    pub fn from_tag(t: u64) -> Option<Precision> {
        match t {
            0 => Some(Precision::F32),
            1 => Some(Precision::Sq8),
            2 => Some(Precision::Pq),
            _ => None,
        }
    }
}

/// Per-dim min/max scalar quantizer with a shared step (see module docs).
#[derive(Clone, Debug)]
pub struct Sq8Codec {
    pub mins: Vec<f32>,
    pub maxs: Vec<f32>,
    /// Shared step size; `delta²` rescales the integer kernel output.
    pub delta: f32,
}

impl Sq8Codec {
    /// Train on all rows of `data` (plain f32 arithmetic, deterministic).
    /// NaN entries are ignored for range-finding; degenerate ranges (empty
    /// data, constant or all-NaN columns) fall back to `delta = 1`.
    pub fn train(data: &Matrix) -> Sq8Codec {
        let dim = data.cols();
        let mut mins = vec![f32::INFINITY; dim];
        let mut maxs = vec![f32::NEG_INFINITY; dim];
        for i in 0..data.rows() {
            for (j, &v) in data.row(i).iter().enumerate() {
                if v < mins[j] {
                    mins[j] = v;
                }
                if v > maxs[j] {
                    maxs[j] = v;
                }
            }
        }
        for j in 0..dim {
            if !mins[j].is_finite() || !maxs[j].is_finite() {
                mins[j] = 0.0;
                maxs[j] = 0.0;
            }
        }
        Sq8Codec::from_ranges(mins, maxs)
    }

    /// Rebuild the codec from persisted ranges; `delta` is re-derived the
    /// same way `train` derives it, so save/load cannot drift (the saved
    /// delta is still written and checked for belt-and-braces).
    pub fn from_ranges(mins: Vec<f32>, maxs: Vec<f32>) -> Sq8Codec {
        let mut span = 0.0f32;
        for (lo, hi) in mins.iter().zip(&maxs) {
            let s = hi - lo;
            if s > span {
                span = s;
            }
        }
        let delta = if span > 0.0 { span / 255.0 } else { 1.0 };
        Sq8Codec { mins, maxs, delta }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.mins.len()
    }

    /// Encode one vector into `out` (length = dim). Out-of-range values
    /// clamp to the byte edges; NaN encodes as 0 (deterministically).
    pub fn encode_into(&self, v: &[f32], out: &mut Vec<u8>) {
        assert_eq!(v.len(), self.dim(), "encode dim mismatch");
        out.clear();
        for (j, &x) in v.iter().enumerate() {
            let q = ((x - self.mins[j]) / self.delta).round().clamp(0.0, 255.0);
            out.push(q as u8); // saturating cast; NaN -> 0
        }
    }

    pub fn encode(&self, v: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.dim());
        self.encode_into(v, &mut out);
        out
    }

    /// Scale factor from integer code distance to approximate squared L2.
    #[inline]
    pub fn dist_scale(&self) -> f32 {
        self.delta * self.delta
    }

    /// Codec parameter bytes (mins + maxs + delta).
    pub fn nbytes(&self) -> usize {
        (self.mins.len() + self.maxs.len() + 1) * std::mem::size_of::<f32>()
    }
}

/// The quantized sibling of an index's `VectorStore`, kept in row
/// lockstep with it: row `i` of the tier encodes row `i` of the data.
/// Built once per index when `Precision != F32`.
pub enum QuantTier {
    Sq8 { codec: Sq8Codec, store: Sq8Store },
    Pq { pq: Pq },
}

impl QuantTier {
    /// Build the tier for `precision` over `data` (`None` for F32).
    pub fn build(precision: Precision, data: &Matrix) -> Option<QuantTier> {
        match precision {
            Precision::F32 => None,
            Precision::Sq8 => {
                let codec = Sq8Codec::train(data);
                let mut store = Sq8Store::with_dims(data.rows(), data.cols());
                let mut codes = Vec::with_capacity(data.cols());
                for i in 0..data.rows() {
                    codec.encode_into(data.row(i), &mut codes);
                    store.push_row(&codes);
                }
                Some(QuantTier::Sq8 { codec, store })
            }
            Precision::Pq => Some(QuantTier::Pq {
                pq: Pq::train(data, Default::default()),
            }),
        }
    }

    pub fn precision(&self) -> Precision {
        match self {
            QuantTier::Sq8 { .. } => Precision::Sq8,
            QuantTier::Pq { .. } => Precision::Pq,
        }
    }

    /// Number of encoded rows (must equal the f32 store's row count).
    pub fn rows(&self) -> usize {
        match self {
            QuantTier::Sq8 { store, .. } => store.rows(),
            QuantTier::Pq { pq } => pq.n,
        }
    }

    /// Encode and append one row with the *frozen* codec/codebooks
    /// (online-insert mirror of the f32 store's `push_row`).
    pub fn push_row(&mut self, v: &[f32]) {
        match self {
            QuantTier::Sq8 { codec, store } => {
                let codes = codec.encode(v);
                store.push_row(&codes);
            }
            QuantTier::Pq { pq } => {
                let codes = pq.encode_row(v);
                pq.push_codes(&codes);
            }
        }
    }

    /// Compaction: gather surviving code rows in `keep` order (old row
    /// indices), codec/codebooks frozen — no re-encode, so the compacted
    /// tier is byte-identical to a replayed one.
    pub fn gather_rows(&mut self, keep: &[usize]) {
        match self {
            QuantTier::Sq8 { store, .. } => {
                let mut next = Sq8Store::with_dims(keep.len(), store.cols());
                for &old in keep {
                    next.push_row(store.row_logical(old));
                }
                *store = next;
            }
            QuantTier::Pq { pq } => {
                let w = pq.ranges.len();
                let mut codes = Vec::with_capacity(keep.len() * w);
                for &old in keep {
                    codes.extend_from_slice(&pq.codes[old * w..(old + 1) * w]);
                }
                pq.codes = codes;
                pq.n = keep.len();
            }
        }
    }

    /// Resident bytes of the quantized tier (codes + codec parameters).
    pub fn nbytes(&self) -> usize {
        match self {
            QuantTier::Sq8 { codec, store } => codec.nbytes() + store.nbytes(),
            QuantTier::Pq { pq } => {
                let book_bytes: usize = pq
                    .books
                    .iter()
                    .map(|b| b.centroids.rows() * b.centroids.cols() * 4)
                    .sum();
                book_bytes + pq.codes.len()
            }
        }
    }

    /// Build the per-query scorer. `qcodes`/`qtable` are pooled scratch
    /// buffers (see `SearchContext`) the scorer borrows for the query's
    /// lifetime: SQ8 encodes + pads the query into `qcodes`, PQ builds
    /// its ADC table into `qtable`.
    pub fn scorer<'a>(
        &'a self,
        q: &[f32],
        qcodes: &'a mut Vec<u8>,
        qtable: &'a mut Vec<f32>,
    ) -> TierScorer<'a> {
        match self {
            QuantTier::Sq8 { codec, store } => {
                codec.encode_into(q, qcodes);
                qcodes.resize(store.padded_cols(), 0);
                TierScorer::Sq8 {
                    store,
                    scale: codec.dist_scale(),
                    qcodes,
                }
            }
            QuantTier::Pq { pq } => {
                pq.adc_table_into(q, qtable);
                TierScorer::Pq { pq, table: qtable }
            }
        }
    }
}

/// Per-query [`ApproxScorer`] over a [`QuantTier`].
pub enum TierScorer<'a> {
    Sq8 {
        store: &'a Sq8Store,
        scale: f32,
        qcodes: &'a [u8],
    },
    Pq { pq: &'a Pq, table: &'a [f32] },
}

impl ApproxScorer for TierScorer<'_> {
    #[inline]
    fn dist(&mut self, row: usize) -> f32 {
        match self {
            TierScorer::Sq8 { store, scale, qcodes } => {
                *scale * u8_l2_sq(qcodes, store.row(row)) as f32
            }
            TierScorer::Pq { pq, table } => pq.adc_dist(table, row),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::{l2_sq, Metric};
    use crate::core::rng::Pcg32;
    use crate::data::synth::tiny;

    #[test]
    fn precision_parse_name_tag_roundtrip() {
        for p in [Precision::F32, Precision::Sq8, Precision::Pq] {
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(Precision::from_tag(p.tag()), Some(p));
        }
        assert_eq!(Precision::parse("full"), Some(Precision::F32));
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::from_tag(9), None);
    }

    #[test]
    fn codes_cover_the_range_and_roundtrip_error_is_bounded() {
        let ds = tiny(31, 300, 24, Metric::L2);
        let codec = Sq8Codec::train(&ds.data);
        assert!(codec.delta > 0.0);
        for i in 0..ds.data.rows() {
            let codes = codec.encode(ds.data.row(i));
            for (j, (&c, &x)) in codes.iter().zip(ds.data.row(i)).enumerate() {
                // Reconstruction within half a step.
                let rec = codec.mins[j] + c as f32 * codec.delta;
                assert!(
                    (rec - x).abs() <= 0.5 * codec.delta + 1e-5,
                    "row {i} dim {j}: rec={rec} x={x}"
                );
            }
        }
    }

    #[test]
    fn approx_distance_correlates_with_exact() {
        let ds = tiny(32, 400, 16, Metric::L2);
        let tier = QuantTier::build(Precision::Sq8, &ds.data).unwrap();
        let q = ds.queries.row(0);
        let (mut qc, mut qt) = (Vec::new(), Vec::new());
        let mut sc = tier.scorer(q, &mut qc, &mut qt);
        let mut approx = Vec::new();
        let mut exact = Vec::new();
        for i in 0..ds.data.rows() {
            approx.push(sc.dist(i));
            exact.push(l2_sq(q, ds.data.row(i)));
        }
        let corr = crate::core::stats::pearson(&approx, &exact);
        assert!(corr > 0.99, "SQ8 correlation = {corr}");
    }

    #[test]
    fn degenerate_inputs_encode_deterministically() {
        // Constant columns, NaN, and out-of-range inserts must all map to
        // well-defined codes.
        let m = Matrix::from_rows(&[vec![1.0, 5.0], vec![1.0, 5.0]]);
        let codec = Sq8Codec::train(&m);
        assert_eq!(codec.delta, 1.0, "constant data falls back to unit step");
        assert_eq!(codec.encode(&[1.0, 5.0]), vec![0, 0]);
        assert_eq!(codec.encode(&[f32::NAN, 1e9]), vec![0, 255]);
        assert_eq!(codec.encode(&[-1e9, -1e9]), vec![0, 0]);
        let empty = Sq8Codec::train(&Matrix::zeros(0, 3));
        assert_eq!(empty.encode(&[0.5, -0.5, 0.0]), vec![1, 0, 0]);
    }

    #[test]
    fn tier_insert_and_gather_stay_in_lockstep() {
        let ds = tiny(33, 60, 8, Metric::L2);
        let mut rng = Pcg32::new(7);
        for p in [Precision::Sq8, Precision::Pq] {
            let mut tier = QuantTier::build(p, &ds.data).unwrap();
            let frozen = QuantTier::build(p, &ds.data).unwrap();
            let mut rows: Vec<Vec<f32>> = (0..ds.data.rows()).map(|i| ds.data.row(i).to_vec()).collect();
            for _ in 0..10 {
                let v: Vec<f32> = (0..8).map(|_| rng.next_gaussian() * 2.0).collect();
                tier.push_row(&v);
                rows.push(v);
            }
            assert_eq!(tier.rows(), 70);
            // Inserted rows used the frozen codec: encoding through the
            // untouched tier gives the same codes.
            let keep: Vec<usize> = (0..70).filter(|i| i % 3 != 0).collect();
            tier.gather_rows(&keep);
            assert_eq!(tier.rows(), keep.len());
            let (mut qc, mut qt) = (Vec::new(), Vec::new());
            let (mut qc2, mut qt2) = (Vec::new(), Vec::new());
            for (new, &old) in keep.iter().enumerate() {
                let mut a = tier.scorer(&rows[0], &mut qc, &mut qt);
                let da = a.dist(new);
                drop(a);
                if old < 60 {
                    let mut b = frozen.scorer(&rows[0], &mut qc2, &mut qt2);
                    let db = b.dist(old);
                    assert_eq!(da.to_bits(), db.to_bits(), "p={p:?} row {old}");
                }
            }
        }
    }
}
