//! recall@k — the benchmark metric of the paper (|found ∩ truth| / k).

use crate::graph::search::Neighbor;

/// recall of one result list against ground truth ids.
pub fn recall(found: &[Neighbor], gt: &[u32]) -> f64 {
    if gt.is_empty() {
        return 0.0;
    }
    let hits = found.iter().filter(|n| gt.contains(&n.id)).count();
    hits as f64 / gt.len() as f64
}

/// recall from plain id lists.
pub fn recall_ids(found: &[u32], gt: &[u32]) -> f64 {
    if gt.is_empty() {
        return 0.0;
    }
    let hits = found.iter().filter(|id| gt.contains(id)).count();
    hits as f64 / gt.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nb(ids: &[u32]) -> Vec<Neighbor> {
        ids.iter().map(|&id| Neighbor { dist: 0.0, id }).collect()
    }

    #[test]
    fn full_and_partial_overlap() {
        assert_eq!(recall(&nb(&[1, 2, 3]), &[1, 2, 3]), 1.0);
        assert_eq!(recall(&nb(&[1, 9, 8]), &[1, 2, 3]), 1.0 / 3.0);
        assert_eq!(recall(&nb(&[]), &[1, 2]), 0.0);
        assert_eq!(recall(&nb(&[1]), &[]), 0.0);
    }

    #[test]
    fn id_variant_matches() {
        assert_eq!(recall_ids(&[5, 6], &[5, 7]), 0.5);
    }
}
