//! `finger bench hotpath` — the reproducible hot-path microharness behind
//! the repo's perf trajectory (`BENCH_hotpath.json`).
//!
//! Three sections, all hand-rolled (no criterion — the offline build has
//! no dependencies):
//!
//! * **kernel** — raw ns/distance of the single-row [`l2_sq`] vs the
//!   4-row [`l2_sq_batch4`] over padded [`VectorStore`] rows, across
//!   dims, under the runtime-dispatched backend (recorded as
//!   `kernel_backend`; `FINGER_KERNEL=scalar` re-runs the same harness on
//!   the portable fallback).
//! * **search** — end-to-end QPS, distance calls/query and inclusive
//!   ns/distance for flat HNSW and FINGER-HNSW, each under batched and
//!   scalar scoring (`SearchParams::with_scalar_kernels`). Before timing,
//!   the harness *asserts* the two scoring modes return bitwise-identical
//!   result streams — the bench doubles as the equality check.
//! * **build** — construction throughput (points/sec) for hnsw and
//!   hnsw-finger at `T = 1` and `T = max` (the deterministic parallel
//!   build plane), asserting the two builds persist identically-shaped
//!   graphs by comparing entry/edges, and logging the speedup. The ≥ 2×
//!   expectation at `T = max` is informational — logged, never asserted.
//! * **quant** (v3) — recall@10 vs QPS vs traversal-resident bytes for
//!   the f32, sq8 and pq distance tiers over the same HNSW graph
//!   parameters, one row per (tier, ef). Each row records `tier_bytes`:
//!   the bytes the beam loop actually reads per tier (padded f32 store
//!   for `f32`; codec + code rows for `sq8`/`pq`). The ≥ 2× byte
//!   reduction of sq8 over f32 *is* asserted (it is a layout fact, not a
//!   measurement); recall deltas are logged, never asserted.
//!
//! `ns_per_dist` in the search section is *inclusive*: elapsed wall time
//! divided by the number of exact distance computations, so it also
//! carries heap/visited/screening overhead — comparable across kernel
//! modes on the same index, not a pure kernel number (that one is in the
//! kernel section).

use std::path::Path;
use std::time::Instant;

use crate::core::distance::{kernel_backend, l2_sq, l2_sq_batch4, LANES};
use crate::core::json::Json;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::core::store::VectorStore;
use crate::core::threads::default_threads;
use crate::data::groundtruth::exact_knn;
use crate::data::spec_by_name;
use crate::eval::recall::recall;
use crate::finger::construct::FingerParams;
use crate::graph::hnsw::HnswParams;
use crate::index::impls::{FingerHnswIndex, HnswIndex};
use crate::index::{AnnIndex, SearchContext, SearchParams};
use crate::quant::sq8::Precision;

/// Median-of-5 timed reps of `f`, returning ns per iteration.
fn time_ns_per_iter<F: FnMut() -> f32>(iters: usize, mut f: F) -> f64 {
    let mut sink = 0.0f32;
    for _ in 0..iters / 10 + 1 {
        sink += f(); // warmup
    }
    let mut reps: Vec<f64> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                sink += f();
            }
            t0.elapsed().as_nanos() as f64 / iters as f64
        })
        .collect();
    reps.sort_by(|a, b| a.total_cmp(b));
    std::hint::black_box(sink);
    reps[2]
}

/// Kernel-level ns/dist: scalar vs batch4 over `rows` padded store rows.
fn kernel_section(out: &mut Vec<Json>) {
    let mut rng = Pcg32::new(0xBE7C);
    for dim in [16usize, 128, 784] {
        let rows = 1024usize;
        let mut m = Matrix::zeros(0, dim);
        for _ in 0..rows {
            let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            m.push_row(&row);
        }
        let store = VectorStore::from_matrix(&m);
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let mut qp = Vec::new();
        store.pad_query(&q, &mut qp);

        let mut i = 0usize;
        let scalar_ns = time_ns_per_iter(200_000, || {
            i = (i + 1) % rows;
            l2_sq(&qp, store.row(i))
        });
        let mut j = 0usize;
        // One batch4 call scores 4 rows; divide by 4 for ns/dist.
        let batch_ns = time_ns_per_iter(50_000, || {
            j = (j + 4) % (rows - 3);
            let d = l2_sq_batch4(
                &qp,
                store.row(j),
                store.row(j + 1),
                store.row(j + 2),
                store.row(j + 3),
            );
            d[0] + d[1] + d[2] + d[3]
        }) / 4.0;
        println!(
            "  kernel dim={dim:<4} scalar {scalar_ns:7.2} ns/dist   batch4 {batch_ns:7.2} ns/dist   ({:.2}x)",
            scalar_ns / batch_ns.max(1e-9)
        );
        out.push(Json::obj(vec![
            ("dim", Json::num(dim as f64)),
            ("scalar_ns_per_dist", Json::num(scalar_ns)),
            ("batch4_ns_per_dist", Json::num(batch_ns)),
        ]));
    }
}

/// Time one index under one kernel mode; returns the measured point.
fn run_search(
    label: &str,
    kernel: &str,
    index: &dyn AnnIndex,
    queries: &Matrix,
    params: &SearchParams,
    ctx: &mut SearchContext,
) -> Json {
    let nq = queries.rows();
    for qi in 0..nq.min(8) {
        index.search(queries.row(qi), params, ctx);
    }
    ctx.reset_stats();
    let t0 = Instant::now();
    for qi in 0..nq {
        std::hint::black_box(index.search(queries.row(qi), params, ctx));
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = ctx.take_stats();
    let qps = nq as f64 / secs.max(1e-9);
    let dist_per_q = stats.dist_calls as f64 / nq as f64;
    let approx_per_q = stats.approx_calls as f64 / nq as f64;
    let ns_per_dist = secs * 1e9 / stats.dist_calls.max(1) as f64;
    println!(
        "  {label:<12} {kernel:<8} ef={:<4} QPS {qps:9.0}   {dist_per_q:7.1} dist/q   {approx_per_q:7.1} approx/q   {ns_per_dist:7.1} ns/dist (incl.)",
        params.ef
    );
    Json::obj(vec![
        ("index", Json::str(label)),
        ("kernel", Json::str(kernel)),
        ("ef", Json::num(params.ef as f64)),
        ("qps", Json::num(qps)),
        ("dist_calls_per_query", Json::num(dist_per_q)),
        ("approx_calls_per_query", Json::num(approx_per_q)),
        ("ns_per_dist_inclusive", Json::num(ns_per_dist)),
    ])
}

/// Build throughput of the deterministic parallel build plane: hnsw and
/// hnsw-finger at T = 1 and T = max, reported as points/sec. The T=max
/// graph is bitwise identical to T=1 by construction (the determinism
/// suite proves it on persisted bytes); here we sanity-check entry +
/// edge count and log the speedup, never assert it. Returns the T=max
/// indexes so the search section can reuse them instead of rebuilding.
fn build_section(ds: &crate::data::Dataset, out: &mut Vec<Json>) -> (HnswIndex, FingerHnswIndex) {
    let n = ds.data.rows();
    let t_max = default_threads();
    let mut keep_hnsw: Option<HnswIndex> = None;
    let mut keep_finger: Option<FingerHnswIndex> = None;
    for (label, rank) in [("hnsw", 0usize), ("hnsw-finger", 16)] {
        let mut pts_per_sec = [0.0f64; 2];
        let mut fingerprint = [(0u32, 0usize); 2];
        for (i, threads) in [1usize, t_max].into_iter().enumerate() {
            let hp = HnswParams { m: 16, ef_construction: 120, threads, ..Default::default() };
            let t0 = Instant::now();
            let (entry, edges) = if rank == 0 {
                let ix = HnswIndex::build(std::sync::Arc::clone(&ds.data), hp);
                let f = (ix.graph.entry, ix.graph.base.num_edges());
                keep_hnsw = Some(ix);
                f
            } else {
                let ix = FingerHnswIndex::build(
                    std::sync::Arc::clone(&ds.data),
                    hp,
                    FingerParams { rank, threads, ..Default::default() },
                );
                let f = (ix.inner.hnsw.entry, ix.inner.hnsw.base.num_edges());
                keep_finger = Some(ix);
                f
            };
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            pts_per_sec[i] = n as f64 / secs;
            fingerprint[i] = (entry, edges);
            println!(
                "  build {label:<12} T={threads:<2} {:8.0} points/sec   ({secs:.2}s)",
                pts_per_sec[i]
            );
            out.push(Json::obj(vec![
                ("index", Json::str(label)),
                ("threads", Json::num(threads as f64)),
                ("points_per_sec", Json::num(pts_per_sec[i])),
                ("build_secs", Json::num(secs)),
            ]));
        }
        assert_eq!(
            fingerprint[0], fingerprint[1],
            "{label}: T=1 and T={t_max} builds diverged"
        );
        println!(
            "  build {label:<12} T={t_max} speedup {:.2}x over T=1 (informational target ≥ 2x)",
            pts_per_sec[1] / pts_per_sec[0].max(1e-9)
        );
    }
    (keep_hnsw.expect("hnsw built"), keep_finger.expect("hnsw-finger built"))
}

/// Quantized-tier sweep: recall@10 vs QPS vs traversal-resident bytes
/// for the f32/sq8/pq tiers over identical graph parameters. The f32
/// index is the T=max build from the build section; the quantized
/// variants rebuild the same graph with a sibling code tier.
fn quant_section(ds: &crate::data::Dataset, hnsw: &HnswIndex, out: &mut Vec<Json>) {
    let k = 10usize;
    let gt = exact_knn(&ds.data, &ds.queries, k);
    let t_max = default_threads();
    let hp = HnswParams { m: 16, ef_construction: 120, threads: t_max, ..Default::default() };

    // Traversal-resident bytes: what the beam loop reads per tier. The
    // f32 tier scores padded store rows; sq8/pq score code rows (codec /
    // codebook bytes included via `QuantTier::nbytes`).
    let n = ds.data.rows();
    let padded = ds.data.cols().div_ceil(LANES.max(1)) * LANES.max(1);
    let f32_bytes = n * padded * std::mem::size_of::<f32>();

    let sq8 = HnswIndex::build_with_precision(std::sync::Arc::clone(&ds.data), hp.clone(), Precision::Sq8);
    let pq = HnswIndex::build_with_precision(std::sync::Arc::clone(&ds.data), hp, Precision::Pq);
    let sq8_bytes = sq8.quant().map_or(0, |t| t.nbytes());
    let pq_bytes = pq.quant().map_or(0, |t| t.nbytes());
    assert!(
        sq8_bytes * 2 <= f32_bytes,
        "sq8 tier ({sq8_bytes} B) must be >= 2x smaller than f32 ({f32_bytes} B)"
    );
    println!(
        "  tier bytes: f32 {f32_bytes}   sq8 {sq8_bytes} ({:.2}x smaller)   pq {pq_bytes} ({:.2}x smaller)",
        f32_bytes as f64 / sq8_bytes.max(1) as f64,
        f32_bytes as f64 / pq_bytes.max(1) as f64
    );

    let tiers: [(&str, &dyn AnnIndex, usize); 3] =
        [("f32", hnsw, f32_bytes), ("sq8", &sq8, sq8_bytes), ("pq", &pq, pq_bytes)];
    let nq = ds.queries.rows();
    let mut ctx = SearchContext::for_universe(n);
    let mut f32_recall = [0.0f64; 3];
    for (ei, ef) in [40usize, 80, 160].into_iter().enumerate() {
        for (label, index, tier_bytes) in tiers {
            let params = SearchParams::new(k).with_ef(ef);
            for qi in 0..nq.min(8) {
                index.search(ds.queries.row(qi), &params, &mut ctx);
            }
            let t0 = Instant::now();
            let mut total_recall = 0.0f64;
            for qi in 0..nq {
                let res = index.search(ds.queries.row(qi), &params, &mut ctx);
                total_recall += recall(&res[..res.len().min(k)], &gt[qi]);
            }
            let secs = t0.elapsed().as_secs_f64();
            let qps = nq as f64 / secs.max(1e-9);
            let rec = total_recall / nq.max(1) as f64;
            if label == "f32" {
                f32_recall[ei] = rec;
            }
            println!(
                "  quant {label:<4} ef={ef:<4} recall@{k} {rec:.4} (Δf32 {:+.4})   QPS {qps:9.0}   {tier_bytes:>9} tier bytes",
                rec - f32_recall[ei]
            );
            out.push(Json::obj(vec![
                ("tier", Json::str(label)),
                ("ef", Json::num(ef as f64)),
                ("recall", Json::num(rec)),
                ("qps", Json::num(qps)),
                ("tier_bytes", Json::num(tier_bytes as f64)),
            ]));
        }
    }
}

/// The `finger bench hotpath` entry: writes `BENCH_hotpath.json` to `out`.
pub fn bench_hotpath(out: &Path, scale: f64) {
    println!("== hotpath: padded-store + batched-kernel data plane ==");
    println!(
        "  kernel backend {} / {} threads",
        kernel_backend().name(),
        default_threads()
    );
    let spec = spec_by_name("sift-sim-128", scale).expect("known dataset");
    println!("  dataset {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();

    let mut kernel = Vec::new();
    kernel_section(&mut kernel);

    // The build-throughput section also supplies the indexes the search
    // section times (T=max builds are bitwise identical to T=1, so reuse
    // loses nothing).
    let mut build = Vec::new();
    let (hnsw, finger) = build_section(&ds, &mut build);

    let mut ctx = SearchContext::for_universe(ds.data.rows()).with_stats();
    let indexes: [(&str, &dyn AnnIndex); 2] = [("hnsw", &hnsw), ("hnsw-finger", &finger)];
    let ef = 80usize;
    let batched = SearchParams::new(10).with_ef(ef);
    let scalar = SearchParams::new(10).with_ef(ef).with_scalar_kernels(true);

    // Correctness gate before timing: scalar and batched scoring must
    // return bitwise-identical (dist, id) streams on every probe query.
    for (label, index) in indexes {
        for qi in 0..ds.queries.rows().min(25) {
            let q = ds.queries.row(qi);
            let a = index.search(q, &batched, &mut ctx);
            let b = index.search(q, &scalar, &mut ctx);
            assert_eq!(a, b, "{label}: scalar/batched streams diverge at query {qi}");
        }
    }
    println!("  equality gate passed (scalar == batched, bitwise)");

    let mut search = Vec::new();
    for (label, index) in indexes {
        search.push(run_search(label, "scalar", index, &ds.queries, &scalar, &mut ctx));
        search.push(run_search(label, "batched", index, &ds.queries, &batched, &mut ctx));
    }

    let mut quant = Vec::new();
    quant_section(&ds, &hnsw, &mut quant);

    let doc = Json::obj(vec![
        ("schema", Json::str("hotpath-v3")),
        ("dataset", Json::str(&ds.name)),
        ("n", Json::num(ds.data.rows() as f64)),
        ("dim", Json::num(ds.data.cols() as f64)),
        ("scale", Json::num(scale)),
        ("ef", Json::num(ef as f64)),
        ("kernel_backend", Json::str(kernel_backend().name())),
        ("threads", Json::num(default_threads() as f64)),
        ("kernel", Json::Arr(kernel)),
        ("build", Json::Arr(build)),
        ("search", Json::Arr(search)),
        ("quant", Json::Arr(quant)),
    ]);
    std::fs::create_dir_all(out).ok();
    let path = out.join("BENCH_hotpath.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_hotpath.json");
    println!("  wrote {}", path.display());
}
