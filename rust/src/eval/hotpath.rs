//! `finger bench hotpath` — the reproducible hot-path microharness behind
//! the repo's perf trajectory (`BENCH_hotpath.json`).
//!
//! Two sections, both hand-rolled (no criterion — the offline build has no
//! dependencies):
//!
//! * **kernel** — raw ns/distance of the scalar [`l2_sq`] vs the 4-row
//!   [`l2_sq_batch4`] over padded [`VectorStore`] rows, across dims.
//! * **search** — end-to-end QPS, distance calls/query and inclusive
//!   ns/distance for flat HNSW and FINGER-HNSW, each under batched and
//!   scalar scoring (`SearchParams::with_scalar_kernels`). Before timing,
//!   the harness *asserts* the two scoring modes return bitwise-identical
//!   result streams — the bench doubles as the equality check.
//!
//! `ns_per_dist` in the search section is *inclusive*: elapsed wall time
//! divided by the number of exact distance computations, so it also
//! carries heap/visited/screening overhead — comparable across kernel
//! modes on the same index, not a pure kernel number (that one is in the
//! kernel section).

use std::path::Path;
use std::time::Instant;

use crate::core::distance::{l2_sq, l2_sq_batch4};
use crate::core::json::Json;
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::core::store::VectorStore;
use crate::data::spec_by_name;
use crate::finger::construct::FingerParams;
use crate::graph::hnsw::HnswParams;
use crate::index::impls::{FingerHnswIndex, HnswIndex};
use crate::index::{AnnIndex, SearchContext, SearchParams};

/// Median-of-5 timed reps of `f`, returning ns per iteration.
fn time_ns_per_iter<F: FnMut() -> f32>(iters: usize, mut f: F) -> f64 {
    let mut sink = 0.0f32;
    for _ in 0..iters / 10 + 1 {
        sink += f(); // warmup
    }
    let mut reps: Vec<f64> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                sink += f();
            }
            t0.elapsed().as_nanos() as f64 / iters as f64
        })
        .collect();
    reps.sort_by(|a, b| a.total_cmp(b));
    std::hint::black_box(sink);
    reps[2]
}

/// Kernel-level ns/dist: scalar vs batch4 over `rows` padded store rows.
fn kernel_section(out: &mut Vec<Json>) {
    let mut rng = Pcg32::new(0xBE7C);
    for dim in [16usize, 128, 784] {
        let rows = 1024usize;
        let mut m = Matrix::zeros(0, dim);
        for _ in 0..rows {
            let row: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            m.push_row(&row);
        }
        let store = VectorStore::from_matrix(&m);
        let q: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let mut qp = Vec::new();
        store.pad_query(&q, &mut qp);

        let mut i = 0usize;
        let scalar_ns = time_ns_per_iter(200_000, || {
            i = (i + 1) % rows;
            l2_sq(&qp, store.row(i))
        });
        let mut j = 0usize;
        // One batch4 call scores 4 rows; divide by 4 for ns/dist.
        let batch_ns = time_ns_per_iter(50_000, || {
            j = (j + 4) % (rows - 3);
            let d = l2_sq_batch4(
                &qp,
                store.row(j),
                store.row(j + 1),
                store.row(j + 2),
                store.row(j + 3),
            );
            d[0] + d[1] + d[2] + d[3]
        }) / 4.0;
        println!(
            "  kernel dim={dim:<4} scalar {scalar_ns:7.2} ns/dist   batch4 {batch_ns:7.2} ns/dist   ({:.2}x)",
            scalar_ns / batch_ns.max(1e-9)
        );
        out.push(Json::obj(vec![
            ("dim", Json::num(dim as f64)),
            ("scalar_ns_per_dist", Json::num(scalar_ns)),
            ("batch4_ns_per_dist", Json::num(batch_ns)),
        ]));
    }
}

/// Time one index under one kernel mode; returns the measured point.
fn run_search(
    label: &str,
    kernel: &str,
    index: &dyn AnnIndex,
    queries: &Matrix,
    params: &SearchParams,
    ctx: &mut SearchContext,
) -> Json {
    let nq = queries.rows();
    for qi in 0..nq.min(8) {
        index.search(queries.row(qi), params, ctx);
    }
    ctx.reset_stats();
    let t0 = Instant::now();
    for qi in 0..nq {
        std::hint::black_box(index.search(queries.row(qi), params, ctx));
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = ctx.take_stats();
    let qps = nq as f64 / secs.max(1e-9);
    let dist_per_q = stats.dist_calls as f64 / nq as f64;
    let approx_per_q = stats.approx_calls as f64 / nq as f64;
    let ns_per_dist = secs * 1e9 / stats.dist_calls.max(1) as f64;
    println!(
        "  {label:<12} {kernel:<8} ef={:<4} QPS {qps:9.0}   {dist_per_q:7.1} dist/q   {approx_per_q:7.1} approx/q   {ns_per_dist:7.1} ns/dist (incl.)",
        params.ef
    );
    Json::obj(vec![
        ("index", Json::str(label)),
        ("kernel", Json::str(kernel)),
        ("ef", Json::num(params.ef as f64)),
        ("qps", Json::num(qps)),
        ("dist_calls_per_query", Json::num(dist_per_q)),
        ("approx_calls_per_query", Json::num(approx_per_q)),
        ("ns_per_dist_inclusive", Json::num(ns_per_dist)),
    ])
}

/// The `finger bench hotpath` entry: writes `BENCH_hotpath.json` to `out`.
pub fn bench_hotpath(out: &Path, scale: f64) {
    println!("== hotpath: padded-store + batched-kernel data plane ==");
    let spec = spec_by_name("sift-sim-128", scale).expect("known dataset");
    println!("  dataset {} (n={}, dim={})", spec.name, spec.n, spec.dim);
    let ds = spec.generate();

    let mut kernel = Vec::new();
    kernel_section(&mut kernel);

    let hnsw_params = HnswParams { m: 16, ef_construction: 120, ..Default::default() };
    let t0 = Instant::now();
    let hnsw = HnswIndex::build(std::sync::Arc::clone(&ds.data), hnsw_params.clone());
    let finger = FingerHnswIndex::build(
        std::sync::Arc::clone(&ds.data),
        hnsw_params,
        FingerParams { rank: 16, ..Default::default() },
    );
    println!("  indexes built in {:.1}s", t0.elapsed().as_secs_f64());

    let mut ctx = SearchContext::for_universe(ds.data.rows()).with_stats();
    let indexes: [(&str, &dyn AnnIndex); 2] = [("hnsw", &hnsw), ("hnsw-finger", &finger)];
    let ef = 80usize;
    let batched = SearchParams::new(10).with_ef(ef);
    let scalar = SearchParams::new(10).with_ef(ef).with_scalar_kernels(true);

    // Correctness gate before timing: scalar and batched scoring must
    // return bitwise-identical (dist, id) streams on every probe query.
    for (label, index) in indexes {
        for qi in 0..ds.queries.rows().min(25) {
            let q = ds.queries.row(qi);
            let a = index.search(q, &batched, &mut ctx);
            let b = index.search(q, &scalar, &mut ctx);
            assert_eq!(a, b, "{label}: scalar/batched streams diverge at query {qi}");
        }
    }
    println!("  equality gate passed (scalar == batched, bitwise)");

    let mut search = Vec::new();
    for (label, index) in indexes {
        search.push(run_search(label, "scalar", index, &ds.queries, &scalar, &mut ctx));
        search.push(run_search(label, "batched", index, &ds.queries, &batched, &mut ctx));
    }

    let doc = Json::obj(vec![
        ("schema", Json::str("hotpath-v1")),
        ("dataset", Json::str(&ds.name)),
        ("n", Json::num(ds.data.rows() as f64)),
        ("dim", Json::num(ds.data.cols() as f64)),
        ("scale", Json::num(scale)),
        ("ef", Json::num(ef as f64)),
        ("kernel", Json::Arr(kernel)),
        ("search", Json::Arr(search)),
    ]);
    std::fs::create_dir_all(out).ok();
    let path = out.join("BENCH_hotpath.json");
    std::fs::write(&path, format!("{doc}\n")).expect("write BENCH_hotpath.json");
    println!("  wrote {}", path.display());
}
