//! Figure/table harnesses: one function per paper artifact, each writing
//! CSV series into the results directory and printing a summary table.
//! The README's layer map links figure → harness → modules.
//!
//! Every searchable thing here goes through `&dyn AnnIndex` + the shared
//! sweep harness — no per-family glue.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::core::distance::{cosine, dot, norm_sq};
use crate::core::matrix::Matrix;
use crate::core::store::VectorStore;
use crate::core::stats;
use crate::data::groundtruth::exact_knn;
use crate::data::synth::{registry, Dataset, SynthSpec};
use crate::eval::sweep::{self, SweepPoint, DEFAULT_EFS};
use crate::finger::construct::{FingerIndex, FingerParams};
use crate::finger::rplsh::build_rplsh_index;
use crate::finger::search::FingerHnsw;
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nndescent::NnDescentParams;
use crate::graph::search::SearchStats;
use crate::graph::vamana::VamanaParams;
use crate::index::impls::{
    FingerHnswIndex, FingerView, HnswIndex, IvfPqIndex, NnDescentIndex, VamanaIndex,
};
use crate::index::{SearchContext, SearchParams};
use crate::quant::ivfpq::IvfPqParams;

pub fn write_csv(dir: &Path, name: &str, content: &str) {
    std::fs::create_dir_all(dir).ok();
    let path = dir.join(name);
    std::fs::write(&path, content).expect("write results csv");
    println!("  wrote {}", path.display());
}

fn materialize(spec: &SynthSpec) -> (Dataset, Vec<Vec<u32>>) {
    let t0 = Instant::now();
    let ds = spec.generate();
    let gt = exact_knn(&ds.data, &ds.queries, 10);
    println!(
        "  dataset {} (n={}, dim={}, {}) ready in {:.1}s",
        ds.name,
        ds.data.rows(),
        ds.data.cols(),
        ds.metric.name(),
        t0.elapsed().as_secs_f64()
    );
    (ds, gt)
}

/// Paper-chosen rank per dataset family (Supplementary E).
fn paper_rank(name: &str) -> usize {
    if name.starts_with("nytimes") {
        48
    } else if name.starts_with("glove") {
        32
    } else if name.starts_with("deep") {
        24
    } else {
        16
    }
}

// ---------------------------------------------------------------- Fig 1/5/8

/// Figures 1, 5 and 8: throughput-vs-recall@10 for all graph methods on
/// all six datasets. Figure 1 is the baseline subset, Figure 5/8 add
/// HNSW-FINGER (and the RPLSH-screened ablation for Fig. 8).
pub fn figure5(out: &Path, scale: f64, with_rplsh: bool) {
    println!("== Figure 5/8 (and Fig. 1 baselines): throughput vs recall@10 ==");
    for spec in registry(scale) {
        let (ds, gt) = materialize(&spec);
        let mut points: Vec<SweepPoint> = Vec::new();
        let rank = paper_rank(&ds.name);

        let hnsw_params = HnswParams { m: 16, ef_construction: 120, ..Default::default() };
        let t0 = Instant::now();
        let hnsw = HnswIndex::build(Arc::clone(&ds.data), hnsw_params);
        println!("  hnsw built in {:.1}s", t0.elapsed().as_secs_f64());
        points.extend(sweep::sweep_efs(&hnsw, &ds.queries, &gt, 10, DEFAULT_EFS));

        let t0 = Instant::now();
        let findex =
            FingerIndex::build(&ds.data, &hnsw.graph.base, FingerParams { rank, ..Default::default() });
        println!(
            "  finger index (r={rank}) built in {:.1}s, corr={:.3}",
            t0.elapsed().as_secs_f64(),
            findex.matching.correlation
        );
        let fh = FingerHnswIndex::from_parts(
            Arc::clone(&ds.data),
            FingerHnsw { hnsw: hnsw.graph, index: findex },
        );
        points.extend(sweep::sweep_efs(&fh, &ds.queries, &gt, 10, DEFAULT_EFS));

        if with_rplsh {
            let ridx = build_rplsh_index(
                &ds.data,
                &fh.inner.hnsw.base,
                FingerParams { rank, ..Default::default() },
            );
            let rh = FingerView {
                data: &ds.data,
                store: fh.store(),
                hnsw: &fh.inner.hnsw,
                findex: &ridx,
                label: "hnsw-rplsh",
            };
            points.extend(sweep::sweep_efs(&rh, &ds.queries, &gt, 10, DEFAULT_EFS));
        }

        let t0 = Instant::now();
        let vam = VamanaIndex::build(Arc::clone(&ds.data), VamanaParams::default());
        println!("  vamana built in {:.1}s", t0.elapsed().as_secs_f64());
        points.extend(sweep::sweep_efs(&vam, &ds.queries, &gt, 10, DEFAULT_EFS));

        let t0 = Instant::now();
        let nnd = NnDescentIndex::build(Arc::clone(&ds.data), NnDescentParams::default());
        println!("  nndescent built in {:.1}s", t0.elapsed().as_secs_f64());
        points.extend(sweep::sweep_efs(&nnd, &ds.queries, &gt, 10, DEFAULT_EFS));

        print_points(&points);
        let fname = format!(
            "{}_{}.csv",
            if with_rplsh { "figure8" } else { "figure5" },
            ds.name
        );
        write_csv(out, &fname, &sweep::to_csv(&points));
    }
}

fn print_points(points: &[SweepPoint]) {
    println!("  {:<14} {:>10} {:>10} {:>12} {:>12}", "method", "param", "recall@10", "QPS", "eff.calls");
    for p in points {
        println!(
            "  {:<14} {:>10} {:>10.4} {:>12.1} {:>12.1}",
            p.method, p.param, p.recall10, p.qps, p.effective_dist_calls
        );
    }
}

// -------------------------------------------------------------------- Fig 2

/// Figure 2: fraction of distance computations larger than the upper bound,
/// bucketed by search phase (node-expansion decile).
pub fn figure2(out: &Path, scale: f64) {
    println!("== Figure 2: wasted distance computations by search phase ==");
    let mut csv = String::from("dataset,phase_decile,total,wasted,fraction\n");
    for name in ["fashion-sim-784", "glove-sim-100"] {
        let spec = crate::data::synth::spec_by_name(name, scale).unwrap();
        let (ds, _gt) = materialize(&spec);
        let store = VectorStore::from_matrix(&ds.data);
        let h = Hnsw::build_with_store(&store, HnswParams { m: 16, ef_construction: 120, ..Default::default() });
        let mut ctx = SearchContext::new().with_stats();
        let params = SearchParams::new(10).with_ef(128);
        for qi in 0..ds.queries.rows() {
            h.search(&store, ds.queries.row(qi), &params, &mut ctx);
        }
        let agg: SearchStats = ctx.take_stats();
        // Bucket per-hop counts into deciles of the search.
        let hops = agg.per_hop.len().max(1);
        let mut deciles = vec![(0u64, 0u64); 10];
        for (h_idx, &(t, w)) in agg.per_hop.iter().enumerate() {
            let d = (h_idx * 10 / hops).min(9);
            deciles[d].0 += t;
            deciles[d].1 += w;
        }
        println!("  {name}: phase -> wasted fraction");
        for (d, &(t, w)) in deciles.iter().enumerate() {
            let frac = if t == 0 { 0.0 } else { w as f64 / t as f64 };
            println!("    decile {d}: {frac:.3} ({w}/{t})");
            csv.push_str(&format!("{name},{d},{t},{w},{frac:.4}\n"));
        }
        let overall = agg.wasted as f64 / agg.dist_calls.max(1) as f64;
        println!("  overall wasted fraction: {overall:.3}");
    }
    write_csv(out, "figure2.csv", &csv);
}

// ------------------------------------------------------------------ Fig 3/4

/// Sample (true cosine, raw inner product, rank-r cosine) triples of
/// neighboring residual pairs.
fn residual_pair_samples(
    ds: &Dataset,
    h: &Hnsw,
    proj: &Matrix,
    max_pairs: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = crate::core::rng::Pcg32::new(9);
    let data = &ds.data;
    let mut cosines = Vec::new();
    let mut dots_ = Vec::new();
    let mut approx = Vec::new();
    for c in 0..data.rows() as u32 {
        if cosines.len() >= max_pairs {
            break;
        }
        let nbs = h.base.neighbors(c);
        if nbs.len() < 2 {
            continue;
        }
        let i = rng.gen_range(nbs.len());
        let mut j = rng.gen_range(nbs.len());
        while j == i {
            j = rng.gen_range(nbs.len());
        }
        let xc = data.row(c as usize);
        let csq = norm_sq(xc).max(1e-12);
        let resid = |d: u32| -> Vec<f32> {
            let xd = data.row(d as usize);
            let t = dot(xc, xd) / csq;
            xd.iter().zip(xc).map(|(&a, &b)| a - t * b).collect()
        };
        let rd = resid(nbs[i]);
        let rdp = resid(nbs[j]);
        cosines.push(cosine(&rd, &rdp));
        dots_.push(dot(&rd, &rdp));
        let pd = crate::finger::construct::project(proj, &rd);
        let pdp = crate::finger::construct::project(proj, &rdp);
        approx.push(cosine(&pd, &pdp));
    }
    (cosines, dots_, approx)
}

fn hist_csv(label: &str, xs: &[f32], lo: f32, hi: f32, bins: usize, csv: &mut String) {
    let h = stats::histogram(xs, lo, hi, bins);
    let w = (hi - lo) / bins as f32;
    for (b, &c) in h.iter().enumerate() {
        let center = lo + (b as f32 + 0.5) * w;
        csv.push_str(&format!("{label},{center:.4},{c}\n"));
    }
}

/// Figure 3: residual-angle distributions are Gaussian-like; raw
/// inner-products are skewed.
pub fn figure3(out: &Path, scale: f64) {
    println!("== Figure 3: neighboring-residual angle distributions ==");
    let mut csv = String::from("series,bin_center,count\n");
    let mut summary = String::from("dataset,series,mean,std,skewness,kurtosis\n");
    for name in ["fashion-sim-784", "sift-sim-128"] {
        let spec = crate::data::synth::spec_by_name(name, scale).unwrap();
        let (ds, _gt) = materialize(&spec);
        let h = Hnsw::build(&ds.data, HnswParams { m: 16, ef_construction: 120, ..Default::default() });
        let fidx = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 16, ..Default::default() });
        let (cosines, dots_, _) = residual_pair_samples(&ds, &h, &fidx.proj, 20_000);
        for (series, xs) in [("cosine", &cosines), ("inner_product", &dots_)] {
            let (m, s) = (stats::mean(xs), stats::stddev(xs));
            let (sk, ku) = (stats::skewness(xs), stats::excess_kurtosis(xs));
            let jb = stats::jarque_bera(xs);
            println!(
                "  {name} {series}: mean={m:.4} std={s:.4} skew={sk:.3} kurt={ku:.3} JB={jb:.0}"
            );
            summary.push_str(&format!("{name},{series},{m:.5},{s:.5},{sk:.4},{ku:.4}\n"));
            let lo = stats::percentile(xs, 0.5);
            let hi = stats::percentile(xs, 99.5);
            hist_csv(&format!("{name}:{series}"), xs, lo, hi.max(lo + 1e-3), 40, &mut csv);
        }
        // Headline check: |skew(cosine)| << |skew(inner product)|.
        let sk_cos = stats::skewness(&cosines).abs();
        let sk_dot = stats::skewness(&dots_).abs();
        println!("  -> skew |cos|={sk_cos:.3} vs |ip|={sk_dot:.3} (paper: cosines less skewed)");
    }
    write_csv(out, "figure3_hist.csv", &csv);
    write_csv(out, "figure3_summary.csv", &summary);
}

/// Figure 4: the rank-r approximated angle distribution is shifted/wider
/// than the true one; distribution matching re-aligns it.
pub fn figure4(out: &Path, scale: f64) {
    println!("== Figure 4: distribution matching ==");
    let mut csv = String::from("series,bin_center,count\n");
    let mut summary = String::from("dataset,series,mean,std\n");
    for name in ["fashion-sim-784", "sift-sim-128"] {
        let spec = crate::data::synth::spec_by_name(name, scale).unwrap();
        let (ds, _gt) = materialize(&spec);
        let h = Hnsw::build(&ds.data, HnswParams { m: 16, ef_construction: 120, ..Default::default() });
        let fidx = FingerIndex::build(&ds.data, &h.base, FingerParams { rank: 16, ..Default::default() });
        let (true_cos, _, approx_cos) = residual_pair_samples(&ds, &h, &fidx.proj, 20_000);
        let mp = fidx.matching;
        let matched: Vec<f32> = approx_cos
            .iter()
            .map(|&y| (y - mp.mu_hat) * (mp.sigma / mp.sigma_hat) + mp.mu)
            .collect();
        for (series, xs) in [
            ("true", &true_cos),
            ("approx_r16", &approx_cos),
            ("approx_matched", &matched),
        ] {
            let (m, s) = (stats::mean(xs), stats::stddev(xs));
            println!("  {name} {series}: mean={m:.4} std={s:.4}");
            summary.push_str(&format!("{name},{series},{m:.5},{s:.5}\n"));
            hist_csv(&format!("{name}:{series}"), xs, -1.0, 1.0, 50, &mut csv);
        }
        // Matched mean/std must land closer to the true distribution.
        let d_before = (stats::mean(&approx_cos) - stats::mean(&true_cos)).abs();
        let d_after = (stats::mean(&matched) - stats::mean(&true_cos)).abs();
        println!("  -> |mean shift| before={d_before:.4} after={d_after:.4}");
    }
    write_csv(out, "figure4_hist.csv", &csv);
    write_csv(out, "figure4_summary.csv", &summary);
}

// -------------------------------------------------------------------- Fig 6

/// Figure 6: ablation — approximation error and recall vs effective
/// distance calls, FINGER vs RPLSH, each with and without distribution
/// matching, sweeping rank. One shared graph, many side-index variants,
/// all searched through the borrowed `FingerView` implementor.
pub fn figure6(out: &Path, scale: f64) {
    println!("== Figure 6: ablation (FINGER vs RPLSH, +/- distribution matching) ==");
    let mut err_csv = String::from("dataset,scheme,rank,approx_error_pct,effective_ratio\n");
    let mut rec_csv =
        String::from("dataset,scheme,rank,ef,recall10,effective_dist_calls\n");
    for name in ["fashion-sim-784", "glove-sim-100"] {
        let spec = crate::data::synth::spec_by_name(name, scale).unwrap();
        let (ds, gt) = materialize(&spec);
        let m = ds.data.cols();
        let store = VectorStore::from_matrix(&ds.data);
        let hnsw = Hnsw::build_with_store(&store, HnswParams { m: 16, ef_construction: 120, ..Default::default() });

        for rank in [8usize, 16, 32] {
            for (scheme, dm) in [
                ("finger", true),
                ("finger-nodm", false),
                ("rplsh", false),
                ("rplsh-dm", true),
            ] {
                let params = FingerParams {
                    rank,
                    distribution_matching: dm,
                    error_correction: dm,
                    ..Default::default()
                };
                let idx = if scheme.starts_with("rplsh") {
                    build_rplsh_index(&ds.data, &hnsw.base, params)
                } else {
                    FingerIndex::build(&ds.data, &hnsw.base, params)
                };

                // Approximation error on sampled pairs: |t - t_hat| / |t|.
                let (true_cos, _, approx_cos) =
                    residual_pair_samples(&ds, &hnsw, &idx.proj, 8_000);
                let mp = idx.matching;
                let mut errs = Vec::new();
                for (&t, &y) in true_cos.iter().zip(&approx_cos) {
                    let t_hat = if dm {
                        (y - mp.mu_hat) * (mp.sigma / mp.sigma_hat) + mp.mu
                    } else {
                        y
                    };
                    if t.abs() > 0.05 {
                        errs.push((t_hat - t).abs() / t.abs());
                    }
                }
                let err_pct = 100.0 * stats::mean(&errs);
                err_csv.push_str(&format!(
                    "{name},{scheme},{rank},{err_pct:.3},{:.4}\n",
                    rank as f64 / m as f64
                ));

                // Recall vs effective calls (shared graph, screened search).
                let view = FingerView {
                    data: &ds.data,
                    store: &store,
                    hnsw: &hnsw,
                    findex: &idx,
                    label: scheme,
                };
                let pts = sweep::sweep_efs(&view, &ds.queries, &gt, 10, &[20, 60, 160]);
                for p in &pts {
                    rec_csv.push_str(&format!(
                        "{name},{scheme},{rank},{},{:.4},{:.1}\n",
                        p.param, p.recall10, p.effective_dist_calls
                    ));
                }
                println!(
                    "  {name} {scheme:<12} r={rank:<3} err={err_pct:6.2}%  recall@ef60={:.4}",
                    pts[1].recall10
                );
            }
        }
    }
    write_csv(out, "figure6_error.csv", &err_csv);
    write_csv(out, "figure6_recall.csv", &rec_csv);
}

// -------------------------------------------------------------------- Fig 7

/// Figure 7: HNSW-FINGER vs quantization (IVF-PQ) on three datasets.
pub fn figure7(out: &Path, scale: f64) {
    println!("== Figure 7: comparison to quantization methods ==");
    for name in ["nytimes-sim-256", "gist-sim-960", "deep-sim-96"] {
        let spec = crate::data::synth::spec_by_name(name, scale).unwrap();
        let (ds, gt) = materialize(&spec);
        let mut points = Vec::new();

        let rank = paper_rank(&ds.name);
        let fh = FingerHnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 16, ef_construction: 120, ..Default::default() },
            FingerParams { rank, ..Default::default() },
        );
        points.extend(sweep::sweep_efs(&fh, &ds.queries, &gt, 10, DEFAULT_EFS));

        let nlist = (ds.data.rows() as f64).sqrt() as usize;
        let ivf = IvfPqIndex::build(
            Arc::clone(&ds.data),
            IvfPqParams { n_list: nlist.max(16), ..Default::default() },
        );
        points.extend(sweep::sweep_probes(&ivf, &ds.queries, &gt, 10, &[1, 2, 4, 8, 16, 32]));

        print_points(&points);
        write_csv(out, &format!("figure7_{}.csv", ds.name), &sweep::to_csv(&points));
    }
}

// ------------------------------------------------------------------ Table 1

/// Table 1: construction time and memory, HNSW vs HNSW-FINGER, M ∈ {12,48}.
pub fn table1(out: &Path, scale: f64) {
    println!("== Table 1: construction statistics ==");
    let mut csv = String::from("dataset,M,method,build_secs,index_bytes\n");
    for name in ["sift-sim-128", "glove-sim-100"] {
        let spec = crate::data::synth::spec_by_name(name, scale).unwrap();
        let ds = spec.generate();
        for m in [12usize, 48] {
            let t0 = Instant::now();
            let hnsw = Hnsw::build(&ds.data, HnswParams { m, ef_construction: 120, ..Default::default() });
            let t_hnsw = t0.elapsed().as_secs_f64();
            let hnsw_bytes = hnsw.nbytes() + ds.data.nbytes();

            let rank = paper_rank(name);
            let t1 = Instant::now();
            let fidx = FingerIndex::build(&ds.data, &hnsw.base, FingerParams { rank, ..Default::default() });
            let t_finger = t_hnsw + t1.elapsed().as_secs_f64();
            let finger_bytes = hnsw_bytes + fidx.nbytes();

            println!(
                "  {name} M={m}: HNSW {t_hnsw:.1}s ({:.2} MB)  HNSW-FINGER {t_finger:.1}s ({:.2} MB)",
                hnsw_bytes as f64 / 1e6,
                finger_bytes as f64 / 1e6
            );
            csv.push_str(&format!("{name},{m},hnsw,{t_hnsw:.2},{hnsw_bytes}\n"));
            csv.push_str(&format!("{name},{m},hnsw-finger,{t_finger:.2},{finger_bytes}\n"));
        }
    }
    write_csv(out, "table1.csv", &csv);
}

// -------------------------------------------------------- Supplementary E

/// Supplementary E: rank selection by correlation threshold.
pub fn rank_selection(out: &Path, scale: f64) {
    println!("== Supplementary E: rank selection (corr >= 0.7, step 8) ==");
    let mut csv = String::from("dataset,rank,correlation,chosen\n");
    for spec in registry(scale) {
        let ds = spec.generate();
        let h = Hnsw::build(&ds.data, HnswParams { m: 16, ef_construction: 120, ..Default::default() });
        let (tried, chosen) = crate::finger::construct::select_rank(&ds.data, &h.base, 0.7, 64, 7);
        for (i, &(r, c)) in tried.iter().enumerate() {
            csv.push_str(&format!("{},{r},{c:.4},{}\n", ds.name, i == chosen));
        }
        println!(
            "  {}: chose r={} (corr={:.3}) after {:?}",
            ds.name,
            tried[chosen].0,
            tried[chosen].1,
            tried.iter().map(|&(r, _)| r).collect::<Vec<_>>()
        );
    }
    write_csv(out, "rank_selection.csv", &csv);
}
