//! ANN-benchmark-style sweeps: run a method across its search-time
//! hyper-parameter grid, measuring throughput (single-thread QPS) and
//! recall@10 at each point — the data behind every throughput/recall
//! curve in the paper (Figures 1, 5, 7, 8).

use std::time::Instant;

use crate::core::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::eval::recall::recall;
use crate::finger::search::FingerHnsw;
use crate::graph::hnsw::Hnsw;
use crate::graph::nndescent::NnDescent;
use crate::graph::search::SearchStats;
use crate::graph::vamana::Vamana;
use crate::graph::visited::VisitedSet;
use crate::quant::ivfpq::IvfPq;

/// One measured point of a throughput/recall curve.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub method: String,
    pub param: String,
    pub recall10: f64,
    pub qps: f64,
    pub mean_full_dist_calls: f64,
    pub mean_approx_calls: f64,
    /// Figure 6's x-axis: full + approx · r/m.
    pub effective_dist_calls: f64,
}

impl SweepPoint {
    pub fn csv_header() -> &'static str {
        "method,param,recall10,qps,full_dist_calls,approx_calls,effective_dist_calls"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{:.4},{:.1},{:.1},{:.1},{:.1}",
            self.method,
            self.param,
            self.recall10,
            self.qps,
            self.mean_full_dist_calls,
            self.mean_approx_calls,
            self.effective_dist_calls
        )
    }
}

/// Generic searcher closure signature: (query, ef, visited, stats) -> ids.
type SearchFn<'a> = dyn Fn(&[f32], usize, &mut VisitedSet, &mut SearchStats) -> Vec<crate::graph::search::Neighbor>
    + 'a;

fn run_sweep(
    method: &str,
    data: &Matrix,
    queries: &Matrix,
    gt: &[Vec<u32>],
    k: usize,
    efs: &[usize],
    rank: usize,
    search: &SearchFn,
) -> Vec<SweepPoint> {
    let mut vis = VisitedSet::new(data.rows());
    let m = data.cols();
    let mut out = Vec::new();
    for &ef in efs {
        // Warmup pass (stabilizes caches), then timed pass.
        for qi in 0..queries.rows().min(8) {
            let mut st = SearchStats::default();
            search(queries.row(qi), ef, &mut vis, &mut st);
        }
        let mut stats = SearchStats::default();
        let mut total_recall = 0.0;
        let t0 = Instant::now();
        for qi in 0..queries.rows() {
            let res = search(queries.row(qi), ef, &mut vis, &mut stats);
            total_recall += recall(&res[..res.len().min(k)], &gt[qi]);
        }
        let secs = t0.elapsed().as_secs_f64();
        let nq = queries.rows() as f64;
        out.push(SweepPoint {
            method: method.to_string(),
            param: format!("ef={ef}"),
            recall10: total_recall / nq,
            qps: nq / secs.max(1e-9),
            mean_full_dist_calls: stats.dist_calls as f64 / nq,
            mean_approx_calls: stats.approx_calls as f64 / nq,
            effective_dist_calls: stats.effective_dist_calls(rank, m) / nq,
        });
    }
    out
}

pub const DEFAULT_EFS: &[usize] = &[10, 20, 40, 80, 160, 320];

pub fn sweep_hnsw(ds: &Dataset, gt: &[Vec<u32>], h: &Hnsw, efs: &[usize], k: usize) -> Vec<SweepPoint> {
    run_sweep(
        "hnsw",
        &ds.data,
        &ds.queries,
        gt,
        k,
        efs,
        0,
        &|q, ef, vis, st| h.search(&ds.data, q, k, ef, vis, Some(st)),
    )
}

pub fn sweep_finger(
    ds: &Dataset,
    gt: &[Vec<u32>],
    f: &FingerHnsw,
    efs: &[usize],
    k: usize,
    label: &str,
) -> Vec<SweepPoint> {
    run_sweep(
        label,
        &ds.data,
        &ds.queries,
        gt,
        k,
        efs,
        f.index.rank,
        &|q, ef, vis, st| f.search(&ds.data, q, k, ef, vis, Some(st)),
    )
}

/// Like `sweep_finger` but over borrowed (graph, index) — lets ablations
/// share one graph across many index variants.
pub fn sweep_finger_borrowed(
    ds: &Dataset,
    gt: &[Vec<u32>],
    hnsw: &Hnsw,
    index: &crate::finger::construct::FingerIndex,
    efs: &[usize],
    k: usize,
    label: &str,
) -> Vec<SweepPoint> {
    run_sweep(
        label,
        &ds.data,
        &ds.queries,
        gt,
        k,
        efs,
        index.rank,
        &|q, ef, vis, st| {
            crate::finger::search::search_hnsw_with_index(
                hnsw, index, &ds.data, q, k, ef, vis, Some(st),
            )
        },
    )
}

pub fn sweep_vamana(ds: &Dataset, gt: &[Vec<u32>], v: &Vamana, efs: &[usize], k: usize) -> Vec<SweepPoint> {
    run_sweep(
        "vamana",
        &ds.data,
        &ds.queries,
        gt,
        k,
        efs,
        0,
        &|q, ef, vis, st| v.search(&ds.data, q, k, ef, vis, Some(st)),
    )
}

pub fn sweep_nndescent(
    ds: &Dataset,
    gt: &[Vec<u32>],
    g: &NnDescent,
    efs: &[usize],
    k: usize,
) -> Vec<SweepPoint> {
    run_sweep(
        "nndescent",
        &ds.data,
        &ds.queries,
        gt,
        k,
        efs,
        0,
        &|q, ef, vis, st| g.search(&ds.data, q, k, ef, vis, Some(st)),
    )
}

/// IVF-PQ sweeps over n_probe rather than ef.
pub fn sweep_ivfpq(
    ds: &Dataset,
    gt: &[Vec<u32>],
    ivf: &IvfPq,
    probes: &[usize],
    k: usize,
) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    let nq = ds.queries.rows() as f64;
    for &p in probes {
        let mut total_recall = 0.0;
        let mut scored_total = 0u64;
        let t0 = Instant::now();
        for qi in 0..ds.queries.rows() {
            let (res, scored) = ivf.search(&ds.data, ds.queries.row(qi), k, p, 10 * k);
            scored_total += scored;
            total_recall += recall(&res, &gt[qi]);
        }
        let secs = t0.elapsed().as_secs_f64();
        out.push(SweepPoint {
            method: "ivfpq".into(),
            param: format!("nprobe={p}"),
            recall10: total_recall / nq,
            qps: nq / secs.max(1e-9),
            mean_full_dist_calls: (10 * k) as f64,
            mean_approx_calls: scored_total as f64 / nq,
            effective_dist_calls: 0.0,
        });
    }
    out
}

/// Write points as CSV.
pub fn to_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from(SweepPoint::csv_header());
    s.push('\n');
    for p in points {
        s.push_str(&p.to_csv());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;
    use crate::graph::hnsw::HnswParams;

    #[test]
    fn sweep_recall_monotone_in_ef() {
        let ds = tiny(111, 500, 16, Metric::L2);
        let h = Hnsw::build(&ds.data, HnswParams { m: 8, ef_construction: 60, ..Default::default() });
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let pts = sweep_hnsw(&ds, &gt, &h, &[10, 160], 10);
        assert_eq!(pts.len(), 2);
        assert!(pts[1].recall10 >= pts[0].recall10 - 0.02);
        assert!(pts[0].qps > 0.0);
        assert!(pts[1].mean_full_dist_calls > pts[0].mean_full_dist_calls);
    }

    #[test]
    fn csv_shape() {
        let p = SweepPoint {
            method: "x".into(),
            param: "ef=1".into(),
            recall10: 0.5,
            qps: 100.0,
            mean_full_dist_calls: 10.0,
            mean_approx_calls: 0.0,
            effective_dist_calls: 10.0,
        };
        let csv = to_csv(&[p]);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("method,"));
    }
}
