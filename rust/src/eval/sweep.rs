//! ANN-benchmark-style sweeps: run any [`AnnIndex`] across a grid of
//! [`SearchParams`], measuring throughput (single-thread QPS) and
//! recall@10 at each point — the data behind every throughput/recall
//! curve in the paper (Figures 1, 5, 7, 8).
//!
//! The old per-family closure shims (`SearchFn`) are gone: the harness
//! sweeps `&dyn AnnIndex` directly, so any implementor — including ones
//! loaded from disk — gets a curve with zero glue code.

use std::sync::Arc;
use std::time::Instant;

use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;
use crate::eval::recall::recall;
use crate::eval::recall_ids;
use crate::index::impls::BruteForce;
use crate::index::mutable::MutableAnnIndex;
use crate::index::sharded::{ShardSpec, ShardedIndex};
use crate::index::{AnnIndex, SearchContext, SearchParams};

/// One measured point of a throughput/recall curve.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub method: String,
    pub param: String,
    pub recall10: f64,
    pub qps: f64,
    pub mean_full_dist_calls: f64,
    pub mean_approx_calls: f64,
    /// Figure 6's x-axis: full + approx · r/m.
    pub effective_dist_calls: f64,
}

impl SweepPoint {
    pub fn csv_header() -> &'static str {
        "method,param,recall10,qps,full_dist_calls,approx_calls,effective_dist_calls"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{:.4},{:.1},{:.1},{:.1},{:.1}",
            self.method,
            self.param,
            self.recall10,
            self.qps,
            self.mean_full_dist_calls,
            self.mean_approx_calls,
            self.effective_dist_calls
        )
    }
}

pub const DEFAULT_EFS: &[usize] = &[10, 20, 40, 80, 160, 320];
pub const DEFAULT_PROBES: &[usize] = &[1, 2, 4, 8, 16, 32];

/// `ef`-grid for graph families: one labeled params per beam width.
pub fn ef_grid(k: usize, efs: &[usize]) -> Vec<(String, SearchParams)> {
    efs.iter()
        .map(|&ef| (format!("ef={ef}"), SearchParams::new(k).with_ef(ef)))
        .collect()
}

/// `n_probe`-grid for IVF-PQ.
pub fn probe_grid(k: usize, probes: &[usize]) -> Vec<(String, SearchParams)> {
    probes
        .iter()
        .map(|&p| (format!("nprobe={p}"), SearchParams::new(k).with_probes(p)))
        .collect()
}

/// Sweep `index` over a labeled parameter grid. `label` overrides the
/// index's own name in the output (useful for ablation variants); pass
/// `None` to use `index.name()`.
pub fn run_sweep(
    label: Option<&str>,
    index: &dyn AnnIndex,
    queries: &Matrix,
    gt: &[Vec<u32>],
    k: usize,
    grid: &[(String, SearchParams)],
) -> Vec<SweepPoint> {
    let method = label.unwrap_or_else(|| index.name());
    let m = index.dim();
    let rank = index.approx_rank();
    let mut ctx = SearchContext::for_universe(index.len()).with_stats();
    let mut out = Vec::new();
    for (param_label, params) in grid {
        // Warmup pass (stabilizes caches and pooled buffers), then timed.
        for qi in 0..queries.rows().min(8) {
            index.search(queries.row(qi), params, &mut ctx);
        }
        ctx.reset_stats();
        let mut total_recall = 0.0;
        let t0 = Instant::now();
        for qi in 0..queries.rows() {
            let res = index.search(queries.row(qi), params, &mut ctx);
            total_recall += recall(&res[..res.len().min(k)], &gt[qi]);
        }
        let secs = t0.elapsed().as_secs_f64();
        let nq = queries.rows() as f64;
        let stats = ctx.take_stats();
        out.push(SweepPoint {
            method: method.to_string(),
            param: param_label.clone(),
            recall10: total_recall / nq,
            qps: nq / secs.max(1e-9),
            mean_full_dist_calls: stats.dist_calls as f64 / nq,
            mean_approx_calls: stats.approx_calls as f64 / nq,
            effective_dist_calls: stats.effective_dist_calls(rank, m) / nq,
        });
    }
    out
}

/// Convenience: sweep a graph-family index over the default `ef` grid.
pub fn sweep_efs(
    index: &dyn AnnIndex,
    queries: &Matrix,
    gt: &[Vec<u32>],
    k: usize,
    efs: &[usize],
) -> Vec<SweepPoint> {
    run_sweep(None, index, queries, gt, k, &ef_grid(k, efs))
}

/// Sweep shard counts the way `sweep_efs` sweeps beam widths: for each
/// `S` in `shard_counts`, partition `data` under `spec` (its `n_shards`
/// is overridden), build one sub-index per shard with `build_shard`, and
/// measure the sharded index at fixed `params`. Points are labeled
/// `shards=S`, so the resulting CSV plots a throughput/recall curve along
/// the data-parallelism axis.
pub fn sweep_shard_counts<F>(
    label: &str,
    data: &Arc<Matrix>,
    queries: &Matrix,
    gt: &[Vec<u32>],
    k: usize,
    shard_counts: &[usize],
    spec: &ShardSpec,
    params: &SearchParams,
    build_shard: F,
) -> Vec<SweepPoint>
where
    F: Fn(Arc<Matrix>) -> Box<dyn AnnIndex> + Sync,
{
    let mut out = Vec::new();
    for &s in shard_counts {
        let spec = ShardSpec { n_shards: s, ..spec.clone() };
        let index = ShardedIndex::build(Arc::clone(data), &spec, &build_shard);
        let grid = vec![(format!("shards={s}"), params.clone())];
        out.extend(run_sweep(Some(label), &index, queries, gt, k, &grid));
    }
    out
}

/// Convenience: sweep IVF-PQ over an `n_probe` grid.
pub fn sweep_probes(
    index: &dyn AnnIndex,
    queries: &Matrix,
    gt: &[Vec<u32>],
    k: usize,
    probes: &[usize],
) -> Vec<SweepPoint> {
    run_sweep(None, index, queries, gt, k, &probe_grid(k, probes))
}

/// One step of a churn sweep: the index's quality against exact truth
/// over its *current* live set, after this step's inserts and deletes.
#[derive(Clone, Debug)]
pub struct ChurnPoint {
    pub step: usize,
    /// Live points after this step.
    pub live: usize,
    /// Tombstoned fraction after this step (pre-compaction pressure).
    pub tombstone_frac: f64,
    /// Whether `compact()` rebuilt this step.
    pub compacted: bool,
    pub recall10: f64,
    pub qps: f64,
}

impl ChurnPoint {
    pub fn csv_header() -> &'static str {
        "step,live,tombstone_frac,compacted,recall10,qps"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{:.4},{},{:.4},{:.1}",
            self.step, self.live, self.tombstone_frac, self.compacted, self.recall10, self.qps
        )
    }
}

/// Streaming-workload harness: interleave inserts, deletes, and query
/// batches against a *freshly built* mutable index, measuring
/// recall-over-time against an exact oracle that replays the identical
/// mutation schedule on a mutable brute-force index (so both always hold
/// the same live set under the same external ids). Deterministic for a
/// fixed seed.
#[allow(clippy::too_many_arguments)]
pub fn churn_sweep(
    index: &mut dyn MutableAnnIndex,
    queries: &Matrix,
    k: usize,
    params: &SearchParams,
    steps: usize,
    inserts_per_step: usize,
    deletes_per_step: usize,
    seed: u64,
) -> Vec<ChurnPoint> {
    // Freshness means *identity ids* (0..n, nothing tombstoned), not just
    // matching counts — a previously compacted or reloaded index has holes
    // in its id space and would diverge from the identity-id oracle.
    let identity: Vec<u32> = (0..index.len() as u32).collect();
    assert!(
        index.live_ids() == identity,
        "churn_sweep starts from a freshly built index (identity external ids)"
    );
    let dim = index.dim();
    let mut oracle = BruteForce::new(Arc::new(index.data().clone()));
    let mut ctx = SearchContext::new();
    let mut rng = Pcg32::new(seed);
    let mut live: Vec<u32> = (0..index.len() as u32).collect();
    let mut out = Vec::with_capacity(steps);
    for step in 0..steps {
        for _ in 0..inserts_per_step {
            let v: Vec<f32> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let a = index.insert(&v, &mut ctx).expect("insert");
            let b = oracle.insert(&v, &mut ctx).expect("oracle insert");
            assert_eq!(a, b, "index and oracle id watermarks diverged");
            live.push(a);
        }
        for _ in 0..deletes_per_step {
            if live.len() <= k {
                break;
            }
            let victim = live.swap_remove(rng.gen_range(live.len()));
            index.remove(victim).expect("remove");
            oracle.remove(victim).expect("oracle remove");
        }
        let tombstone_frac = index.tombstone_fraction();
        let compacted = index.compact(&mut ctx).expect("compact");
        oracle.compact(&mut ctx).expect("oracle compact");

        // Only the index search is timed — the oracle's exact scan is
        // measurement scaffolding and must not leak into the QPS curve.
        let mut total = 0.0;
        let mut search_secs = 0.0f64;
        for qi in 0..queries.rows() {
            let t0 = Instant::now();
            let got = index.search(queries.row(qi), params, &mut ctx);
            search_secs += t0.elapsed().as_secs_f64();
            let got_ids: Vec<u32> = got.iter().map(|n| n.id).collect();
            let want: Vec<u32> = oracle
                .search(queries.row(qi), &SearchParams::new(k), &mut ctx)
                .iter()
                .map(|n| n.id)
                .collect();
            total += recall_ids(&got_ids, &want);
        }
        out.push(ChurnPoint {
            step,
            live: index.live_len(),
            tombstone_frac,
            compacted,
            recall10: total / queries.rows().max(1) as f64,
            qps: queries.rows() as f64 / search_secs.max(1e-9),
        });
    }
    out
}

/// Write churn points as CSV.
pub fn churn_to_csv(points: &[ChurnPoint]) -> String {
    let mut s = String::from(ChurnPoint::csv_header());
    s.push('\n');
    for p in points {
        s.push_str(&p.to_csv());
        s.push('\n');
    }
    s
}

/// Write points as CSV.
pub fn to_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from(SweepPoint::csv_header());
    s.push('\n');
    for p in points {
        s.push_str(&p.to_csv());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::groundtruth::exact_knn;
    use crate::data::synth::tiny;
    use crate::graph::hnsw::HnswParams;
    use crate::index::impls::{BruteForce, HnswIndex, IvfPqIndex};
    use crate::quant::ivfpq::IvfPqParams;
    use std::sync::Arc;

    #[test]
    fn sweep_recall_monotone_in_ef() {
        let ds = tiny(111, 500, 16, Metric::L2);
        let h = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 60, ..Default::default() },
        );
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let pts = sweep_efs(&h, &ds.queries, &gt, 10, &[10, 160]);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].method, "hnsw");
        assert!(pts[1].recall10 >= pts[0].recall10 - 0.02);
        assert!(pts[0].qps > 0.0);
        assert!(pts[1].mean_full_dist_calls > pts[0].mean_full_dist_calls);
    }

    #[test]
    fn same_harness_sweeps_every_kind() {
        let ds = tiny(112, 300, 16, Metric::L2);
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let bf = BruteForce::new(Arc::clone(&ds.data));
        let ivf = IvfPqIndex::build(
            Arc::clone(&ds.data),
            IvfPqParams { n_list: 8, ..Default::default() },
        );
        let indexes: Vec<&dyn AnnIndex> = vec![&bf, &ivf];
        for index in indexes {
            let grid = if index.name() == "ivfpq" {
                probe_grid(10, &[2, 8])
            } else {
                ef_grid(10, &[10])
            };
            let pts = run_sweep(None, index, &ds.queries, &gt, 10, &grid);
            assert!(!pts.is_empty());
            assert_eq!(pts[0].method, index.name());
            assert!(pts.iter().all(|p| p.recall10 > 0.0));
        }
        // Brute force is exact by construction.
        let pts = sweep_efs(&bf, &ds.queries, &gt, 10, &[10]);
        assert!((pts[0].recall10 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn shard_count_sweep_produces_labeled_points() {
        let ds = tiny(113, 400, 12, Metric::L2);
        let gt = exact_knn(&ds.data, &ds.queries, 10);
        let pts = sweep_shard_counts(
            "sharded-bf",
            &ds.data,
            &ds.queries,
            &gt,
            10,
            &[1, 2, 4],
            &ShardSpec::default(),
            &SearchParams::new(10),
            |sub| -> Box<dyn AnnIndex> { Box::new(BruteForce::new(sub)) },
        );
        assert_eq!(pts.len(), 3);
        let labels: Vec<&str> = pts.iter().map(|p| p.param.as_str()).collect();
        assert_eq!(labels, vec!["shards=1", "shards=2", "shards=4"]);
        // Brute force stays exact at every shard count.
        for p in &pts {
            assert_eq!(p.method, "sharded-bf");
            assert!((p.recall10 - 1.0).abs() < 1e-9, "{}: {}", p.param, p.recall10);
        }
    }

    #[test]
    fn churn_sweep_tracks_live_set_and_is_deterministic() {
        let ds = tiny(114, 200, 8, Metric::L2);
        let run = || {
            let mut idx = HnswIndex::build(
                Arc::clone(&ds.data),
                HnswParams { m: 8, ef_construction: 60, ..Default::default() },
            );
            idx.set_compact_threshold(0.2);
            let params = SearchParams::new(10).with_ef(400);
            churn_sweep(&mut idx, &ds.queries, 10, &params, 6, 8, 12, 77)
        };
        let pts = run();
        assert_eq!(pts.len(), 6);
        assert!(pts.last().unwrap().live < 200, "net-negative churn shrinks the live set");
        for p in &pts {
            assert!(p.recall10 > 0.85, "step {}: recall {}", p.step, p.recall10);
        }
        assert!(
            pts.iter().any(|p| p.compacted),
            "accumulated tombstone pressure must cross the 0.2 threshold"
        );
        // Same seed, fresh index: identical curve (timing aside).
        let pts2 = run();
        for (a, b) in pts.iter().zip(&pts2) {
            assert_eq!(a.live, b.live);
            assert_eq!(a.recall10, b.recall10);
            assert_eq!(a.compacted, b.compacted);
            assert_eq!(a.tombstone_frac, b.tombstone_frac);
        }
        // CSV shape.
        let csv = churn_to_csv(&pts);
        assert_eq!(csv.lines().count(), 7);
        assert!(csv.starts_with("step,"));
    }

    #[test]
    fn csv_shape() {
        let p = SweepPoint {
            method: "x".into(),
            param: "ef=1".into(),
            recall10: 0.5,
            qps: 100.0,
            mean_full_dist_calls: 10.0,
            mean_approx_calls: 0.0,
            effective_dist_calls: 10.0,
        };
        let csv = to_csv(&[p]);
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("method,"));
    }
}
