//! Evaluation: recall metrics, throughput/recall sweeps, and per-figure
//! harnesses regenerating every table and figure of the paper.

pub mod figures;
pub mod hotpath;
pub mod recall;
pub mod sweep;

pub use recall::{recall, recall_ids};
pub use sweep::{ChurnPoint, SweepPoint, DEFAULT_EFS, DEFAULT_PROBES};
