//! Replica-side replication client: connects to a leader, applies the
//! ordered op stream through the shared [`ServeIndex`], and acks each
//! op once it is durable locally.
//!
//! The replica is strict about sequencing: after applying seq `s`, the
//! only acceptable next op is `s + 1`. A gap means a frame was lost in
//! transit (or the leader's log diverged); a lower-or-equal seq means a
//! duplicate. Either way the replica counts a violation, drops the
//! connection, and reconnects with a fresh `Hello { last_seq: applied }`
//! — the leader's catch-up path then re-delivers exactly the missing
//! suffix (or a snapshot if the tail was compacted away). Torn and
//! corrupt frames never reach this layer; the frame codec rejects them.
//!
//! Durability comes in three flavours ([`ReplicaStore`]): ephemeral
//! (re-snapshot on restart), an owned WAL directory (the classic
//! `--replica-of` shape), or a *shared* [`Wal`] handle for cluster
//! nodes — the node owns one WAL across its leader/follower role flips,
//! and a received snapshot swaps its generation in place via
//! [`Wal::reinstall_into`] (wiping any divergent uncommitted tail a
//! deposed leader may carry). Received snapshots replace the local
//! generation byte-for-byte, preserving the determinism contract.
//!
//! Reconnects use capped exponential backoff with deterministic seeded
//! jitter: `min(base << attempt, cap) + uniform(0..=25%)`, attempt
//! resetting whenever a connection makes progress. Counters live in
//! [`ReplMetrics`], surfaced through the REPL_STATUS verb.

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::core::rng::Pcg32;
use crate::repl::frame::Frame;
use crate::router::server::ServeIndex;
use crate::wal::{FsyncPolicy, Wal};

/// Where the replica keeps its durable state.
#[derive(Clone)]
pub enum ReplicaStore {
    /// Ephemeral: no local WAL; re-snapshots from the leader on restart.
    None,
    /// Own a WAL generation under this directory (recovered at start).
    Dir(PathBuf),
    /// Share the cluster node's WAL: snapshots swap its generation in
    /// place ([`Wal::reinstall_into`]); ops append through the normal
    /// apply path. The node must NOT run a second writer on the same
    /// directory.
    Shared(Arc<Wal>),
}

impl std::fmt::Debug for ReplicaStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReplicaStore::None => write!(f, "None"),
            ReplicaStore::Dir(d) => write!(f, "Dir({})", d.display()),
            ReplicaStore::Shared(_) => write!(f, "Shared(..)"),
        }
    }
}

/// Called with the applied seq each time this replica's local log
/// genuinely advances from the leader's stream (snapshot install or op
/// apply). Cluster nodes use it to label their election log position
/// with the term whose stream the data actually came from — NOT the
/// term of whichever leader is merely being heard.
pub type ApplyHook = Arc<dyn Fn(u64) + Send + Sync>;

/// Replica configuration.
#[derive(Clone)]
pub struct ReplicaOpts {
    pub store: ReplicaStore,
    pub policy: FsyncPolicy,
    /// First reconnect backoff; doubles per consecutive failed attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling (jitter is added on top).
    pub backoff_cap: Duration,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
    /// Request a full snapshot on the first connection even if local
    /// state exists — cluster followers set this on every new
    /// (leader, term) so a divergent uncommitted tail cannot survive.
    pub force_snapshot: bool,
    /// Observer of genuine local log advancement (see [`ApplyHook`]).
    pub on_apply: Option<ApplyHook>,
}

impl std::fmt::Debug for ReplicaOpts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReplicaOpts")
            .field("store", &self.store)
            .field("policy", &self.policy)
            .field("backoff_base", &self.backoff_base)
            .field("backoff_cap", &self.backoff_cap)
            .field("seed", &self.seed)
            .field("force_snapshot", &self.force_snapshot)
            .field("on_apply", &self.on_apply.as_ref().map(|_| ".."))
            .finish()
    }
}

impl Default for ReplicaOpts {
    fn default() -> Self {
        ReplicaOpts {
            store: ReplicaStore::None,
            policy: FsyncPolicy::EveryN(8),
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            seed: 0x5EED,
            force_snapshot: false,
            on_apply: None,
        }
    }
}

/// Reconnect/stream counters for the REPL_STATUS verb. All monotonic
/// except `last_backoff_ms` (a gauge).
#[derive(Debug, Default)]
pub struct ReplMetrics {
    /// Reconnect cycles entered (every time the stream ends and the
    /// loop goes back to dial).
    pub reconnect_attempts: AtomicU64,
    /// Reconnect cycles whose connection then made progress (applied
    /// advanced or caught up).
    pub reconnects_completed: AtomicU64,
    /// Full snapshots installed from the stream.
    pub snapshots_installed: AtomicU64,
    /// Sequencing violations (gaps/duplicates that forced a reconnect).
    pub violations: AtomicU64,
    /// Backoff chosen after the most recent disconnect, in ms.
    pub last_backoff_ms: AtomicU64,
}

/// Handle to the background replication loop. Dropping it does NOT stop
/// the loop; call [`Replica::stop`].
pub struct Replica {
    applied: Arc<AtomicU64>,
    ready: Arc<AtomicBool>,
    metrics: Arc<ReplMetrics>,
    stop: Arc<AtomicBool>,
    /// Live connection, shared so `stop()` can shut the socket down and
    /// unblock a reader waiting on a quiet leader.
    conn: Arc<Mutex<Option<TcpStream>>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// `min(base << attempt, cap)` plus up to 25% deterministic jitter.
fn backoff_for(attempt: u32, base: Duration, cap: Duration, rng: &mut Pcg32) -> Duration {
    let base_ms = base.as_millis().max(1) as u64;
    let cap_ms = cap.as_millis().max(1) as u64;
    let exp = base_ms.saturating_mul(1u64 << attempt.min(20)).min(cap_ms);
    let jitter_span = (exp / 4).max(1) as usize;
    Duration::from_millis(exp + rng.gen_range(jitter_span) as u64)
}

impl Replica {
    /// Start replicating from `primary` into `serve`. If a local WAL
    /// generation already exists under a [`ReplicaStore::Dir`], it is
    /// recovered and installed first, so the replica resumes from its
    /// durable position instead of re-fetching a snapshot (and the
    /// serve index leaves its warming state immediately — stale reads
    /// beat no reads).
    pub fn start(
        primary: SocketAddr,
        serve: Arc<ServeIndex>,
        opts: ReplicaOpts,
    ) -> io::Result<Replica> {
        let applied = Arc::new(AtomicU64::new(0));
        let ready = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ReplMetrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let conn: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));

        let mut local = LocalWal::None;
        let mut has_state = false;
        match &opts.store {
            ReplicaStore::None => {}
            ReplicaStore::Dir(dir) => {
                if Wal::has_snapshot(dir) {
                    let (index, wal, report) = Wal::recover(dir, opts.policy)?;
                    serve.install(index, report.last_seq);
                    serve.set_ready();
                    applied.store(report.last_seq, Ordering::SeqCst);
                    local = LocalWal::Owned(wal);
                    has_state = true;
                }
            }
            ReplicaStore::Shared(wal) => {
                // The cluster node recovered this WAL and installed the
                // index before flipping into follower mode; pick up its
                // position rather than re-deriving it.
                applied.store(serve.applied_seq(), Ordering::SeqCst);
                local = LocalWal::Shared(Arc::clone(wal));
                has_state = true;
            }
        }

        let thread = {
            let applied = Arc::clone(&applied);
            let ready = Arc::clone(&ready);
            let metrics = Arc::clone(&metrics);
            let stop = Arc::clone(&stop);
            let conn = Arc::clone(&conn);
            let backoff_base = opts.backoff_base;
            let backoff_cap = opts.backoff_cap;
            let mut rng = Pcg32::new(opts.seed);
            std::thread::Builder::new().name("finger-replica".into()).spawn(move || {
                let mut st = StreamState {
                    serve,
                    force_snapshot: opts.force_snapshot,
                    opts,
                    local,
                    has_state,
                    conn,
                    metrics: Arc::clone(&metrics),
                };
                let mut attempt: u32 = 0;
                while !stop.load(Ordering::Relaxed) {
                    let before = applied.load(Ordering::SeqCst);
                    // Ok(()) is a clean EOF (leader went away); errors are
                    // connect failures or protocol violations — the latter
                    // are tallied inside stream_once where the context is.
                    let _ = st.stream_once(primary, &applied, &ready, &stop);
                    let progressed =
                        applied.load(Ordering::SeqCst) > before || ready.load(Ordering::SeqCst);
                    ready.store(false, Ordering::SeqCst);
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    metrics.reconnect_attempts.fetch_add(1, Ordering::Relaxed);
                    if progressed {
                        metrics.reconnects_completed.fetch_add(1, Ordering::Relaxed);
                        attempt = 0;
                    }
                    let pause = backoff_for(attempt, backoff_base, backoff_cap, &mut rng);
                    metrics.last_backoff_ms.store(pause.as_millis() as u64, Ordering::Relaxed);
                    attempt = attempt.saturating_add(1);
                    // Sleep in slices so stop() is honoured promptly even
                    // at the backoff ceiling.
                    let deadline = Instant::now() + pause;
                    while Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                }
            })?
        };

        Ok(Replica { applied, ready, metrics, stop, conn, thread: Some(thread) })
    }

    /// Highest seq applied locally.
    pub fn applied(&self) -> u64 {
        self.applied.load(Ordering::SeqCst)
    }

    /// True once the leader signalled the replica is caught up on the
    /// current connection.
    pub fn is_ready(&self) -> bool {
        self.ready.load(Ordering::SeqCst)
    }

    /// Sequencing violations detected (gaps or duplicates that forced a
    /// reconnect). Fault-injection tests assert this moves.
    pub fn violations(&self) -> u64 {
        self.metrics.violations.load(Ordering::Relaxed)
    }

    /// Reconnect cycles entered.
    pub fn reconnects(&self) -> u64 {
        self.metrics.reconnect_attempts.load(Ordering::Relaxed)
    }

    /// Shared counters for the REPL_STATUS verb.
    pub fn metrics(&self) -> Arc<ReplMetrics> {
        Arc::clone(&self.metrics)
    }

    /// Poll until caught up or `timeout` elapses.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.is_ready() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.is_ready()
    }

    /// Poll until `applied() >= seq` or `timeout` elapses.
    pub fn wait_applied(&self, seq: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.applied() >= seq {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.applied() >= seq
    }

    /// Stop the loop and join it. Releases the local WAL lock so a
    /// successor replica can reopen the same directory.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(stream) = self.conn.lock().unwrap_or_else(|e| e.into_inner()).take() {
            stream.shutdown(std::net::Shutdown::Both).ok();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// The replica's live WAL handle (see [`ReplicaStore`]).
enum LocalWal {
    None,
    Owned(Wal),
    Shared(Arc<Wal>),
}

impl LocalWal {
    fn as_wal(&self) -> Option<&Wal> {
        match self {
            LocalWal::None => None,
            LocalWal::Owned(w) => Some(w),
            LocalWal::Shared(w) => Some(w),
        }
    }
}

/// Mutable state owned by the replication thread across reconnects.
struct StreamState {
    serve: Arc<ServeIndex>,
    opts: ReplicaOpts,
    local: LocalWal,
    has_state: bool,
    /// Ask for a snapshot on the next handshake regardless of local
    /// state; cleared once one is installed.
    force_snapshot: bool,
    conn: Arc<Mutex<Option<TcpStream>>>,
    metrics: Arc<ReplMetrics>,
}

impl StreamState {
    /// One connection lifetime: handshake, then apply frames until EOF,
    /// error, or stop. Sequencing violations bump the metric before the
    /// connection is abandoned; the caller reconnects either way.
    fn stream_once(
        &mut self,
        primary: SocketAddr,
        applied: &AtomicU64,
        ready: &AtomicBool,
        stop: &AtomicBool,
    ) -> io::Result<()> {
        let mut out = TcpStream::connect_timeout(&primary, Duration::from_millis(500))?;
        out.set_nodelay(true).ok();
        // Publish the socket so stop() can shut it down and unblock the
        // (otherwise fully blocking) frame reads below.
        *self.conn.lock().unwrap_or_else(|e| e.into_inner()) = Some(out.try_clone()?);
        let mut reader = BufReader::new(out.try_clone()?);
        Frame::Hello {
            last_seq: if self.force_snapshot { 0 } else { applied.load(Ordering::SeqCst) },
            need_snapshot: !self.has_state || self.force_snapshot,
        }
        .write_to(&mut out)?;

        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let frame = match Frame::read_from(&mut reader) {
                Ok(Some(f)) => f,
                Ok(None) => return Ok(()), // clean EOF
                Err(e) => return Err(e),
            };
            match frame {
                Frame::Snapshot { snapshot_seq, bundle } => {
                    let index = crate::data::persist::load_index_from_slice(&bundle)
                        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
                    match &self.opts.store {
                        ReplicaStore::None => {}
                        ReplicaStore::Dir(dir) => {
                            // Replace the local generation with the
                            // leader's bytes verbatim before exposing the
                            // new state.
                            self.local = LocalWal::Owned(Wal::reinstall(
                                dir,
                                snapshot_seq,
                                &bundle,
                                self.opts.policy,
                            )?);
                        }
                        ReplicaStore::Shared(wal) => {
                            // Swap the shared WAL's generation in place —
                            // this wipes any divergent uncommitted tail
                            // from a deposed-leader past.
                            wal.reinstall_into(snapshot_seq, &bundle)?;
                        }
                    }
                    self.serve.install(index, snapshot_seq);
                    applied.store(snapshot_seq, Ordering::SeqCst);
                    self.has_state = true;
                    self.force_snapshot = false;
                    self.metrics.snapshots_installed.fetch_add(1, Ordering::Relaxed);
                    // The local log now genuinely reflects the leader's
                    // stream (a divergent tail was wiped just above).
                    if let Some(hook) = &self.opts.on_apply {
                        hook(snapshot_seq);
                    }
                    Frame::Ack { seq: snapshot_seq }.write_to(&mut out)?;
                }
                Frame::Op { record } => {
                    let (seq, op) = Frame::Op { record }
                        .op_record()
                        .expect("frame codec validated the op payload");
                    let expect = applied.load(Ordering::SeqCst) + 1;
                    if !self.has_state || seq != expect {
                        // Gap (lost frame) or duplicate: refuse to apply,
                        // reconnect, and let catch-up repair the stream.
                        self.metrics.violations.fetch_add(1, Ordering::Relaxed);
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("seq violation: got {seq}, expected {expect}"),
                        ));
                    }
                    self.serve
                        .apply_replicated(seq, &op, self.local.as_wal())
                        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
                    applied.store(seq, Ordering::SeqCst);
                    if let Some(hook) = &self.opts.on_apply {
                        hook(seq);
                    }
                    Frame::Ack { seq }.write_to(&mut out)?;
                }
                Frame::CaughtUp { seq: _ } => {
                    ready.store(true, Ordering::SeqCst);
                    // End of warming: the serve index may now answer
                    // queries (one-way latch; stays up across later
                    // disconnects so stale reads keep flowing).
                    self.serve.set_ready();
                }
                _ => {
                    // Handshake/ack/election traffic has no business on a
                    // replica's downstream.
                    self.metrics.violations.fetch_add(1, Ordering::Relaxed);
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "unexpected frame from leader",
                    ));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_capped_exponential_with_bounded_jitter() {
        let base = Duration::from_millis(50);
        let cap = Duration::from_secs(2);
        let mut rng = Pcg32::new(0x5EED);
        for attempt in 0..24u32 {
            let exp = 50u64.saturating_mul(1 << attempt.min(20)).min(2000);
            let b = backoff_for(attempt, base, cap, &mut rng).as_millis() as u64;
            assert!(b >= exp, "attempt {attempt}: {b} below floor {exp}");
            assert!(b <= exp + (exp / 4).max(1), "attempt {attempt}: {b} above jitter bound");
        }
        // Deterministic for a given seed.
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for attempt in 0..8 {
            assert_eq!(
                backoff_for(attempt, base, cap, &mut a),
                backoff_for(attempt, base, cap, &mut b)
            );
        }
    }
}
