//! Replica-side replication client: connects to a primary, applies the
//! ordered op stream through the shared [`ServeIndex`], and acks each
//! op once it is durable locally.
//!
//! The replica is strict about sequencing: after applying seq `s`, the
//! only acceptable next op is `s + 1`. A gap means a frame was lost in
//! transit (or the primary's log diverged); a lower-or-equal seq means a
//! duplicate. Either way the replica counts a violation, drops the
//! connection, and reconnects with a fresh `Hello { last_seq: applied }`
//! — the primary's catch-up path then re-delivers exactly the missing
//! suffix (or a snapshot if the tail was compacted away). Torn and
//! corrupt frames never reach this layer; the frame codec rejects them.
//!
//! When the replica keeps its own WAL (`ReplicaOpts::wal_dir`), every
//! applied op is appended and committed there before the ack goes back,
//! so a primary running at ack level `all` over replicas with
//! `--fsync-policy always` gets true multi-node durability. A received
//! snapshot atomically replaces the local generation via
//! [`Wal::reinstall`], byte-for-byte, preserving the determinism
//! contract: primary and replica bundles stay byte-identical.

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::repl::frame::Frame;
use crate::router::server::ServeIndex;
use crate::wal::{FsyncPolicy, Wal};

/// Replica configuration. `wal_dir: None` keeps the replica ephemeral
/// (it re-snapshots from the primary on every restart).
#[derive(Clone, Debug)]
pub struct ReplicaOpts {
    pub wal_dir: Option<PathBuf>,
    pub policy: FsyncPolicy,
    /// Pause between reconnect attempts after a dropped stream.
    pub reconnect: Duration,
}

impl Default for ReplicaOpts {
    fn default() -> Self {
        ReplicaOpts {
            wal_dir: None,
            policy: FsyncPolicy::EveryN(8),
            reconnect: Duration::from_millis(50),
        }
    }
}

/// Handle to the background replication loop. Dropping it does NOT stop
/// the loop; call [`Replica::stop`].
pub struct Replica {
    applied: Arc<AtomicU64>,
    ready: Arc<AtomicBool>,
    violations: Arc<AtomicU64>,
    reconnects: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    /// Live connection, shared so `stop()` can shut the socket down and
    /// unblock a reader waiting on a quiet primary.
    conn: Arc<Mutex<Option<TcpStream>>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Replica {
    /// Start replicating from `primary` into `serve`. If a local WAL
    /// generation already exists under `opts.wal_dir`, it is recovered
    /// and installed first, so the replica resumes from its durable
    /// position instead of re-fetching a snapshot.
    pub fn start(
        primary: SocketAddr,
        serve: Arc<ServeIndex>,
        opts: ReplicaOpts,
    ) -> io::Result<Replica> {
        let applied = Arc::new(AtomicU64::new(0));
        let ready = Arc::new(AtomicBool::new(false));
        let violations = Arc::new(AtomicU64::new(0));
        let reconnects = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let conn: Arc<Mutex<Option<TcpStream>>> = Arc::new(Mutex::new(None));

        let mut local: Option<Wal> = None;
        let mut has_state = false;
        if let Some(dir) = &opts.wal_dir {
            if Wal::has_snapshot(dir) {
                let (index, wal, report) = Wal::recover(dir, opts.policy)?;
                serve.install(index, report.last_seq);
                applied.store(report.last_seq, Ordering::SeqCst);
                local = Some(wal);
                has_state = true;
            }
        }

        let thread = {
            let applied = Arc::clone(&applied);
            let ready = Arc::clone(&ready);
            let violations = Arc::clone(&violations);
            let reconnects = Arc::clone(&reconnects);
            let stop = Arc::clone(&stop);
            let conn = Arc::clone(&conn);
            std::thread::Builder::new().name("finger-replica".into()).spawn(move || {
                let mut st = StreamState { serve, opts, local, has_state, conn };
                while !stop.load(Ordering::Relaxed) {
                    // Ok(()) is a clean EOF (primary went away); errors are
                    // connect failures or protocol violations — the latter
                    // are tallied inside stream_once where the context is.
                    let _ = st.stream_once(primary, &applied, &ready, &violations, &stop);
                    ready.store(false, Ordering::SeqCst);
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    reconnects.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(st.opts.reconnect);
                }
            })?
        };

        Ok(Replica {
            applied,
            ready,
            violations,
            reconnects,
            stop,
            conn,
            thread: Some(thread),
        })
    }

    /// Highest seq applied locally.
    pub fn applied(&self) -> u64 {
        self.applied.load(Ordering::SeqCst)
    }

    /// True once the primary signalled the replica is caught up on the
    /// current connection.
    pub fn is_ready(&self) -> bool {
        self.ready.load(Ordering::SeqCst)
    }

    /// Sequencing violations detected (gaps or duplicates that forced a
    /// reconnect). Fault-injection tests assert this moves.
    pub fn violations(&self) -> u64 {
        self.violations.load(Ordering::Relaxed)
    }

    /// Completed reconnect cycles.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::Relaxed)
    }

    /// Poll until caught up or `timeout` elapses.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.is_ready() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.is_ready()
    }

    /// Poll until `applied() >= seq` or `timeout` elapses.
    pub fn wait_applied(&self, seq: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if self.applied() >= seq {
                return true;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.applied() >= seq
    }

    /// Stop the loop and join it. Releases the local WAL lock so a
    /// successor replica can reopen the same directory.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(stream) = self.conn.lock().unwrap_or_else(|e| e.into_inner()).take() {
            stream.shutdown(std::net::Shutdown::Both).ok();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Mutable state owned by the replication thread across reconnects.
struct StreamState {
    serve: Arc<ServeIndex>,
    opts: ReplicaOpts,
    local: Option<Wal>,
    has_state: bool,
    conn: Arc<Mutex<Option<TcpStream>>>,
}

impl StreamState {
    /// One connection lifetime: handshake, then apply frames until EOF,
    /// error, or stop. Sequencing violations bump `violations` before the
    /// connection is abandoned; the caller reconnects either way.
    fn stream_once(
        &mut self,
        primary: SocketAddr,
        applied: &AtomicU64,
        ready: &AtomicBool,
        violations: &AtomicU64,
        stop: &AtomicBool,
    ) -> io::Result<()> {
        let mut out = TcpStream::connect_timeout(&primary, Duration::from_millis(500))?;
        out.set_nodelay(true).ok();
        // Publish the socket so stop() can shut it down and unblock the
        // (otherwise fully blocking) frame reads below.
        *self.conn.lock().unwrap_or_else(|e| e.into_inner()) = Some(out.try_clone()?);
        let mut reader = BufReader::new(out.try_clone()?);
        Frame::Hello {
            last_seq: applied.load(Ordering::SeqCst),
            need_snapshot: !self.has_state,
        }
        .write_to(&mut out)?;

        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            let frame = match Frame::read_from(&mut reader) {
                Ok(Some(f)) => f,
                Ok(None) => return Ok(()), // clean EOF
                Err(e) => return Err(e),
            };
            match frame {
                Frame::Snapshot { snapshot_seq, bundle } => {
                    let index = crate::data::persist::load_index_from_slice(&bundle)
                        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
                    if let Some(dir) = &self.opts.wal_dir {
                        // Replace the local generation with the primary's
                        // bytes verbatim before exposing the new state.
                        self.local =
                            Some(Wal::reinstall(dir, snapshot_seq, &bundle, self.opts.policy)?);
                    }
                    self.serve.install(index, snapshot_seq);
                    applied.store(snapshot_seq, Ordering::SeqCst);
                    self.has_state = true;
                    Frame::Ack { seq: snapshot_seq }.write_to(&mut out)?;
                }
                Frame::Op { record } => {
                    let (seq, op) = Frame::Op { record }
                        .op_record()
                        .expect("frame codec validated the op payload");
                    let expect = applied.load(Ordering::SeqCst) + 1;
                    if !self.has_state || seq != expect {
                        // Gap (lost frame) or duplicate: refuse to apply,
                        // reconnect, and let catch-up repair the stream.
                        violations.fetch_add(1, Ordering::Relaxed);
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("seq violation: got {seq}, expected {expect}"),
                        ));
                    }
                    self.serve
                        .apply_replicated(seq, &op, self.local.as_ref())
                        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
                    applied.store(seq, Ordering::SeqCst);
                    Frame::Ack { seq }.write_to(&mut out)?;
                }
                Frame::CaughtUp { seq: _ } => {
                    ready.store(true, Ordering::SeqCst);
                }
                Frame::Hello { .. } | Frame::Ack { .. } => {
                    violations.fetch_add(1, Ordering::Relaxed);
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "unexpected handshake/ack frame from primary",
                    ));
                }
            }
        }
    }
}
