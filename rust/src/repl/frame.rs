//! Replication wire frames: length-prefixed, CRC-checked, little-endian.
//!
//! The stream between a primary and a replica is a sequence of frames:
//!
//! ```text
//!   crc u32 | len u32 | type u8 | payload (len bytes)
//! ```
//!
//! `crc` is the WAL's CRC-32 (IEEE, [`crate::wal::record`]) over
//! `type || payload` — the same per-record verification discipline as
//! the log, lifted to the wire, so a bit flip or a torn TCP segment is
//! detected before anything is applied. `len` is u32 (not the log's
//! u16) because a `Snapshot` frame carries a whole persisted bundle.
//!
//! Frame types (type byte in parentheses; `0` reserved, like the log's
//! padding sentinel):
//!
//! * `Hello` (1), replica → primary: `last_seq u64 | need_snapshot u8`.
//!   Opens every connection; `last_seq` is the replica's durable
//!   position, `need_snapshot` forces a full snapshot when the replica
//!   has no local state at all.
//! * `Snapshot` (2), primary → replica: `snapshot_seq u64 | bundle`.
//!   The bundle bytes are a complete `save_index` v5 bundle, verbatim.
//! * `Op` (3), primary → replica: exactly [`WalOp::encode`]`(seq)` — the
//!   WAL's logical record, reused unchanged, so the replication stream
//!   and the log literally share one serialization.
//! * `Ack` (4), replica → primary: `seq u64`, the replica's new durable
//!   position.
//! * `CaughtUp` (5), primary → replica: `seq u64`, sent once the
//!   registration-time catch-up is fully enqueued; the replica uses it
//!   to report readiness.
//!
//! Election frames (types 6–9, one request/response pair per
//! short-lived connection between election endpoints):
//!
//! * `VoteRequest` (6), candidate → peer:
//!   `term u64 | candidate u64 | last_log_term u64 | last_seq u64`.
//!   The `(last_log_term, last_seq)` pair is the candidate's log
//!   position; a peer grants only to candidates at least as up to date
//!   as itself (lexicographic compare), so a node missing
//!   quorum-committed ops can never win.
//! * `VoteReply` (7), peer → candidate: `term u64 | granted u8`.
//! * `Heartbeat` (8), leader → peer: `term u64 | leader u64 |
//!   commit u64 | repl_len u16 | repl addr bytes | query_len u16 |
//!   query addr bytes`. The addr strings advertise where the leader's
//!   replication hub and query plane live, so followers discover both
//!   without any out-of-band config.
//! * `HeartbeatAck` (9), peer → leader: `term u64` (a higher term than
//!   the leader's fences a deposed leader immediately).
//!
//! The golden fixture `rust/tests/fixtures/repl_frame_v1.bin` pins the
//! v1 (types 1–5) encoding byte for byte; any drift fails `repl_props`.
//! Types 6–9 are additive — the v1 bytes are untouched.

use std::io::{self, Read, Write};

use crate::wal::record::crc32;
use crate::wal::WalOp;

/// Frame header: crc u32 + len u32 + type u8.
pub const HEADER_SIZE: usize = 9;
/// Sanity cap on a frame payload (a snapshot bundle can be large, but a
/// garbage length must not allocate unboundedly).
pub const MAX_FRAME: usize = 1 << 30;

const TY_HELLO: u8 = 1;
const TY_SNAPSHOT: u8 = 2;
const TY_OP: u8 = 3;
const TY_ACK: u8 = 4;
const TY_CAUGHT_UP: u8 = 5;
const TY_VOTE_REQUEST: u8 = 6;
const TY_VOTE_REPLY: u8 = 7;
const TY_HEARTBEAT: u8 = 8;
const TY_HEARTBEAT_ACK: u8 = 9;

/// Cap on an advertised addr string inside a `Heartbeat` frame.
const MAX_ADDR: usize = 256;

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// One replication frame. See the module docs for the wire layout.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Hello { last_seq: u64, need_snapshot: bool },
    Snapshot { snapshot_seq: u64, bundle: Vec<u8> },
    /// Payload is exactly `WalOp::encode(seq)`.
    Op { record: Vec<u8> },
    Ack { seq: u64 },
    CaughtUp { seq: u64 },
    VoteRequest { term: u64, candidate: u64, last_log_term: u64, last_seq: u64 },
    VoteReply { term: u64, granted: bool },
    Heartbeat { term: u64, leader: u64, commit: u64, repl_addr: String, query_addr: String },
    HeartbeatAck { term: u64 },
}

impl Frame {
    /// An `Op` frame straight from a logical WAL op.
    pub fn op(seq: u64, op: &WalOp) -> Frame {
        Frame::Op { record: op.encode(seq) }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::Snapshot { .. } => "snapshot",
            Frame::Op { .. } => "op",
            Frame::Ack { .. } => "ack",
            Frame::CaughtUp { .. } => "caught_up",
            Frame::VoteRequest { .. } => "vote_request",
            Frame::VoteReply { .. } => "vote_reply",
            Frame::Heartbeat { .. } => "heartbeat",
            Frame::HeartbeatAck { .. } => "heartbeat_ack",
        }
    }

    fn type_byte(&self) -> u8 {
        match self {
            Frame::Hello { .. } => TY_HELLO,
            Frame::Snapshot { .. } => TY_SNAPSHOT,
            Frame::Op { .. } => TY_OP,
            Frame::Ack { .. } => TY_ACK,
            Frame::CaughtUp { .. } => TY_CAUGHT_UP,
            Frame::VoteRequest { .. } => TY_VOTE_REQUEST,
            Frame::VoteReply { .. } => TY_VOTE_REPLY,
            Frame::Heartbeat { .. } => TY_HEARTBEAT,
            Frame::HeartbeatAck { .. } => TY_HEARTBEAT_ACK,
        }
    }

    fn payload(&self) -> Vec<u8> {
        match self {
            Frame::Hello { last_seq, need_snapshot } => {
                let mut p = Vec::with_capacity(9);
                p.extend_from_slice(&last_seq.to_le_bytes());
                p.push(u8::from(*need_snapshot));
                p
            }
            Frame::Snapshot { snapshot_seq, bundle } => {
                let mut p = Vec::with_capacity(8 + bundle.len());
                p.extend_from_slice(&snapshot_seq.to_le_bytes());
                p.extend_from_slice(bundle);
                p
            }
            Frame::Op { record } => record.clone(),
            Frame::Ack { seq } | Frame::CaughtUp { seq } => seq.to_le_bytes().to_vec(),
            Frame::VoteRequest { term, candidate, last_log_term, last_seq } => {
                let mut p = Vec::with_capacity(32);
                p.extend_from_slice(&term.to_le_bytes());
                p.extend_from_slice(&candidate.to_le_bytes());
                p.extend_from_slice(&last_log_term.to_le_bytes());
                p.extend_from_slice(&last_seq.to_le_bytes());
                p
            }
            Frame::VoteReply { term, granted } => {
                let mut p = Vec::with_capacity(9);
                p.extend_from_slice(&term.to_le_bytes());
                p.push(u8::from(*granted));
                p
            }
            Frame::Heartbeat { term, leader, commit, repl_addr, query_addr } => {
                let mut p = Vec::with_capacity(28 + repl_addr.len() + query_addr.len());
                p.extend_from_slice(&term.to_le_bytes());
                p.extend_from_slice(&leader.to_le_bytes());
                p.extend_from_slice(&commit.to_le_bytes());
                for addr in [repl_addr, query_addr] {
                    p.extend_from_slice(&(addr.len() as u16).to_le_bytes());
                    p.extend_from_slice(addr.as_bytes());
                }
                p
            }
            Frame::HeartbeatAck { term } => term.to_le_bytes().to_vec(),
        }
    }

    /// Serialize: header + payload, ready for one `write_all`.
    pub fn encode(&self) -> Vec<u8> {
        let ty = self.type_byte();
        let payload = self.payload();
        let mut crc_buf = Vec::with_capacity(1 + payload.len());
        crc_buf.push(ty);
        crc_buf.extend_from_slice(&payload);
        let mut out = Vec::with_capacity(HEADER_SIZE + payload.len());
        out.extend_from_slice(&crc32(&crc_buf).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.push(ty);
        out.extend_from_slice(&payload);
        out
    }

    pub fn write_to(&self, w: &mut dyn Write) -> io::Result<()> {
        w.write_all(&self.encode())?;
        w.flush()
    }

    /// Read one frame. `Ok(None)` is a clean EOF (zero bytes before the
    /// header); anything torn, CRC-mismatched, oversized, or unknown is
    /// an error — the caller drops the connection rather than applying a
    /// suspect frame.
    pub fn read_from(r: &mut dyn Read) -> io::Result<Option<Frame>> {
        let mut header = [0u8; HEADER_SIZE];
        let mut got = 0;
        while got < HEADER_SIZE {
            match r.read(&mut header[got..]) {
                Ok(0) if got == 0 => return Ok(None),
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        format!("torn frame header ({got} of {HEADER_SIZE} bytes)"),
                    ))
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        let crc = u32::from_le_bytes(header[..4].try_into().unwrap());
        let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
        let ty = header[8];
        if len > MAX_FRAME {
            return Err(invalid(format!("frame claims {len} bytes (cap {MAX_FRAME})")));
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).map_err(|e| {
            io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("torn frame payload (want {len} bytes): {e}"),
            )
        })?;
        let mut crc_buf = Vec::with_capacity(1 + len);
        crc_buf.push(ty);
        crc_buf.extend_from_slice(&payload);
        if crc32(&crc_buf) != crc {
            return Err(invalid("frame CRC mismatch".into()));
        }
        Frame::decode_payload(ty, payload).map(Some).map_err(invalid)
    }

    fn decode_payload(ty: u8, payload: Vec<u8>) -> Result<Frame, String> {
        let u64_at = |p: &[u8]| -> Result<u64, String> {
            p.get(..8)
                .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
                .ok_or_else(|| "frame payload too short for u64".to_string())
        };
        match ty {
            TY_HELLO => {
                if payload.len() != 9 {
                    return Err(format!("hello frame wants 9 bytes, got {}", payload.len()));
                }
                let need_snapshot = match payload[8] {
                    0 => false,
                    1 => true,
                    other => return Err(format!("hello need_snapshot byte {other}")),
                };
                Ok(Frame::Hello { last_seq: u64_at(&payload)?, need_snapshot })
            }
            TY_SNAPSHOT => {
                let snapshot_seq = u64_at(&payload)?;
                Ok(Frame::Snapshot { snapshot_seq, bundle: payload[8..].to_vec() })
            }
            TY_OP => {
                // Validate now so a malformed record never reaches apply;
                // keep the original bytes (the replica re-decodes, and the
                // bytes are what its own WAL append must reproduce).
                WalOp::decode(&payload)?;
                Ok(Frame::Op { record: payload })
            }
            TY_ACK => {
                if payload.len() != 8 {
                    return Err(format!("ack frame wants 8 bytes, got {}", payload.len()));
                }
                Ok(Frame::Ack { seq: u64_at(&payload)? })
            }
            TY_CAUGHT_UP => {
                if payload.len() != 8 {
                    return Err(format!("caught_up frame wants 8 bytes, got {}", payload.len()));
                }
                Ok(Frame::CaughtUp { seq: u64_at(&payload)? })
            }
            TY_VOTE_REQUEST => {
                if payload.len() != 32 {
                    return Err(format!("vote_request frame wants 32 bytes, got {}", payload.len()));
                }
                Ok(Frame::VoteRequest {
                    term: u64_at(&payload)?,
                    candidate: u64_at(&payload[8..])?,
                    last_log_term: u64_at(&payload[16..])?,
                    last_seq: u64_at(&payload[24..])?,
                })
            }
            TY_VOTE_REPLY => {
                if payload.len() != 9 {
                    return Err(format!("vote_reply frame wants 9 bytes, got {}", payload.len()));
                }
                let granted = match payload[8] {
                    0 => false,
                    1 => true,
                    other => return Err(format!("vote_reply granted byte {other}")),
                };
                Ok(Frame::VoteReply { term: u64_at(&payload)?, granted })
            }
            TY_HEARTBEAT => {
                if payload.len() < 28 {
                    return Err(format!("heartbeat frame wants >= 28 bytes, got {}", payload.len()));
                }
                let term = u64_at(&payload)?;
                let leader = u64_at(&payload[8..])?;
                let commit = u64_at(&payload[16..])?;
                let mut at = 24usize;
                let mut addrs = Vec::with_capacity(2);
                for what in ["repl", "query"] {
                    let len_bytes = payload
                        .get(at..at + 2)
                        .ok_or_else(|| format!("heartbeat {what} addr length is torn"))?;
                    let len = u16::from_le_bytes(len_bytes.try_into().unwrap()) as usize;
                    if len > MAX_ADDR {
                        return Err(format!("heartbeat {what} addr claims {len} bytes"));
                    }
                    at += 2;
                    let bytes = payload
                        .get(at..at + len)
                        .ok_or_else(|| format!("heartbeat {what} addr is torn"))?;
                    let addr = std::str::from_utf8(bytes)
                        .map_err(|_| format!("heartbeat {what} addr is not utf-8"))?;
                    addrs.push(addr.to_string());
                    at += len;
                }
                if at != payload.len() {
                    return Err(format!(
                        "heartbeat frame has {} trailing byte(s)",
                        payload.len() - at
                    ));
                }
                let query_addr = addrs.pop().unwrap();
                let repl_addr = addrs.pop().unwrap();
                Ok(Frame::Heartbeat { term, leader, commit, repl_addr, query_addr })
            }
            TY_HEARTBEAT_ACK => {
                if payload.len() != 8 {
                    return Err(format!("heartbeat_ack frame wants 8 bytes, got {}", payload.len()));
                }
                Ok(Frame::HeartbeatAck { term: u64_at(&payload)? })
            }
            other => Err(format!("unknown frame type {other}")),
        }
    }

    /// The `(seq, op)` of an `Op` frame (`None` for other frames).
    pub fn op_record(&self) -> Option<(u64, WalOp)> {
        match self {
            Frame::Op { record } => WalOp::decode(record).ok(),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn all_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { last_seq: 7, need_snapshot: true },
            Frame::Hello { last_seq: 0, need_snapshot: false },
            Frame::Snapshot { snapshot_seq: 3, bundle: vec![0xDE, 0xAD, 0xBE, 0xEF] },
            Frame::Snapshot { snapshot_seq: 0, bundle: Vec::new() },
            Frame::op(9, &WalOp::Insert { vector: vec![1.5, -2.0] }),
            Frame::op(10, &WalOp::SetThreshold { frac: 0.25 }),
            Frame::op(11, &WalOp::Delete { key: 42 }),
            Frame::op(12, &WalOp::Compact),
            Frame::Ack { seq: 12 },
            Frame::CaughtUp { seq: 12 },
            Frame::VoteRequest { term: 3, candidate: 2, last_log_term: 2, last_seq: 17 },
            Frame::VoteReply { term: 3, granted: true },
            Frame::VoteReply { term: 4, granted: false },
            Frame::Heartbeat {
                term: 3,
                leader: 2,
                commit: 17,
                repl_addr: "127.0.0.1:7780".into(),
                query_addr: "127.0.0.1:7771".into(),
            },
            Frame::Heartbeat {
                term: 0,
                leader: 1,
                commit: 0,
                repl_addr: String::new(),
                query_addr: String::new(),
            },
            Frame::HeartbeatAck { term: 3 },
        ]
    }

    #[test]
    fn frames_roundtrip_through_a_stream() {
        let mut wire = Vec::new();
        for f in all_frames() {
            f.write_to(&mut wire).unwrap();
        }
        let mut r = Cursor::new(wire);
        for want in all_frames() {
            let got = Frame::read_from(&mut r).unwrap().unwrap();
            assert_eq!(got, want);
        }
        assert_eq!(Frame::read_from(&mut r).unwrap(), None, "clean EOF");
    }

    #[test]
    fn op_frames_expose_their_record() {
        let f = Frame::op(5, &WalOp::Delete { key: 3 });
        assert_eq!(f.op_record(), Some((5, WalOp::Delete { key: 3 })));
        assert_eq!(Frame::Ack { seq: 5 }.op_record(), None);
    }

    #[test]
    fn corruption_is_rejected_not_applied() {
        let good = Frame::Ack { seq: 9 }.encode();
        // Flip one payload bit: CRC mismatch.
        let mut flipped = good.clone();
        *flipped.last_mut().unwrap() ^= 0x40;
        assert!(Frame::read_from(&mut Cursor::new(flipped)).is_err());
        // Flip the type byte: CRC covers it too.
        let mut retyped = good.clone();
        retyped[8] = TY_CAUGHT_UP;
        assert!(Frame::read_from(&mut Cursor::new(retyped)).is_err());
        // Torn header and torn payload.
        assert!(Frame::read_from(&mut Cursor::new(good[..4].to_vec())).is_err());
        assert!(Frame::read_from(&mut Cursor::new(good[..HEADER_SIZE + 2].to_vec())).is_err());
        // Unknown type with a valid CRC.
        let mut unknown = Frame::Ack { seq: 9 }.payload();
        let mut crc_buf = vec![99u8];
        crc_buf.extend_from_slice(&unknown);
        let mut wire = crc32(&crc_buf).to_le_bytes().to_vec();
        wire.extend_from_slice(&(unknown.len() as u32).to_le_bytes());
        wire.push(99);
        wire.append(&mut unknown);
        assert!(Frame::read_from(&mut Cursor::new(wire)).is_err());
        // Absurd length: rejected before allocating.
        let mut huge = good;
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::read_from(&mut Cursor::new(huge)).is_err());
    }

    /// Types 1–5 keep their v1 encoding byte for byte: the election
    /// frames are additive, so a v1 peer stream still parses.
    #[test]
    fn legacy_frame_bytes_are_untouched() {
        let hello = Frame::Hello { last_seq: 7, need_snapshot: true }.encode();
        assert_eq!(hello[8], TY_HELLO);
        assert_eq!(&hello[9..17], &7u64.to_le_bytes());
        assert_eq!(hello[17], 1);
        assert_eq!(hello.len(), HEADER_SIZE + 9);
        let ack = Frame::Ack { seq: 12 }.encode();
        assert_eq!(ack[8], TY_ACK);
        assert_eq!(&ack[9..17], &12u64.to_le_bytes());
    }

    #[test]
    fn malformed_election_payloads_are_rejected() {
        // A heartbeat whose addr length field overruns the payload.
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.extend_from_slice(&2u64.to_le_bytes());
        payload.extend_from_slice(&3u64.to_le_bytes());
        payload.extend_from_slice(&200u16.to_le_bytes()); // claims 200 bytes, has 2
        payload.extend_from_slice(b"hi");
        assert!(Frame::decode_payload(TY_HEARTBEAT, payload).is_err());
        // Truncated vote request.
        assert!(Frame::decode_payload(TY_VOTE_REQUEST, vec![0u8; 24]).is_err());
        // Vote reply with a non-boolean granted byte.
        let mut reply = 5u64.to_le_bytes().to_vec();
        reply.push(2);
        assert!(Frame::decode_payload(TY_VOTE_REPLY, reply).is_err());
        // Heartbeat with trailing garbage after both addrs.
        let mut hb = Frame::Heartbeat {
            term: 1,
            leader: 2,
            commit: 3,
            repl_addr: "a".into(),
            query_addr: "b".into(),
        }
        .payload();
        hb.push(0);
        assert!(Frame::decode_payload(TY_HEARTBEAT, hb).is_err());
    }

    #[test]
    fn malformed_op_payloads_fail_at_decode_time() {
        // An op frame whose record is garbage must be rejected by the
        // frame layer (valid CRC, invalid logical payload).
        let record = vec![0u8; 9]; // seq 0, op byte 0 = unknown
        let mut crc_buf = vec![TY_OP];
        crc_buf.extend_from_slice(&record);
        let mut wire = crc32(&crc_buf).to_le_bytes().to_vec();
        wire.extend_from_slice(&(record.len() as u32).to_le_bytes());
        wire.push(TY_OP);
        wire.extend_from_slice(&record);
        assert!(Frame::read_from(&mut Cursor::new(wire)).is_err());
    }
}
