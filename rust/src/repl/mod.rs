//! Quorum replication for the serving plane.
//!
//! The whole subsystem rides on two guarantees the earlier layers
//! already prove:
//!
//! 1. **Determinism** (PR 5): applying the same mutation sequence to the
//!    same starting state produces byte-identical persisted bundles, for
//!    every mutable index family.
//! 2. **A total mutation order** (PR 6): the WAL assigns each applied op
//!    a contiguous sequence number under the index write lock.
//!
//! Given those, replication is just shipping the ordered op stream: the
//! leader streams WAL records to N replicas ([`hub::ReplHub`]), each
//! replica applies them through the same `MutableAnnIndex` verbs
//! ([`replica::Replica`]), and byte-level state equality falls out —
//! checkable at runtime by comparing [`bundle_fingerprint`]s, and
//! checked exhaustively (restarts, fault injection, SIGKILL, leader
//! kills, partitions) by `rust/tests/repl_props.rs` and
//! `rust/tests/failover_props.rs`.
//!
//! Who the leader *is* comes from [`election`]: term-numbered randomized
//! elections with a log-matching vote check, Raft-style. The
//! [`cluster::ClusterNode`] supervisor converges each node's wiring
//! (hub vs replica) onto its elected role, so failover needs no
//! operator.
//!
//! Wire format: [`frame::Frame`] — the same length-prefixed CRC-checked
//! framing discipline as the on-disk log, with `Op` payloads literally
//! being [`crate::wal::WalOp::encode`] bytes, extended with the election
//! frames (vote request/reply, heartbeat, heartbeat ack).

pub mod cluster;
pub mod election;
pub mod frame;
pub mod hub;
pub mod replica;

use std::net::SocketAddr;

use crate::core::json::Json;
use crate::index::AnnIndex;
use crate::router::protocol::{QueryRequest, QueryResponse, Request};
use crate::router::server::Client;

/// How much of the cluster must hold a mutation durably before the
/// client is acked. `None` = fire-and-forget (replicas converge
/// asynchronously); `One` = at least one replica has applied and
/// durably logged the op; `All` = every expected replica has; `Quorum`
/// = a majority of the cluster counting the leader itself — the default
/// for multi-node clusters, and the level that makes acked ops survive
/// any minority of failures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AckLevel {
    None,
    One,
    All,
    Quorum,
}

impl AckLevel {
    pub fn parse(s: &str) -> Result<AckLevel, String> {
        match s {
            "none" => Ok(AckLevel::None),
            "one" => Ok(AckLevel::One),
            "all" => Ok(AckLevel::All),
            "quorum" => Ok(AckLevel::Quorum),
            other => Err(format!("unknown ack level '{other}' (none|one|all|quorum)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AckLevel::None => "none",
            AckLevel::One => "one",
            AckLevel::All => "all",
            AckLevel::Quorum => "quorum",
        }
    }
}

/// FNV-1a 64-bit. Tiny, dependency-free, and stable across platforms —
/// exactly what a divergence check needs (this is an integrity
/// fingerprint, not a cryptographic one).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of the live index: hash of its persisted-bundle bytes.
/// Because persistence is deterministic, two nodes that applied the same
/// op sequence return the same value — the `FINGERPRINT` verb and
/// `repl fingerprint` CLI compare these across the topology.
pub fn bundle_fingerprint(index: &dyn AnnIndex) -> std::io::Result<u64> {
    Ok(fnv1a64(&crate::data::persist::bundle_to_vec(index)?))
}

/// Splice a `min_seq` session token into an already-encoded query line
/// (additive field; replicas without session support ignore it).
fn with_min_seq(line: &str, seq: u64) -> String {
    match line.rfind('}') {
        Some(pos) => format!("{}, \"min_seq\": {}{}", &line[..pos], seq, &line[pos..]),
        None => line.to_string(),
    }
}

/// Round-robin read fan-out over a replica set: queries rotate across
/// the addresses and fail over to the next on connection error — the
/// read-scaling half of the replication plane. Connections are
/// per-call; this is a CLI/test convenience, not a pooled client.
///
/// Read-your-writes: after a write, feed the leader's `(term, seq)` ack
/// into [`ReadPool::note_write`]; subsequent queries carry the seq as a
/// `min_seq` session token and a replica still behind it answers a
/// structured stale-replica error, which this pool treats like any
/// other failure — it tries the next node.
pub struct ReadPool {
    addrs: Vec<SocketAddr>,
    next: usize,
    /// Highest `(term, seq)` this session has written.
    session: Option<(u64, u64)>,
}

impl ReadPool {
    pub fn new(addrs: Vec<SocketAddr>) -> ReadPool {
        ReadPool { addrs, next: 0, session: None }
    }

    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// Record a write acknowledged at `(term, seq)`; later queries in
    /// this session only accept replicas at-or-after `seq`.
    pub fn note_write(&mut self, term: u64, seq: u64) {
        let newer = match self.session {
            None => true,
            Some((t, s)) => (term, seq) > (t, s),
        };
        if newer {
            self.session = Some((term, seq));
        }
    }

    /// The session's read-your-writes token, if any write happened.
    pub fn session(&self) -> Option<(u64, u64)> {
        self.session
    }

    /// Ask every node for its replication status until one names the
    /// leader's query address (its own, when asked of the leader).
    /// Works against any node — followers relay what heartbeats told
    /// them.
    pub fn discover_leader(&self) -> Option<String> {
        for addr in &self.addrs {
            let Ok(mut c) = Client::connect(addr) else { continue };
            let Ok(line) = c.send_raw(&Request::ReplStatus { id: 0 }.to_json_line()) else {
                continue;
            };
            let Ok(v) = Json::parse(line.trim()) else { continue };
            if v.get("role").and_then(|r| r.as_str()) == Some("leader") {
                return Some(addr.to_string());
            }
            if let Some(lq) = v.get("leader_query").and_then(|x| x.as_str()) {
                if !lq.is_empty() {
                    return Some(lq.to_string());
                }
            }
        }
        None
    }

    /// Query the next node in rotation; on failure (connect error, or a
    /// stale replica rejecting the session token) try the rest in order.
    /// Returns the answering node alongside the response.
    pub fn query(&mut self, req: &QueryRequest) -> Result<(SocketAddr, QueryResponse), String> {
        if self.addrs.is_empty() {
            return Err("read pool has no addresses".into());
        }
        let frame = match self.session {
            Some((_, seq)) if seq > 0 => with_min_seq(&req.to_json_line(), seq),
            _ => req.to_json_line(),
        };
        let n = self.addrs.len();
        let mut last_err = String::new();
        for i in 0..n {
            let addr = self.addrs[(self.next + i) % n];
            match Client::connect(&addr).map_err(|e| e.to_string()) {
                Ok(mut c) => match c.send_raw(&frame) {
                    Ok(line) => match QueryResponse::parse(line.trim()) {
                        Ok(resp) => {
                            self.next = (self.next + i + 1) % n;
                            return Ok((addr, resp));
                        }
                        Err(e) => last_err = format!("{addr}: {e}"),
                    },
                    Err(e) => last_err = format!("{addr}: {e}"),
                },
                Err(e) => last_err = format!("{addr}: {e}"),
            }
        }
        Err(format!("all {n} node(s) failed, last: {last_err}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ack_levels_parse_and_name() {
        for (s, l) in [
            ("none", AckLevel::None),
            ("one", AckLevel::One),
            ("all", AckLevel::All),
            ("quorum", AckLevel::Quorum),
        ] {
            assert_eq!(AckLevel::parse(s), Ok(l));
            assert_eq!(l.name(), s);
        }
        assert!(AckLevel::parse("two").is_err());
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fingerprint_is_deterministic_and_state_sensitive() {
        use crate::core::matrix::Matrix;
        use crate::index::impls::BruteForce;
        use crate::index::SearchContext;
        use std::sync::Arc;
        let mut m = Matrix::zeros(0, 2);
        m.push_row(&[1.0, 2.0]);
        m.push_row(&[3.0, 4.0]);
        let mut a: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(m.clone())));
        let b: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(m)));
        let fa = bundle_fingerprint(a.as_ref()).unwrap();
        assert_eq!(fa, bundle_fingerprint(b.as_ref()).unwrap(), "same state, same print");
        let mut ctx = SearchContext::new();
        a.as_mutable().unwrap().insert(&[5.0, 6.0], &mut ctx).unwrap();
        assert_ne!(fa, bundle_fingerprint(a.as_ref()).unwrap(), "mutation moves the print");
    }

    #[test]
    fn session_tokens_splice_into_query_lines_and_order_lexicographically() {
        let mut pool = ReadPool::new(vec![]);
        assert_eq!(pool.session(), None);
        pool.note_write(2, 10);
        pool.note_write(2, 7); // older seq, same term: ignored
        assert_eq!(pool.session(), Some((2, 10)));
        pool.note_write(3, 1); // newer term wins even at a lower seq
        assert_eq!(pool.session(), Some((3, 1)));

        let req = QueryRequest { id: 1, vector: vec![1.0, 2.0], k: 3 };
        let line = with_min_seq(&req.to_json_line(), 10);
        assert!(line.contains("\"min_seq\": 10"), "spliced: {line}");
        // Still a valid query frame with the original fields intact.
        let back = crate::router::protocol::QueryRequest::parse(&line).unwrap();
        assert_eq!(back, req);
        assert_eq!(crate::router::protocol::session_min_seq(&line), Some(10));
    }
}
