//! Primary/backup replication for the serving plane.
//!
//! The whole subsystem rides on two guarantees the earlier layers
//! already prove:
//!
//! 1. **Determinism** (PR 5): applying the same mutation sequence to the
//!    same starting state produces byte-identical persisted bundles, for
//!    every mutable index family.
//! 2. **A total mutation order** (PR 6): the WAL assigns each applied op
//!    a contiguous sequence number under the index write lock.
//!
//! Given those, replication is just shipping the ordered op stream: the
//! primary streams WAL records to N replicas ([`hub::ReplHub`]), each
//! replica applies them through the same `MutableAnnIndex` verbs
//! ([`replica::Replica`]), and byte-level state equality falls out —
//! checkable at runtime by comparing [`bundle_fingerprint`]s, and
//! checked exhaustively (restarts, fault injection, SIGKILL) by
//! `rust/tests/repl_props.rs`.
//!
//! Wire format: [`frame::Frame`] — the same length-prefixed CRC-checked
//! framing discipline as the on-disk log, with `Op` payloads literally
//! being [`crate::wal::WalOp::encode`] bytes.

pub mod frame;
pub mod hub;
pub mod replica;

use std::net::SocketAddr;

use crate::index::AnnIndex;
use crate::router::protocol::{QueryRequest, QueryResponse};
use crate::router::server::Client;

/// How many replica acknowledgements a mutation waits for before the
/// client is acked. `None` = fire-and-forget (replicas converge
/// asynchronously); `One` = at least one replica has applied and
/// durably logged the op; `All` = every expected replica has.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AckLevel {
    None,
    One,
    All,
}

impl AckLevel {
    pub fn parse(s: &str) -> Result<AckLevel, String> {
        match s {
            "none" => Ok(AckLevel::None),
            "one" => Ok(AckLevel::One),
            "all" => Ok(AckLevel::All),
            other => Err(format!("unknown ack level '{other}' (none|one|all)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AckLevel::None => "none",
            AckLevel::One => "one",
            AckLevel::All => "all",
        }
    }
}

/// FNV-1a 64-bit. Tiny, dependency-free, and stable across platforms —
/// exactly what a divergence check needs (this is an integrity
/// fingerprint, not a cryptographic one).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of the live index: hash of its persisted-bundle bytes.
/// Because persistence is deterministic, two nodes that applied the same
/// op sequence return the same value — the `FINGERPRINT` verb and
/// `repl fingerprint` CLI compare these across the topology.
pub fn bundle_fingerprint(index: &dyn AnnIndex) -> std::io::Result<u64> {
    Ok(fnv1a64(&crate::data::persist::bundle_to_vec(index)?))
}

/// Round-robin read fan-out over a replica set: queries rotate across
/// the addresses and fail over to the next on connection error — the
/// read-scaling half of primary/backup replication. Connections are
/// per-call; this is a CLI/test convenience, not a pooled client.
pub struct ReadPool {
    addrs: Vec<SocketAddr>,
    next: usize,
}

impl ReadPool {
    pub fn new(addrs: Vec<SocketAddr>) -> ReadPool {
        ReadPool { addrs, next: 0 }
    }

    pub fn addrs(&self) -> &[SocketAddr] {
        &self.addrs
    }

    /// Query the next node in rotation; on failure try the rest in order.
    /// Returns the answering node alongside the response.
    pub fn query(&mut self, req: &QueryRequest) -> Result<(SocketAddr, QueryResponse), String> {
        if self.addrs.is_empty() {
            return Err("read pool has no addresses".into());
        }
        let n = self.addrs.len();
        let mut last_err = String::new();
        for i in 0..n {
            let addr = self.addrs[(self.next + i) % n];
            match Client::connect(&addr).map_err(|e| e.to_string()) {
                Ok(mut c) => match c.query(req) {
                    Ok(resp) => {
                        self.next = (self.next + i + 1) % n;
                        return Ok((addr, resp));
                    }
                    Err(e) => last_err = format!("{addr}: {e}"),
                },
                Err(e) => last_err = format!("{addr}: {e}"),
            }
        }
        Err(format!("all {n} node(s) failed, last: {last_err}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ack_levels_parse_and_name() {
        for (s, l) in [("none", AckLevel::None), ("one", AckLevel::One), ("all", AckLevel::All)] {
            assert_eq!(AckLevel::parse(s), Ok(l));
            assert_eq!(l.name(), s);
        }
        assert!(AckLevel::parse("two").is_err());
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fingerprint_is_deterministic_and_state_sensitive() {
        use crate::core::matrix::Matrix;
        use crate::index::impls::BruteForce;
        use crate::index::SearchContext;
        use std::sync::Arc;
        let mut m = Matrix::zeros(0, 2);
        m.push_row(&[1.0, 2.0]);
        m.push_row(&[3.0, 4.0]);
        let mut a: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(m.clone())));
        let b: Box<dyn AnnIndex> = Box::new(BruteForce::new(Arc::new(m)));
        let fa = bundle_fingerprint(a.as_ref()).unwrap();
        assert_eq!(fa, bundle_fingerprint(b.as_ref()).unwrap(), "same state, same print");
        let mut ctx = SearchContext::new();
        a.as_mutable().unwrap().insert(&[5.0, 6.0], &mut ctx).unwrap();
        assert_ne!(fa, bundle_fingerprint(a.as_ref()).unwrap(), "mutation moves the print");
    }
}
