//! Cluster-node supervisor: glues the election state machine to the
//! replication plane so a node flips between leader and follower roles
//! without operator intervention.
//!
//! One [`ClusterNode`] per process. It owns the node's replication
//! listener — bound once at startup, so the address a node advertises in
//! heartbeats survives every role flip — and a small reconciliation loop
//! that polls the [`ElectionNode`] every ~20ms and converges the local
//! wiring onto the elected role:
//!
//! * **Leader**: construct a listener-less [`ReplHub`] over the shared
//!   WAL, attach it to the [`ServeIndex`] (mutations start publishing +
//!   quorum-gating), and route accepted replication sockets into it.
//! * **Follower**: tear the hub down (stale-term ops then fail the
//!   role check, not replicate), and run a [`Replica`] against the
//!   leader's advertised replication address in shared-WAL mode. Every
//!   new `(leader, term)` forces a full snapshot on first contact: a
//!   deposed leader may carry an uncommitted divergent tail, and the
//!   snapshot install ([`Wal::reinstall_into`]) wipes it byte-exactly.
//! * **Candidate / no leader**: neither; reads keep serving from the
//!   installed state, writes fail fast with a structured `no-quorum`
//!   error via [`ClusterNode::check_writable`].
//!
//! The supervisor also feeds the election its inputs: as leader it
//! notes its log position under its own term each tick (plus the
//! applied watermark via `note_commit`); as follower the running
//! replica's apply hook advances the position, labeled with the term
//! whose stream the data actually came from. The label must never get
//! ahead of the log's content: tagging a merely *heard* leader's term
//! onto a not-yet-wiped divergent tail would let a healed deposed
//! leader advertise `(new_term, inflated_seq)` and outvote honest
//! nodes holding quorum-committed data.

use std::io;
use std::net::{SocketAddr, TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::repl::election::{ElectionNode, LeaderInfo, Role};
use crate::repl::hub::{HubOpts, ReplHub};
use crate::repl::replica::{ReplMetrics, Replica, ReplicaOpts, ReplicaStore};
use crate::router::server::ServeIndex;
use crate::wal::{FsyncPolicy, Wal};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

const RECONCILE_TICK: Duration = Duration::from_millis(20);

/// Cluster-node tuning (everything the reconciler needs beyond the
/// election itself).
#[derive(Clone, Debug)]
pub struct ClusterOpts {
    /// Hub options applied whenever this node leads (level `quorum`,
    /// `expect` = cluster size for multi-node clusters).
    pub hub: HubOpts,
    /// Fsync policy for follower-side appends.
    pub policy: FsyncPolicy,
    /// Replication address advertised in heartbeats — what followers
    /// dial. Usually the repl listener's own address; tests point it at
    /// a fault proxy.
    pub repl_advertise: String,
    /// Query address advertised in heartbeats — where clients should
    /// send writes when this node leads.
    pub query_advertise: String,
    /// Seed for the follower reconnect-backoff jitter.
    pub seed: u64,
}

/// Role-dependent wiring owned by the reconciler.
struct Active {
    hub: Option<Arc<ReplHub>>,
    replica: Option<Replica>,
    /// `(leader id, term)` the running replica follows.
    following: Option<(u64, u64)>,
    /// Metrics handle of the most recent follower stream (kept after a
    /// promotion so REPL_STATUS history survives the flip).
    metrics: Option<Arc<ReplMetrics>>,
}

/// See the module docs. Construct with [`ClusterNode::start`]; store on
/// the [`ServeIndex`] via `set_cluster` so mutations consult
/// [`ClusterNode::check_writable`].
pub struct ClusterNode {
    election: ElectionNode,
    serve: Arc<ServeIndex>,
    wal: Arc<Wal>,
    opts: ClusterOpts,
    repl_local: SocketAddr,
    active: Mutex<Active>,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

fn resolve(addr: &str) -> Option<SocketAddr> {
    addr.parse().ok().or_else(|| addr.to_socket_addrs().ok().and_then(|mut it| it.next()))
}

impl ClusterNode {
    /// Start supervising. `election` must already be running;
    /// `repl_listener` is the node's bound replication port (stable for
    /// the process lifetime). The serve index should already hold the
    /// recovered local state.
    pub fn start(
        election: ElectionNode,
        repl_listener: TcpListener,
        wal: Arc<Wal>,
        serve: Arc<ServeIndex>,
        opts: ClusterOpts,
    ) -> io::Result<Arc<ClusterNode>> {
        let repl_local = repl_listener.local_addr()?;
        repl_listener.set_nonblocking(true)?;
        election.set_advert(&opts.repl_advertise, &opts.query_advertise);
        // Seed the election's log position from recovered state without
        // clobbering the persisted term label.
        election.note_log(election.last_log_term(), serve.applied_seq());

        let node = Arc::new(ClusterNode {
            election,
            serve,
            wal,
            opts,
            repl_local,
            active: Mutex::new(Active {
                hub: None,
                replica: None,
                following: None,
                metrics: None,
            }),
            stop: Arc::new(AtomicBool::new(false)),
            threads: Mutex::new(Vec::new()),
        });

        let accept = {
            let node = Arc::clone(&node);
            std::thread::Builder::new().name("finger-cluster-accept".into()).spawn(move || {
                loop {
                    if node.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match repl_listener.accept() {
                        Ok((stream, _)) => {
                            // Route to the active hub; a non-leader has
                            // nothing to stream, so the socket drops and
                            // the dialer backs off and retries (by then
                            // the heartbeats point it elsewhere).
                            let hub = lock(&node.active).hub.clone();
                            match hub {
                                Some(h) => h.attach(stream),
                                None => drop(stream),
                            }
                        }
                        Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?
        };
        let reconcile = {
            let node = Arc::clone(&node);
            std::thread::Builder::new()
                .name("finger-cluster-reconcile".into())
                .spawn(move || node.reconcile_loop())?
        };
        lock(&node.threads).extend([accept, reconcile]);
        Ok(node)
    }

    fn reconcile_loop(&self) {
        while !self.stop.load(Ordering::Relaxed) {
            self.reconcile_once();
            std::thread::sleep(RECONCILE_TICK);
        }
    }

    /// One convergence step: make the local wiring match the elected
    /// role. Idempotent; cheap when nothing changed.
    fn reconcile_once(&self) {
        // One atomic snapshot: reading role and term piecemeal could
        // pair `Leader` with a term this node was already deposed from.
        let (role, term, leader) = self.election.view();
        let is_leader = role == Role::Leader;
        let mut act = lock(&self.active);

        if is_leader {
            if let Some(r) = act.replica.take() {
                r.stop();
            }
            act.following = None;
            if act.hub.is_none() {
                let hub =
                    ReplHub::new(Arc::clone(&self.wal), self.opts.hub.clone(), self.repl_local);
                self.serve.set_hub(Some(Arc::clone(&hub)));
                act.hub = Some(hub);
                // A node only wins with the longest durable log, so its
                // state is as fresh as the cluster has: serve it.
                self.serve.set_ready();
            }
            self.election.note_commit(self.serve.applied_seq());
        } else {
            if let Some(h) = act.hub.take() {
                self.serve.set_hub(None);
                h.shutdown();
            }
            if let Some(info) = leader.as_ref().filter(|l| l.id != self.election.id()) {
                let key = (info.id, info.term);
                if act.following != Some(key) {
                    if let Some(r) = act.replica.take() {
                        r.stop();
                    }
                    if let Some(addr) = resolve(&info.repl_addr) {
                        // Label the election log position with this
                        // leadership's term only as the stream actually
                        // lands locally — the forced snapshot below has
                        // wiped any divergent tail by the time the hook
                        // first fires, so `(term, seq)` never overstates
                        // what this node's log really holds.
                        let hook_election = self.election.clone();
                        let hook_term = info.term;
                        let ropts = ReplicaOpts {
                            store: ReplicaStore::Shared(Arc::clone(&self.wal)),
                            policy: self.opts.policy,
                            seed: self.opts.seed,
                            // A new (leader, term) means our tail may be
                            // divergent; never trust it.
                            force_snapshot: true,
                            on_apply: Some(Arc::new(move |seq| {
                                hook_election.note_log(hook_term, seq);
                            })),
                            ..ReplicaOpts::default()
                        };
                        if let Ok(r) = Replica::start(addr, Arc::clone(&self.serve), ropts) {
                            act.metrics = Some(r.metrics());
                            act.replica = Some(r);
                            act.following = Some(key);
                        }
                    }
                }
            }
            // No known leader: keep any running replica dialing its last
            // target — if that leader returns it resumes, and a new
            // leader's heartbeat re-keys `following` above.
        }
        drop(act);

        // As leader, note the log position under our own term each tick
        // (winning required a quorum to judge this log at least as
        // up-to-date, so the label is honest). As follower the replica's
        // apply hook advances it instead — merely *hearing* a leader's
        // heartbeat must not relabel a possibly-divergent local tail
        // with the new term. With no leader in sight the label holds
        // (the log does not advance either).
        if is_leader {
            self.election.note_log(term, self.serve.applied_seq());
        }
    }

    pub fn id(&self) -> u64 {
        self.election.id()
    }

    pub fn role(&self) -> Role {
        self.election.role()
    }

    pub fn term(&self) -> u64 {
        self.election.term()
    }

    pub fn leader(&self) -> Option<LeaderInfo> {
        self.election.leader()
    }

    /// The election handle (tests use it for partition injection).
    pub fn election(&self) -> &ElectionNode {
        &self.election
    }

    /// This node's bound replication address.
    pub fn repl_addr(&self) -> SocketAddr {
        self.repl_local
    }

    /// Follower-stream counters (present once this node has followed).
    pub fn replica_metrics(&self) -> Option<Arc<ReplMetrics>> {
        lock(&self.active).metrics.clone()
    }

    /// Gate for mutation verbs: only the elected leader takes writes.
    /// The error is structured — followers point at the leader's query
    /// address so clients can redirect, and a leaderless cluster reports
    /// `no-quorum` instead of hanging.
    pub fn check_writable(&self) -> Result<(), String> {
        if self.election.is_leader() {
            return Ok(());
        }
        match self.election.leader() {
            Some(l) => Err(format!(
                "not the leader (term {}); leader is at {}",
                l.term, l.query_addr
            )),
            None => Err(format!(
                "no-quorum: no leader elected (term {}); writes unavailable, reads still serve",
                self.election.term()
            )),
        }
    }

    /// Stop the reconciler, the election, and whatever role wiring is
    /// live. Safe to call more than once.
    pub fn shutdown(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        self.election.shutdown();
        {
            let mut act = lock(&self.active);
            if let Some(r) = act.replica.take() {
                r.stop();
            }
            if let Some(h) = act.hub.take() {
                self.serve.set_hub(None);
                h.shutdown();
            }
        }
        for t in lock(&self.threads).drain(..) {
            let _ = t.join();
        }
    }
}
