//! Term-numbered leader election over the replication wire frames.
//!
//! One `ElectionNode` per cluster member. The state machine is the
//! Raft election core, stripped to what the replication plane needs:
//!
//! * **Roles.** Every node is a follower until its randomized election
//!   timeout fires without hearing a leader heartbeat; it then bumps
//!   the term, votes for itself, and campaigns. A majority of granted
//!   votes makes it leader; a higher term observed anywhere (vote,
//!   heartbeat, or ack) demotes it back to follower immediately — the
//!   term is the fence.
//! * **Log matching.** A vote request carries the candidate's
//!   `(last_log_term, last_seq)` position and a peer grants only when
//!   that pair is lexicographically at least its own. A quorum-acked op
//!   is durable on a majority, so every majority overlaps a holder of
//!   it: a node missing committed ops can never assemble a majority.
//!   (Comparing `last_seq` alone would be unsafe — a deposed leader's
//!   long uncommitted tail could outvote a survivor holding committed
//!   entries from a newer term.)
//! * **Persistence.** `(term, voted_for, last_log_term)` live in a
//!   CRC-checked `election.state` file (tmp + rename + fsync), so a
//!   restarted node can never vote twice in one term or regress its
//!   term — the two invariants that make majorities mean anything.
//! * **Seeding.** The log *position* (`last_seq`) is in-memory only:
//!   the serving layer feeds it via [`ElectionNode::note_log`] after
//!   recovering the WAL. Until that first call a node neither grants
//!   votes nor campaigns — a restarted node reporting a zero position
//!   could otherwise hand its vote to a candidate missing committed
//!   ops, breaking the quorum-overlap argument above.
//! * **Transport.** Short-lived TCP connections carrying exactly one
//!   request/response frame pair (`VoteRequest`/`VoteReply`,
//!   `Heartbeat`/`HeartbeatAck`) — no long-lived session state, so a
//!   partition heals the moment connects succeed again. Heartbeats
//!   advertise the leader's replication and query addresses; followers
//!   discover where to stream from without out-of-band config. Sends
//!   go through one long-lived thread per peer holding a latest-wins
//!   mailbox: a slow or partitioned peer blocks only its own thread
//!   (stale heartbeats are superseded, never queued), instead of
//!   accumulating a fresh blocked thread per tick.
//!
//! The `set_partitioned` test seam freezes a node completely (no sends,
//! incoming frames dropped without reply) to simulate a network
//! partition around a node that still believes it is leader.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::core::rng::Pcg32;
use crate::repl::frame::Frame;
use crate::wal::record::crc32;

/// Where a node currently stands in the election state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

impl Role {
    pub fn name(&self) -> &'static str {
        match self {
            Role::Follower => "follower",
            Role::Candidate => "candidate",
            Role::Leader => "leader",
        }
    }
}

/// One peer's identity and election endpoint (everything but self).
#[derive(Clone, Debug)]
pub struct PeerSpec {
    pub id: u64,
    pub addr: String,
}

/// Static election configuration for one node.
#[derive(Clone)]
pub struct ElectionConfig {
    /// This node's id. Must be nonzero (0 encodes "voted for nobody").
    pub id: u64,
    /// Election listener bind address (e.g. `127.0.0.1:0`).
    pub listen: String,
    /// Every other cluster member's election endpoint.
    pub peers: Vec<PeerSpec>,
    /// Base election timeout; the live timeout is randomized in
    /// `[base, 2*base)` and re-drawn per campaign so ties break.
    pub election_timeout: Duration,
    /// Leader heartbeat period (keep well under `election_timeout`).
    pub heartbeat_interval: Duration,
    /// Directory for the persisted `election.state` file (`None` keeps
    /// state in memory only — tests, or callers without durability).
    pub state_dir: Option<PathBuf>,
    /// Seed for the timeout jitter (deterministic per node).
    pub seed: u64,
}

/// What a node knows about the current leader.
#[derive(Clone, Debug, PartialEq)]
pub struct LeaderInfo {
    pub id: u64,
    pub term: u64,
    pub repl_addr: String,
    pub query_addr: String,
}

struct ElState {
    term: u64,
    /// Who this node voted for in `term` (0 = nobody yet).
    voted_for: u64,
    role: Role,
    leader: Option<LeaderInfo>,
    /// Last heartbeat (or granted vote) observed; the election clock.
    last_heartbeat: Instant,
    /// Last heartbeat broadcast sent (leader only).
    last_broadcast: Instant,
    /// Live randomized election timeout.
    timeout: Duration,
    /// Votes gathered in the current candidacy (self included).
    votes: usize,
    rng: Pcg32,
}

/// One peer's outbound lane: a latest-wins mailbox drained by a
/// dedicated sender thread. Heartbeats and vote requests supersede
/// whatever is still pending — a peer that blocks for the full
/// connect+reply timeout simply misses the superseded frames.
struct PeerLink {
    addr: SocketAddr,
    pending: Mutex<Option<Frame>>,
    cv: Condvar,
}

struct Inner {
    cfg: ElectionConfig,
    peers: Vec<Arc<PeerLink>>,
    local_addr: SocketAddr,
    state: Mutex<ElState>,
    /// Advertised (repl_addr, query_addr) carried in heartbeats.
    advert: Mutex<(String, String)>,
    /// This node's log position, fed by the serving layer via
    /// [`ElectionNode::note_log`]; read by the vote handlers.
    last_log_term: AtomicU64,
    last_seq: AtomicU64,
    /// Flips on the first `note_log`: until then the position above is
    /// a placeholder and the node must not grant votes or campaign.
    log_seeded: AtomicBool,
    /// Commit watermark advertised when leader / last heard from one.
    commit: AtomicU64,
    partitioned: AtomicBool,
    stop: AtomicBool,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
    tick_thread: Mutex<Option<JoinHandle<()>>>,
    peer_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// A running election participant. Cheap to clone (shared inner).
#[derive(Clone)]
pub struct ElectionNode {
    inner: Arc<Inner>,
}

const STATE_FILE: &str = "election.state";
const STATE_MAGIC: &[u8; 4] = b"ELS1";
const CONNECT_TIMEOUT: Duration = Duration::from_millis(250);
const REPLY_TIMEOUT: Duration = Duration::from_millis(500);
const TICK: Duration = Duration::from_millis(10);

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn encode_state(term: u64, voted_for: u64, last_log_term: u64) -> Vec<u8> {
    let mut b = Vec::with_capacity(32);
    b.extend_from_slice(STATE_MAGIC);
    b.extend_from_slice(&term.to_le_bytes());
    b.extend_from_slice(&voted_for.to_le_bytes());
    b.extend_from_slice(&last_log_term.to_le_bytes());
    b.extend_from_slice(&crc32(&b[..28]).to_le_bytes());
    b
}

fn decode_state(bytes: &[u8]) -> Option<(u64, u64, u64)> {
    if bytes.len() != 32 || &bytes[..4] != STATE_MAGIC {
        return None;
    }
    let crc = u32::from_le_bytes(bytes[28..32].try_into().unwrap());
    if crc32(&bytes[..28]) != crc {
        return None;
    }
    let at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    Some((at(4), at(12), at(20)))
}

fn load_state(dir: &std::path::Path) -> Option<(u64, u64, u64)> {
    decode_state(&std::fs::read(dir.join(STATE_FILE)).ok()?)
}

/// Durable before it matters: a node that voted (or bumped its term)
/// must still know after a crash, or one term could mint two leaders.
fn write_state(dir: &std::path::Path, bytes: &[u8]) -> io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!("{STATE_FILE}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        io::Write::write_all(&mut f, bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, dir.join(STATE_FILE))?;
    crate::data::persist::sync_dir(dir);
    Ok(())
}

fn persist_locked(inner: &Inner, st: &ElState) {
    let Some(dir) = &inner.cfg.state_dir else { return };
    let bytes = encode_state(st.term, st.voted_for, inner.last_log_term.load(Ordering::SeqCst));
    if let Err(e) = write_state(dir, &bytes) {
        eprintln!("election[{}]: state persist failed: {e}", inner.cfg.id);
    }
}

fn draw_timeout(rng: &mut Pcg32, base: Duration) -> Duration {
    let ms = base.as_millis().max(1) as usize;
    base + Duration::from_millis(rng.gen_range(ms) as u64)
}

impl ElectionNode {
    /// Bind `cfg.listen` and start the node.
    pub fn start(cfg: ElectionConfig) -> io::Result<ElectionNode> {
        let listener = TcpListener::bind(&cfg.listen)?;
        Self::start_on(cfg, listener)
    }

    /// Start on a pre-bound listener (tests reserve port-0 addresses up
    /// front so every node can name its peers before any node runs).
    pub fn start_on(cfg: ElectionConfig, listener: TcpListener) -> io::Result<ElectionNode> {
        if cfg.id == 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "node id 0 is reserved"));
        }
        let mut peers = Vec::with_capacity(cfg.peers.len());
        for p in &cfg.peers {
            let addr: SocketAddr = p.addr.parse().map_err(|_| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("bad peer addr '{}' for node {}", p.addr, p.id),
                )
            })?;
            peers.push(Arc::new(PeerLink {
                addr,
                pending: Mutex::new(None),
                cv: Condvar::new(),
            }));
        }
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let (term, voted_for, last_log_term) = cfg
            .state_dir
            .as_deref()
            .and_then(load_state)
            .unwrap_or((0, 0, 0));
        let mut rng = Pcg32::new(cfg.seed ^ cfg.id.wrapping_mul(0x9E3779B97F4A7C15));
        let timeout = draw_timeout(&mut rng, cfg.election_timeout);
        let now = Instant::now();
        let inner = Arc::new(Inner {
            peers,
            local_addr,
            state: Mutex::new(ElState {
                term,
                voted_for,
                role: Role::Follower,
                leader: None,
                last_heartbeat: now,
                last_broadcast: now,
                timeout,
                votes: 0,
                rng,
            }),
            advert: Mutex::new((String::new(), String::new())),
            last_log_term: AtomicU64::new(last_log_term),
            last_seq: AtomicU64::new(0),
            log_seeded: AtomicBool::new(false),
            commit: AtomicU64::new(0),
            partitioned: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            accept_thread: Mutex::new(None),
            tick_thread: Mutex::new(None),
            peer_threads: Mutex::new(Vec::new()),
            cfg,
        });

        let acc = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("finger-election-accept".into())
            .spawn(move || accept_loop(&acc, listener))?;
        *lock(&inner.accept_thread) = Some(accept);

        let tic = Arc::clone(&inner);
        let tick = std::thread::Builder::new()
            .name("finger-election-tick".into())
            .spawn(move || tick_loop(&tic))?;
        *lock(&inner.tick_thread) = Some(tick);

        {
            let mut senders = lock(&inner.peer_threads);
            for link in &inner.peers {
                let inner = Arc::clone(&inner);
                let link = Arc::clone(link);
                senders.push(
                    std::thread::Builder::new()
                        .name("finger-election-peer".into())
                        .spawn(move || peer_loop(&inner, &link))?,
                );
            }
        }

        Ok(ElectionNode { inner })
    }

    pub fn id(&self) -> u64 {
        self.inner.cfg.id
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr
    }

    pub fn role(&self) -> Role {
        lock(&self.inner.state).role
    }

    pub fn term(&self) -> u64 {
        lock(&self.inner.state).term
    }

    pub fn is_leader(&self) -> bool {
        self.role() == Role::Leader
    }

    /// The leader this node currently recognizes (itself included).
    pub fn leader(&self) -> Option<LeaderInfo> {
        lock(&self.inner.state).leader.clone()
    }

    /// Atomic `(role, term, leader)` snapshot under one state lock.
    /// Reading the three piecemeal races step-downs: a caller could see
    /// `Leader` and then a *newer* term — and, say, label its log with a
    /// term whose entries it does not hold.
    pub fn view(&self) -> (Role, u64, Option<LeaderInfo>) {
        let st = lock(&self.inner.state);
        (st.role, st.term, st.leader.clone())
    }

    /// The highest commit watermark heard from (or advertised as) a
    /// leader.
    pub fn leader_commit(&self) -> u64 {
        self.inner.commit.load(Ordering::SeqCst)
    }

    /// Advertise where this node's replication hub and query plane
    /// listen; carried in heartbeats when it leads.
    pub fn set_advert(&self, repl_addr: &str, query_addr: &str) {
        *lock(&self.inner.advert) = (repl_addr.to_string(), query_addr.to_string());
    }

    /// Feed the node's durable log position `(term, seq)` into the vote
    /// handlers. The term component persists when it changes (once per
    /// leadership change, not per op). The first call unlocks vote
    /// granting and campaigning: until the serving layer has reported
    /// its recovered position the node abstains entirely.
    pub fn note_log(&self, term: u64, seq: u64) {
        self.inner.last_seq.store(seq, Ordering::SeqCst);
        let prev = self.inner.last_log_term.swap(term, Ordering::SeqCst);
        if prev != term {
            let st = lock(&self.inner.state);
            persist_locked(&self.inner, &st);
        }
        self.inner.log_seeded.store(true, Ordering::SeqCst);
    }

    /// Advance the commit watermark advertised in this leader's
    /// heartbeats.
    pub fn note_commit(&self, seq: u64) {
        self.inner.commit.fetch_max(seq, Ordering::SeqCst);
    }

    /// The log-position term last fed via [`ElectionNode::note_log`] (or
    /// restored from the persisted state file).
    pub fn last_log_term(&self) -> u64 {
        self.inner.last_log_term.load(Ordering::SeqCst)
    }

    /// The log-position seq last fed via [`ElectionNode::note_log`].
    pub fn last_seq(&self) -> u64 {
        self.inner.last_seq.load(Ordering::SeqCst)
    }

    /// Test seam: a partitioned node freezes — it sends nothing, drops
    /// every incoming frame without replying, and never campaigns (so
    /// its term does not inflate while cut off). Healing resets its
    /// election clock so it first listens for the current leader.
    pub fn set_partitioned(&self, on: bool) {
        self.inner.partitioned.store(on, Ordering::SeqCst);
        if !on {
            lock(&self.inner.state).last_heartbeat = Instant::now();
        }
    }

    pub fn is_partitioned(&self) -> bool {
        self.inner.partitioned.load(Ordering::SeqCst)
    }

    /// Observe a term from outside the election transport (e.g. a
    /// replication peer): a higher term demotes immediately.
    pub fn observe_term(&self, term: u64) {
        step_down(&self.inner, term);
    }

    /// Stop the threads. Safe to call more than once.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        for link in &self.inner.peers {
            link.cv.notify_all();
        }
        if let Some(t) = lock(&self.inner.accept_thread).take() {
            t.join().ok();
        }
        if let Some(t) = lock(&self.inner.tick_thread).take() {
            t.join().ok();
        }
        for t in lock(&self.inner.peer_threads).drain(..) {
            t.join().ok();
        }
    }
}

/// Demote to follower at `term` if it is newer than ours.
fn step_down(inner: &Inner, term: u64) {
    let mut st = lock(&inner.state);
    if term > st.term {
        st.term = term;
        st.voted_for = 0;
        st.role = Role::Follower;
        st.leader = None;
        st.last_heartbeat = Instant::now();
        persist_locked(inner, &st);
    }
}

fn accept_loop(inner: &Arc<Inner>, listener: TcpListener) {
    while !inner.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => handle_conn(inner, stream),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

fn handle_conn(inner: &Arc<Inner>, mut stream: TcpStream) {
    if inner.partitioned.load(Ordering::SeqCst) {
        return; // dropped without a reply: the caller sees a dead peer
    }
    stream.set_read_timeout(Some(REPLY_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    let Ok(Some(req)) = Frame::read_from(&mut stream) else { return };
    if inner.partitioned.load(Ordering::SeqCst) {
        return;
    }
    let reply = match req {
        Frame::VoteRequest { term, candidate, last_log_term, last_seq } => {
            handle_vote(inner, term, candidate, last_log_term, last_seq)
        }
        Frame::Heartbeat { term, leader, commit, repl_addr, query_addr } => {
            handle_heartbeat(inner, term, leader, commit, repl_addr, query_addr)
        }
        _ => return, // replication frames do not belong on this port
    };
    reply.write_to(&mut stream).ok();
}

fn handle_vote(inner: &Inner, term: u64, candidate: u64, last_log_term: u64, last_seq: u64) -> Frame {
    let mut st = lock(&inner.state);
    let mut dirty = false;
    if term > st.term {
        st.term = term;
        st.voted_for = 0;
        st.role = Role::Follower;
        st.leader = None;
        dirty = true;
    }
    let mine = (
        inner.last_log_term.load(Ordering::SeqCst),
        inner.last_seq.load(Ordering::SeqCst),
    );
    let up_to_date = (last_log_term, last_seq) >= mine;
    // An unseeded node does not know its own position yet (last_seq
    // starts at 0 until the serving layer recovers the WAL); comparing
    // against the placeholder would under-report and could elect a
    // candidate missing committed ops. Abstain instead.
    let granted = inner.log_seeded.load(Ordering::SeqCst)
        && term == st.term
        && (st.voted_for == 0 || st.voted_for == candidate)
        && up_to_date;
    if granted {
        if st.voted_for != candidate {
            st.voted_for = candidate;
            dirty = true;
        }
        // Granting resets the election clock: give the candidate a full
        // timeout to win before this node campaigns against it.
        st.last_heartbeat = Instant::now();
    }
    if dirty {
        persist_locked(inner, &st);
    }
    Frame::VoteReply { term: st.term, granted }
}

fn handle_heartbeat(
    inner: &Inner,
    term: u64,
    leader: u64,
    commit: u64,
    repl_addr: String,
    query_addr: String,
) -> Frame {
    let mut st = lock(&inner.state);
    if term < st.term {
        return Frame::HeartbeatAck { term: st.term };
    }
    let mut dirty = false;
    if term > st.term {
        st.term = term;
        st.voted_for = 0;
        dirty = true;
    }
    // Equal term included: a candidate that hears the winner's
    // heartbeat steps down.
    st.role = Role::Follower;
    st.leader = Some(LeaderInfo { id: leader, term, repl_addr, query_addr });
    st.last_heartbeat = Instant::now();
    inner.commit.fetch_max(commit, Ordering::SeqCst);
    if dirty {
        persist_locked(inner, &st);
    }
    Frame::HeartbeatAck { term: st.term }
}

fn tick_loop(inner: &Arc<Inner>) {
    while !inner.stop.load(Ordering::SeqCst) {
        std::thread::sleep(TICK);
        if inner.partitioned.load(Ordering::SeqCst) {
            continue;
        }
        let now = Instant::now();
        enum Action {
            Broadcast(u64),
            Campaign(u64),
            None,
        }
        let action = {
            let mut st = lock(&inner.state);
            match st.role {
                Role::Leader => {
                    if now.duration_since(st.last_broadcast) >= inner.cfg.heartbeat_interval {
                        st.last_broadcast = now;
                        Action::Broadcast(st.term)
                    } else {
                        Action::None
                    }
                }
                _ => {
                    if !inner.log_seeded.load(Ordering::SeqCst) {
                        // Not seeded: hold the election clock so the
                        // node neither campaigns on a placeholder
                        // position nor fires the instant it is seeded.
                        st.last_heartbeat = now;
                        Action::None
                    } else if now.duration_since(st.last_heartbeat) >= st.timeout {
                        st.term += 1;
                        st.voted_for = inner.cfg.id;
                        st.role = Role::Candidate;
                        st.leader = None;
                        st.votes = 1;
                        st.last_heartbeat = now;
                        let base = inner.cfg.election_timeout;
                        st.timeout = draw_timeout(&mut st.rng, base);
                        persist_locked(inner, &st);
                        Action::Campaign(st.term)
                    } else {
                        Action::None
                    }
                }
            }
        };
        match action {
            Action::Broadcast(term) => broadcast_heartbeats(inner, term),
            Action::Campaign(term) => start_campaign(inner, term),
            Action::None => {}
        }
    }
}

fn majority(inner: &Inner) -> usize {
    (inner.peers.len() + 1) / 2 + 1
}

fn become_leader_if_won(inner: &Arc<Inner>, term: u64) {
    let won = {
        let mut st = lock(&inner.state);
        if st.role == Role::Candidate && st.term == term && st.votes >= majority(inner) {
            st.role = Role::Leader;
            let (repl_addr, query_addr) = lock(&inner.advert).clone();
            st.leader = Some(LeaderInfo { id: inner.cfg.id, term, repl_addr, query_addr });
            st.last_broadcast = Instant::now();
            true
        } else {
            false
        }
    };
    if won {
        // Announce immediately: every heartbeat a peer hears before its
        // timeout fires is one fewer disputed election.
        broadcast_heartbeats(inner, term);
    }
}

/// Post a frame to a peer's mailbox, superseding whatever was pending.
fn post(link: &PeerLink, frame: Frame) {
    *lock(&link.pending) = Some(frame);
    link.cv.notify_all();
}

fn start_campaign(inner: &Arc<Inner>, term: u64) {
    become_leader_if_won(inner, term); // single-node cluster wins alone
    let last_log_term = inner.last_log_term.load(Ordering::SeqCst);
    let last_seq = inner.last_seq.load(Ordering::SeqCst);
    for link in &inner.peers {
        post(
            link,
            Frame::VoteRequest { term, candidate: inner.cfg.id, last_log_term, last_seq },
        );
    }
}

fn broadcast_heartbeats(inner: &Arc<Inner>, term: u64) {
    let (repl_addr, query_addr) = lock(&inner.advert).clone();
    let commit = inner.commit.load(Ordering::SeqCst);
    for link in &inner.peers {
        post(
            link,
            Frame::Heartbeat {
                term,
                leader: inner.cfg.id,
                commit,
                repl_addr: repl_addr.clone(),
                query_addr: query_addr.clone(),
            },
        );
    }
}

/// One peer's long-lived sender: block on the mailbox, exchange one
/// request/response with the peer, feed the reply back into the state
/// machine. At most one exchange (≤ connect + reply timeout) is ever in
/// flight per peer, regardless of heartbeat cadence or partitions.
fn peer_loop(inner: &Arc<Inner>, link: &PeerLink) {
    while !inner.stop.load(Ordering::SeqCst) {
        let req = {
            let mut mb = lock(&link.pending);
            loop {
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(f) = mb.take() {
                    break f;
                }
                let (guard, _) = link
                    .cv
                    .wait_timeout(mb, Duration::from_millis(25))
                    .unwrap_or_else(|e| e.into_inner());
                mb = guard;
            }
        };
        if inner.partitioned.load(Ordering::SeqCst) {
            continue;
        }
        match &req {
            &Frame::VoteRequest { term, .. } => {
                // A dead or partitioned peer simply contributes no vote.
                if let Some(Frame::VoteReply { term: t, granted }) = ask(&link.addr, &req) {
                    if t > term {
                        step_down(inner, t);
                    } else if granted {
                        {
                            let mut st = lock(&inner.state);
                            if st.role == Role::Candidate && st.term == term {
                                st.votes += 1;
                            }
                        }
                        become_leader_if_won(inner, term);
                    }
                }
            }
            &Frame::Heartbeat { term, .. } => {
                if let Some(Frame::HeartbeatAck { term: t }) = ask(&link.addr, &req) {
                    if t > term {
                        step_down(inner, t);
                    }
                }
            }
            _ => {}
        }
    }
}

/// One request/response exchange on a fresh connection.
fn ask(addr: &SocketAddr, req: &Frame) -> Option<Frame> {
    let mut stream = TcpStream::connect_timeout(addr, CONNECT_TIMEOUT).ok()?;
    stream.set_read_timeout(Some(REPLY_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    req.write_to(&mut stream).ok()?;
    Frame::read_from(&mut stream).ok()?
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A node whose election timeout is effectively infinite: it never
    /// campaigns, so tests drive it purely with frames over TCP. Seeded
    /// at position (0, 0) so it may grant votes; tests exercising the
    /// unseeded state call `ElectionNode::start` themselves.
    fn quiet_node(id: u64, state_dir: Option<PathBuf>) -> ElectionNode {
        let node = ElectionNode::start(ElectionConfig {
            id,
            listen: "127.0.0.1:0".into(),
            peers: Vec::new(),
            election_timeout: Duration::from_secs(3600),
            heartbeat_interval: Duration::from_secs(3600),
            state_dir,
            seed: 7,
        })
        .expect("start quiet node");
        node.note_log(0, 0);
        node
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("finger_election_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn send(addr: &SocketAddr, req: &Frame) -> Option<Frame> {
        ask(addr, req)
    }

    #[test]
    fn votes_require_up_to_date_logs_and_are_single_per_term() {
        let node = quiet_node(1, None);
        node.note_log(2, 10);
        let addr = node.local_addr();
        let vote = |term, candidate, llt, ls| {
            match send(&addr, &Frame::VoteRequest { term, candidate, last_log_term: llt, last_seq: ls }) {
                Some(Frame::VoteReply { term, granted }) => (term, granted),
                other => panic!("want a vote reply, got {other:?}"),
            }
        };
        // A longer but older-term log loses the lexicographic compare.
        assert_eq!(vote(5, 2, 1, 50), (5, false));
        // Up-to-date candidate wins the vote.
        assert_eq!(vote(5, 3, 2, 10), (5, true));
        // Same term, different candidate: already voted.
        assert_eq!(vote(5, 4, 2, 10), (5, false));
        // Same candidate re-asking is idempotent.
        assert_eq!(vote(5, 3, 2, 10), (5, true));
        // A new term resets the vote.
        assert_eq!(vote(6, 4, 3, 0), (6, true));
        // Stale-term request is refused and told the current term.
        assert_eq!(vote(4, 5, 9, 99), (6, false));
        node.shutdown();
    }

    #[test]
    fn heartbeats_install_a_leader_and_stale_terms_are_fenced() {
        let node = quiet_node(1, None);
        let addr = node.local_addr();
        let hb = Frame::Heartbeat {
            term: 3,
            leader: 9,
            commit: 17,
            repl_addr: "127.0.0.1:7780".into(),
            query_addr: "127.0.0.1:7771".into(),
        };
        assert_eq!(send(&addr, &hb), Some(Frame::HeartbeatAck { term: 3 }));
        assert_eq!(node.term(), 3);
        assert_eq!(node.role(), Role::Follower);
        let leader = node.leader().expect("leader installed");
        assert_eq!((leader.id, leader.term), (9, 3));
        assert_eq!(leader.repl_addr, "127.0.0.1:7780");
        assert_eq!(node.leader_commit(), 17);
        // A stale-term heartbeat changes nothing and is answered with
        // the newer term (the fence a deposed leader observes).
        let stale = Frame::Heartbeat {
            term: 2,
            leader: 8,
            commit: 0,
            repl_addr: String::new(),
            query_addr: String::new(),
        };
        assert_eq!(send(&addr, &stale), Some(Frame::HeartbeatAck { term: 3 }));
        assert_eq!(node.leader().expect("unchanged").id, 9);
        node.shutdown();
    }

    #[test]
    fn term_and_vote_survive_a_restart() {
        let dir = tmp_dir("persist");
        let node = quiet_node(1, Some(dir.clone()));
        let addr = node.local_addr();
        send(
            &addr,
            &Frame::Heartbeat {
                term: 7,
                leader: 2,
                commit: 0,
                repl_addr: String::new(),
                query_addr: String::new(),
            },
        );
        assert_eq!(node.term(), 7);
        node.shutdown();
        let reborn = quiet_node(1, Some(dir.clone()));
        assert_eq!(reborn.term(), 7, "term must survive a crash");
        // A corrupt state file is ignored, not trusted.
        std::fs::write(dir.join(STATE_FILE), b"garbage").unwrap();
        assert_eq!(load_state(&dir), None);
        reborn.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partitioned_node_drops_frames_without_reply() {
        let node = quiet_node(1, None);
        let addr = node.local_addr();
        node.set_partitioned(true);
        assert!(node.is_partitioned());
        let req = Frame::VoteRequest { term: 9, candidate: 2, last_log_term: 9, last_seq: 9 };
        assert_eq!(send(&addr, &req), None, "partitioned node must not reply");
        assert_eq!(node.term(), 0, "dropped frames must not move the term");
        node.set_partitioned(false);
        assert!(matches!(send(&addr, &req), Some(Frame::VoteReply { granted: true, .. })));
        node.shutdown();
    }

    fn cluster(n: usize, base_ms: u64) -> Vec<ElectionNode> {
        let listeners: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind")).collect();
        let addrs: Vec<String> =
            listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
        listeners
            .into_iter()
            .enumerate()
            .map(|(i, listener)| {
                let peers = addrs
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| *j != i)
                    .map(|(j, a)| PeerSpec { id: (j + 1) as u64, addr: a.clone() })
                    .collect();
                let node = ElectionNode::start_on(
                    ElectionConfig {
                        id: (i + 1) as u64,
                        listen: String::new(),
                        peers,
                        election_timeout: Duration::from_millis(base_ms),
                        heartbeat_interval: Duration::from_millis(base_ms / 4),
                        state_dir: None,
                        seed: 0xE1EC + i as u64,
                    },
                    listener,
                )
                .expect("start node");
                node.note_log(0, 0);
                node
            })
            .collect()
    }

    fn wait_for_leader(nodes: &[ElectionNode], budget: Duration) -> usize {
        let deadline = Instant::now() + budget;
        loop {
            let leaders: Vec<usize> = nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| n.is_leader())
                .map(|(i, _)| i)
                .collect();
            if leaders.len() == 1 {
                let li = leaders[0];
                let term = nodes[li].term();
                // Stable once every follower recognizes it at that term.
                let all_agree = nodes.iter().enumerate().all(|(i, n)| {
                    i == li
                        || n.leader().map(|l| l.id == nodes[li].id() && l.term == term)
                            == Some(true)
                });
                if all_agree {
                    return li;
                }
            }
            assert!(Instant::now() < deadline, "no stable leader within {budget:?}");
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn three_nodes_elect_exactly_one_leader() {
        let nodes = cluster(3, 150);
        let li = wait_for_leader(&nodes, Duration::from_secs(10));
        let term = nodes[li].term();
        assert!(term >= 1);
        for (i, n) in nodes.iter().enumerate() {
            if i != li {
                assert_eq!(n.role(), Role::Follower);
            }
        }
        for n in &nodes {
            n.shutdown();
        }
    }

    /// Until `note_log` seeds the recovered position, a node must
    /// neither grant votes (its in-memory `last_seq` placeholder
    /// under-reports, which could elect a candidate missing committed
    /// ops) nor campaign on the placeholder.
    #[test]
    fn an_unseeded_node_abstains_from_votes_and_campaigns() {
        let node = ElectionNode::start(ElectionConfig {
            id: 1,
            listen: "127.0.0.1:0".into(),
            peers: Vec::new(),
            election_timeout: Duration::from_millis(40),
            heartbeat_interval: Duration::from_millis(20),
            state_dir: None,
            seed: 3,
        })
        .expect("start node");
        let addr = node.local_addr();
        // A peerless node campaigns and wins alone — unless gated.
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(node.role(), Role::Follower, "unseeded node must not campaign");
        let req = Frame::VoteRequest { term: 5, candidate: 2, last_log_term: 9, last_seq: 99 };
        assert!(
            matches!(ask(&addr, &req), Some(Frame::VoteReply { granted: false, .. })),
            "unseeded node must refuse even a generous candidate"
        );
        // Seeding unlocks both.
        node.note_log(0, 0);
        assert!(matches!(ask(&addr, &req), Some(Frame::VoteReply { granted: true, .. })));
        let deadline = Instant::now() + Duration::from_secs(10);
        while !node.is_leader() {
            assert!(Instant::now() < deadline, "seeded single-node cluster must elect itself");
            std::thread::sleep(Duration::from_millis(10));
        }
        node.shutdown();
    }

    /// The log-matching check: with two nodes, the one holding the
    /// longer durable log must win (the stale node can never assemble a
    /// majority because the up-to-date node refuses it).
    #[test]
    fn log_matching_lets_only_the_longest_log_win() {
        let nodes = cluster(2, 150);
        nodes[0].note_log(1, 5);
        nodes[1].note_log(1, 0);
        let li = wait_for_leader(&nodes, Duration::from_secs(15));
        assert_eq!(li, 0, "the node with the longer durable log must win");
        for n in &nodes {
            n.shutdown();
        }
    }
}
