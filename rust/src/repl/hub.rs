//! Primary-side replication hub: accepts replicas, streams the ordered
//! WAL, gates client acknowledgements on replica acks.
//!
//! One hub per primary. Each accepted connection handshakes with a
//! [`Frame::Hello`] carrying the replica's durable position, then — with
//! the hub state locked, so live publishes cannot interleave — the hub
//! reads a catch-up from the WAL's generation manager
//! ([`Wal::catchup_since`]: full snapshot if the replica is behind the
//! generation base, plus the log tail), enqueues it, and registers the
//! replica for the live stream. The lock ordering makes the stream
//! gap-free and duplicate-free by construction:
//!
//! * [`ReplHub::publish`] runs under the index write lock (the caller's),
//!   once per applied+logged op, in seq order; it takes the state lock to
//!   enqueue.
//! * Registration holds the state lock across the catch-up file read, so
//!   for any op, either its publish happened before registration (then
//!   its append — which precedes publish under the index lock — is in
//!   the tail the catch-up read) or it happens after (then the slot is
//!   registered and receives it live). The per-slot `last_enqueued`
//!   watermark drops the overlap.
//!
//! Ack gating: `wait_acked(seq)` blocks until enough connected replicas
//! report a durable position `>= seq` — `none` returns immediately,
//! `one` wants any single replica, `all` wants `expect` of them — or
//! the timeout elapses (a structured error; the op stays applied and
//! logged locally, so a timed-out ack is ambiguous, not rolled back —
//! exactly the semantics of every quorum system's timeout).

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::repl::frame::Frame;
use crate::repl::AckLevel;
use crate::wal::{Wal, WalOp};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

struct Slot {
    id: u64,
    /// Highest seq enqueued to this replica (catch-up included).
    last_enqueued: u64,
    /// Highest seq the replica acked as durably applied.
    acked: u64,
    tx: mpsc::Sender<Vec<u8>>,
    /// Kept for shutdown: closing the socket unblocks the reader thread.
    stream: TcpStream,
}

struct HubState {
    next_id: u64,
    slots: Vec<Slot>,
}

/// Per-replica view for `repl status`.
#[derive(Clone, Debug)]
pub struct ReplicaStatus {
    pub id: u64,
    pub acked: u64,
    pub enqueued: u64,
}

/// See the module docs. Construct with [`ReplHub::start`].
pub struct ReplHub {
    level: AckLevel,
    expect: usize,
    ack_timeout: Duration,
    wal: Arc<Wal>,
    local_addr: SocketAddr,
    state: Mutex<HubState>,
    acked_cv: Condvar,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ReplHub {
    /// Bind the replication listener and start accepting replicas.
    /// `expect` is the replica count level `all` waits for (min 1).
    pub fn start(
        addr: &str,
        wal: Arc<Wal>,
        level: AckLevel,
        expect: usize,
        ack_timeout: Duration,
    ) -> io::Result<Arc<ReplHub>> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let hub = Arc::new(ReplHub {
            level,
            expect: expect.max(1),
            ack_timeout,
            wal,
            local_addr,
            state: Mutex::new(HubState { next_id: 0, slots: Vec::new() }),
            acked_cv: Condvar::new(),
            stop: Arc::new(AtomicBool::new(false)),
            accept_thread: Mutex::new(None),
        });
        let accept = {
            let hub = Arc::clone(&hub);
            std::thread::Builder::new()
                .name("finger-repl-accept".into())
                .spawn(move || loop {
                    if hub.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let hub2 = Arc::clone(&hub);
                            std::thread::Builder::new()
                                .name("finger-repl-conn".into())
                                .spawn(move || hub2.serve_replica(stream))
                                .ok();
                        }
                        Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                })?
        };
        *lock(&hub.accept_thread) = Some(accept);
        Ok(hub)
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn level(&self) -> AckLevel {
        self.level
    }

    pub fn expect(&self) -> usize {
        self.expect
    }

    /// Handshake + catch-up + registration, then pump acks until the
    /// replica disconnects. Runs on a per-connection thread.
    fn serve_replica(self: Arc<Self>, stream: TcpStream) {
        stream.set_nodelay(true).ok();
        let Ok(reader_stream) = stream.try_clone() else { return };
        let mut reader = BufReader::new(reader_stream);
        let (last_seq, need_snapshot) = match Frame::read_from(&mut reader) {
            Ok(Some(Frame::Hello { last_seq, need_snapshot })) => (last_seq, need_snapshot),
            _ => return, // anything else: not a replica; drop
        };

        let (id, rx) = {
            // State lock held across the catch-up read — see the module
            // docs for why this ordering closes the publish race.
            let mut state = lock(&self.state);
            let Ok(catchup) = self.wal.catchup_since(last_seq, need_snapshot) else {
                return;
            };
            let (tx, rx) = mpsc::channel::<Vec<u8>>();
            let mut enqueued = last_seq;
            if let Some((base, bundle)) = catchup.snapshot {
                let _ = tx.send(Frame::Snapshot { snapshot_seq: base, bundle }.encode());
                enqueued = enqueued.max(base);
            }
            for (seq, op) in &catchup.ops {
                let _ = tx.send(Frame::op(*seq, op).encode());
                enqueued = enqueued.max(*seq);
            }
            let _ = tx.send(Frame::CaughtUp { seq: enqueued }.encode());
            let id = state.next_id;
            state.next_id += 1;
            let Ok(slot_stream) = stream.try_clone() else { return };
            state.slots.push(Slot {
                id,
                last_enqueued: enqueued,
                // A reconnecting replica's durable position stands.
                acked: last_seq,
                tx,
                stream: slot_stream,
            });
            self.acked_cv.notify_all();
            (id, rx)
        };

        // Sender thread: drain the queue onto the socket.
        let sender = {
            let hub = Arc::clone(&self);
            let mut out = stream;
            std::thread::Builder::new()
                .name("finger-repl-send".into())
                .spawn(move || {
                    use std::io::Write as _;
                    while let Ok(frame) = rx.recv() {
                        if out.write_all(&frame).is_err() {
                            break;
                        }
                    }
                    hub.drop_slot(id);
                })
        };

        // This thread becomes the ack reader.
        loop {
            match Frame::read_from(&mut reader) {
                Ok(Some(Frame::Ack { seq })) => {
                    let mut state = lock(&self.state);
                    if let Some(slot) = state.slots.iter_mut().find(|s| s.id == id) {
                        slot.acked = slot.acked.max(seq);
                    }
                    self.acked_cv.notify_all();
                }
                Ok(Some(_)) | Ok(None) | Err(_) => break,
            }
        }
        self.drop_slot(id);
        if let Ok(s) = sender {
            let _ = s.join();
        }
    }

    /// Deregister a replica (its queue sender drops, ending the sender
    /// thread; waiters re-evaluate without it).
    fn drop_slot(&self, id: u64) {
        let mut state = lock(&self.state);
        if let Some(pos) = state.slots.iter().position(|s| s.id == id) {
            let slot = state.slots.remove(pos);
            slot.stream.shutdown(std::net::Shutdown::Both).ok();
        }
        self.acked_cv.notify_all();
    }

    /// Enqueue one applied+logged op to every connected replica. Call
    /// under the same lock that serialized apply+append (the index write
    /// lock) so publish order equals log order.
    pub fn publish(&self, seq: u64, op: &WalOp) {
        let frame = Frame::op(seq, op).encode();
        let mut state = lock(&self.state);
        let mut dead: Vec<u64> = Vec::new();
        for slot in &mut state.slots {
            if seq <= slot.last_enqueued {
                continue; // catch-up already covered it
            }
            debug_assert_eq!(seq, slot.last_enqueued + 1, "publish must be gap-free");
            if slot.tx.send(frame.clone()).is_ok() {
                slot.last_enqueued = seq;
            } else {
                dead.push(slot.id);
            }
        }
        for id in dead {
            if let Some(pos) = state.slots.iter().position(|s| s.id == id) {
                let slot = state.slots.remove(pos);
                slot.stream.shutdown(std::net::Shutdown::Both).ok();
            }
        }
    }

    /// Block until the configured replication level acknowledges `seq`
    /// (see the module docs), or time out with a structured error.
    pub fn wait_acked(&self, seq: u64) -> Result<(), String> {
        let want = match self.level {
            AckLevel::None => return Ok(()),
            AckLevel::One => 1,
            AckLevel::All => self.expect,
        };
        let deadline = Instant::now() + self.ack_timeout;
        let mut state = lock(&self.state);
        loop {
            let have = state.slots.iter().filter(|s| s.acked >= seq).count();
            if have >= want {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(format!(
                    "replication ack timeout: seq {seq} durable on {have} replica(s), \
                     level '{}' wants {want} (op is applied and logged locally)",
                    self.level.name()
                ));
            }
            let (guard, _) = self
                .acked_cv
                .wait_timeout(state, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            state = guard;
        }
    }

    /// Connected-replica snapshot for the `repl_status` verb.
    pub fn status(&self) -> Vec<ReplicaStatus> {
        lock(&self.state)
            .slots
            .iter()
            .map(|s| ReplicaStatus { id: s.id, acked: s.acked, enqueued: s.last_enqueued })
            .collect()
    }

    /// Stop accepting, disconnect every replica, join the accept thread.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        {
            let mut state = lock(&self.state);
            for slot in state.slots.drain(..) {
                slot.stream.shutdown(std::net::Shutdown::Both).ok();
            }
        }
        self.acked_cv.notify_all();
        if let Some(t) = lock(&self.accept_thread).take() {
            let _ = t.join();
        }
    }
}
