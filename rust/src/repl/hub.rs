//! Primary-side replication hub: accepts replicas, streams the ordered
//! WAL, gates client acknowledgements on replica acks.
//!
//! One hub per leader. Each accepted connection handshakes with a
//! [`Frame::Hello`] carrying the replica's durable position, then — with
//! the hub state locked, so live publishes cannot interleave — the hub
//! reads a catch-up from the WAL's generation manager
//! ([`Wal::catchup_since`]: full snapshot if the replica is behind the
//! generation base, plus the log tail), enqueues it, and registers the
//! replica for the live stream. The lock ordering makes the stream
//! gap-free and duplicate-free by construction:
//!
//! * [`ReplHub::publish`] runs under the index write lock (the caller's),
//!   once per applied+logged op, in seq order; it takes the state lock to
//!   enqueue.
//! * Registration holds the state lock across the catch-up file read, so
//!   for any op, either its publish happened before registration (then
//!   its append — which precedes publish under the index lock — is in
//!   the tail the catch-up read) or it happens after (then the slot is
//!   registered and receives it live). The per-slot `last_enqueued`
//!   watermark drops the overlap.
//!
//! A replica that claims a durable position AHEAD of this hub's log (a
//! deposed leader reconnecting with an uncommitted tail) is never
//! believed: the handshake forces a full snapshot and zeroes the slot's
//! watermarks, so the stale claim can neither vote phantom quorum acks
//! nor filter future publishes. A claim within the log but ahead of the
//! hub's *fsynced* prefix is believed for streaming (the ops exist, so
//! catch-up resumes from the claim) but its quorum vote is capped at
//! the durable seq — an appended-but-unsynced op must gather fresh acks
//! once it is actually on disk, not inherit them from a handshake.
//!
//! Ack gating: `wait_acked(seq)` blocks until enough of the cluster
//! reports a durable position `>= seq` — `none` returns immediately,
//! `one` wants any single replica, `all` wants `expect` replicas, and
//! `quorum` wants a majority of the `expect`-node cluster *counting the
//! leader's own fsync as one vote*. Quorum waits degrade instead of
//! hanging: when fewer than a majority of nodes are even connected the
//! wait fails fast with a structured `no-quorum` error (the op stays
//! applied and logged locally — ambiguous, not rolled back — exactly
//! the semantics of every quorum system's timeout).
//!
//! In cluster mode the hub does not own a listener: construct with
//! [`ReplHub::new`] and hand accepted replica sockets to
//! [`ReplHub::attach`] (the cluster supervisor owns the bound port so
//! the advertised address survives leader changes).

use std::io::{self, BufReader};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::repl::frame::Frame;
use crate::repl::AckLevel;
use crate::wal::{Wal, WalOp};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Hub tuning. `expect` is the cluster size the `all` and `quorum`
/// levels are judged against (min 1): for `all` it is the replica count
/// to wait for; for `quorum` it is the total node count *including the
/// leader*, of which a majority must be durable.
#[derive(Clone, Debug)]
pub struct HubOpts {
    pub level: AckLevel,
    pub expect: usize,
    pub ack_timeout: Duration,
    /// Max live (post-catch-up) frames a replica may leave unacked
    /// before the hub drops it back to the reconnect+catch-up path.
    /// Bounds queue memory when a replica stalls without dying.
    pub max_inflight: u64,
}

impl Default for HubOpts {
    fn default() -> Self {
        HubOpts {
            level: AckLevel::One,
            expect: 1,
            ack_timeout: Duration::from_secs(5),
            max_inflight: 4096,
        }
    }
}

struct Slot {
    id: u64,
    /// Highest seq enqueued to this replica (catch-up included).
    last_enqueued: u64,
    /// Highest seq the replica acked as durably applied.
    acked: u64,
    /// Watermark at registration time: live publishes below it were
    /// delivered by the catch-up read, so the in-flight window counts
    /// only frames above `max(acked, catchup_high)` — a replica still
    /// draining a large catch-up is not punished for it.
    catchup_high: u64,
    tx: mpsc::Sender<Vec<u8>>,
    /// Kept for shutdown: closing the socket unblocks the reader thread.
    stream: TcpStream,
}

struct HubState {
    next_id: u64,
    slots: Vec<Slot>,
}

/// Per-replica view for `repl status`.
#[derive(Clone, Debug)]
pub struct ReplicaStatus {
    pub id: u64,
    pub acked: u64,
    pub enqueued: u64,
}

/// See the module docs. Construct with [`ReplHub::start`] (owns a
/// listener) or [`ReplHub::new`] + [`ReplHub::attach`] (cluster mode).
pub struct ReplHub {
    opts: HubOpts,
    wal: Arc<Wal>,
    local_addr: SocketAddr,
    state: Mutex<HubState>,
    acked_cv: Condvar,
    stop: Arc<AtomicBool>,
    accept_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ReplHub {
    /// Listener-less hub for cluster mode: the caller owns the bound
    /// replication port and routes accepted sockets via [`attach`].
    /// `advertised` is what [`local_addr`] reports.
    ///
    /// [`attach`]: ReplHub::attach
    /// [`local_addr`]: ReplHub::local_addr
    pub fn new(wal: Arc<Wal>, opts: HubOpts, advertised: SocketAddr) -> Arc<ReplHub> {
        Arc::new(ReplHub {
            opts: HubOpts { expect: opts.expect.max(1), ..opts },
            wal,
            local_addr: advertised,
            state: Mutex::new(HubState { next_id: 0, slots: Vec::new() }),
            acked_cv: Condvar::new(),
            stop: Arc::new(AtomicBool::new(false)),
            accept_thread: Mutex::new(None),
        })
    }

    /// Bind the replication listener and start accepting replicas.
    pub fn start(addr: &str, wal: Arc<Wal>, opts: HubOpts) -> io::Result<Arc<ReplHub>> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let hub = ReplHub::new(wal, opts, local_addr);
        let accept = {
            let hub = Arc::clone(&hub);
            std::thread::Builder::new()
                .name("finger-repl-accept".into())
                .spawn(move || loop {
                    if hub.stop.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => hub.attach(stream),
                        Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                })?
        };
        *lock(&hub.accept_thread) = Some(accept);
        Ok(hub)
    }

    /// Hand an accepted replica socket to this hub (spawns the
    /// per-connection handshake/ack thread).
    pub fn attach(self: &Arc<Self>, stream: TcpStream) {
        if self.stop.load(Ordering::Relaxed) {
            stream.shutdown(std::net::Shutdown::Both).ok();
            return;
        }
        let hub = Arc::clone(self);
        std::thread::Builder::new()
            .name("finger-repl-conn".into())
            .spawn(move || hub.serve_replica(stream))
            .ok();
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn level(&self) -> AckLevel {
        self.opts.level
    }

    pub fn expect(&self) -> usize {
        self.opts.expect
    }

    /// Handshake + catch-up + registration, then pump acks until the
    /// replica disconnects. Runs on a per-connection thread.
    fn serve_replica(self: Arc<Self>, stream: TcpStream) {
        stream.set_nodelay(true).ok();
        let Ok(reader_stream) = stream.try_clone() else { return };
        let mut reader = BufReader::new(reader_stream);
        let (hello_seq, hello_snap) = match Frame::read_from(&mut reader) {
            Ok(Some(Frame::Hello { last_seq, need_snapshot })) => (last_seq, need_snapshot),
            _ => return, // anything else: not a replica; drop
        };
        // Never believe a position ahead of our own log (a deposed
        // leader's uncommitted tail): force a full snapshot and zero the
        // watermarks, else the claim counts as a phantom quorum vote and
        // filters every future publish.
        let leader_appended = self.wal.writer().appended_seq();
        let (last_seq, need_snapshot) =
            if hello_seq > leader_appended { (0, true) } else { (hello_seq, hello_snap) };
        // The claim's quorum vote is additionally capped at this hub's
        // *durable* prefix: a seq that is appended but not yet fsynced
        // here must earn fresh acks once committed, not be pre-counted
        // by a handshake (the stream itself still resumes from the
        // claim — the ops exist and re-sending them would only trip the
        // replica's duplicate detection).
        let believed_acked = last_seq.min(self.wal.writer().synced_seq());

        let (id, rx) = {
            // State lock held across the catch-up read — see the module
            // docs for why this ordering closes the publish race.
            let mut state = lock(&self.state);
            let Ok(catchup) = self.wal.catchup_since(last_seq, need_snapshot) else {
                return;
            };
            let (tx, rx) = mpsc::channel::<Vec<u8>>();
            let mut enqueued = last_seq;
            if let Some((base, bundle)) = catchup.snapshot {
                let _ = tx.send(Frame::Snapshot { snapshot_seq: base, bundle }.encode());
                enqueued = enqueued.max(base);
            }
            for (seq, op) in &catchup.ops {
                let _ = tx.send(Frame::op(*seq, op).encode());
                enqueued = enqueued.max(*seq);
            }
            let _ = tx.send(Frame::CaughtUp { seq: enqueued }.encode());
            let id = state.next_id;
            state.next_id += 1;
            let Ok(slot_stream) = stream.try_clone() else { return };
            state.slots.push(Slot {
                id,
                last_enqueued: enqueued,
                // A reconnecting replica's durable position stands up to
                // this hub's own durable prefix (zeroed above when it
                // claimed to be ahead of the log entirely).
                acked: believed_acked,
                catchup_high: enqueued,
                tx,
                stream: slot_stream,
            });
            self.acked_cv.notify_all();
            (id, rx)
        };

        // Sender thread: drain the queue onto the socket.
        let sender = {
            let hub = Arc::clone(&self);
            let mut out = stream;
            std::thread::Builder::new()
                .name("finger-repl-send".into())
                .spawn(move || {
                    use std::io::Write as _;
                    while let Ok(frame) = rx.recv() {
                        if out.write_all(&frame).is_err() {
                            break;
                        }
                    }
                    hub.drop_slot(id);
                })
        };

        // This thread becomes the ack reader.
        loop {
            match Frame::read_from(&mut reader) {
                Ok(Some(Frame::Ack { seq })) => {
                    let mut state = lock(&self.state);
                    if let Some(slot) = state.slots.iter_mut().find(|s| s.id == id) {
                        slot.acked = slot.acked.max(seq);
                    }
                    self.acked_cv.notify_all();
                }
                Ok(Some(_)) | Ok(None) | Err(_) => break,
            }
        }
        self.drop_slot(id);
        if let Ok(s) = sender {
            let _ = s.join();
        }
    }

    /// Deregister a replica (its queue sender drops, ending the sender
    /// thread; waiters re-evaluate without it).
    fn drop_slot(&self, id: u64) {
        let mut state = lock(&self.state);
        if let Some(pos) = state.slots.iter().position(|s| s.id == id) {
            let slot = state.slots.remove(pos);
            slot.stream.shutdown(std::net::Shutdown::Both).ok();
        }
        self.acked_cv.notify_all();
    }

    /// Enqueue one applied+logged op to every connected replica. Call
    /// under the same lock that serialized apply+append (the index write
    /// lock) so publish order equals log order. A replica whose live
    /// in-flight window (frames past its catch-up high, unacked) has
    /// reached `max_inflight` is dropped; it reconnects and catches up
    /// from the log instead of growing the queue without bound.
    pub fn publish(&self, seq: u64, op: &WalOp) {
        let frame = Frame::op(seq, op).encode();
        let mut state = lock(&self.state);
        let mut dead: Vec<u64> = Vec::new();
        for slot in &mut state.slots {
            if seq <= slot.last_enqueued {
                continue; // catch-up already covered it
            }
            debug_assert_eq!(seq, slot.last_enqueued + 1, "publish must be gap-free");
            let window_floor = slot.acked.max(slot.catchup_high);
            if slot.last_enqueued.saturating_sub(window_floor) >= self.opts.max_inflight {
                dead.push(slot.id);
                continue;
            }
            if slot.tx.send(frame.clone()).is_ok() {
                slot.last_enqueued = seq;
            } else {
                dead.push(slot.id);
            }
        }
        let any_dead = !dead.is_empty();
        for id in dead {
            if let Some(pos) = state.slots.iter().position(|s| s.id == id) {
                let slot = state.slots.remove(pos);
                slot.stream.shutdown(std::net::Shutdown::Both).ok();
            }
        }
        drop(state);
        if any_dead {
            // Quorum waiters count connected nodes; a drop can flip
            // them to the fast no-quorum path.
            self.acked_cv.notify_all();
        }
    }

    /// Block until the configured replication level acknowledges `seq`
    /// (see the module docs), or fail with a structured error. Quorum
    /// waits fail *fast* — without burning the timeout — whenever fewer
    /// than a majority of the `expect`-node cluster is even connected.
    pub fn wait_acked(&self, seq: u64) -> Result<(), String> {
        let (want, count_self) = match self.opts.level {
            AckLevel::None => return Ok(()),
            AckLevel::One => (1, false),
            AckLevel::All => (self.opts.expect, false),
            AckLevel::Quorum => (self.opts.expect / 2 + 1, true),
        };
        let deadline = Instant::now() + self.opts.ack_timeout;
        let mut state = lock(&self.state);
        loop {
            let durable =
                state.slots.iter().filter(|s| s.acked >= seq).count() + usize::from(count_self);
            if durable >= want {
                return Ok(());
            }
            if count_self {
                let reachable = 1 + state.slots.len();
                if reachable < want {
                    return Err(format!(
                        "no-quorum: {reachable}/{} node(s) reachable, quorum wants {want} \
                         (seq {seq} is applied and logged locally and may be superseded \
                         on failover)",
                        self.opts.expect
                    ));
                }
            }
            let now = Instant::now();
            if now >= deadline {
                if count_self {
                    return Err(format!(
                        "no-quorum: replication ack timeout: seq {seq} durable on \
                         {durable}/{} node(s), quorum wants {want} (op is applied and \
                         logged locally and may be superseded on failover)",
                        self.opts.expect
                    ));
                }
                return Err(format!(
                    "replication ack timeout: seq {seq} durable on {durable} replica(s), \
                     level '{}' wants {want} (op is applied and logged locally)",
                    self.opts.level.name()
                ));
            }
            let (guard, _) = self
                .acked_cv
                .wait_timeout(state, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            state = guard;
        }
    }

    /// Connected-replica snapshot for the `repl_status` verb.
    pub fn status(&self) -> Vec<ReplicaStatus> {
        lock(&self.state)
            .slots
            .iter()
            .map(|s| ReplicaStatus { id: s.id, acked: s.acked, enqueued: s.last_enqueued })
            .collect()
    }

    /// Stop accepting, disconnect every replica, join the accept thread
    /// (if this hub owns one).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        {
            let mut state = lock(&self.state);
            for slot in state.slots.drain(..) {
                slot.stream.shutdown(std::net::Shutdown::Both).ok();
            }
        }
        self.acked_cv.notify_all();
        if let Some(t) = lock(&self.accept_thread).take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::matrix::Matrix;
    use crate::index::impls::BruteForce;
    use crate::wal::FsyncPolicy;
    use std::io::Write as _;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("finger_hub_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn test_hub(name: &str, opts: HubOpts) -> Arc<ReplHub> {
        let data = Arc::new(Matrix::zeros(2, 3));
        let index = BruteForce::new(data);
        let dir = tmp_dir(name);
        let wal = Arc::new(Wal::bootstrap(&dir, &index, FsyncPolicy::Always).expect("bootstrap"));
        ReplHub::start("127.0.0.1:0", wal, opts).expect("bind hub")
    }

    fn wait_slots(hub: &ReplHub, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while hub.status().len() != n {
            assert!(Instant::now() < deadline, "hub never reached {n} slot(s)");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn stalled_replica_is_dropped_at_the_inflight_window() {
        let hub = test_hub(
            "window",
            HubOpts {
                max_inflight: 2,
                ack_timeout: Duration::from_millis(100),
                ..HubOpts::default()
            },
        );
        // A fake replica that handshakes and then never acks.
        let mut conn = TcpStream::connect(hub.local_addr()).expect("connect");
        conn.write_all(&Frame::Hello { last_seq: 0, need_snapshot: false }.encode())
            .expect("hello");
        wait_slots(&hub, 1);

        let op = WalOp::SetThreshold { frac: 0.5 };
        hub.publish(1, &op);
        hub.publish(2, &op);
        assert_eq!(hub.status().len(), 1, "within the window the slot stays");
        assert_eq!(hub.status()[0].enqueued, 2);
        // A third unacked live frame exceeds max_inflight=2: dropped.
        hub.publish(3, &op);
        assert!(hub.status().is_empty(), "stalled replica must be dropped");
        hub.shutdown();
    }

    #[test]
    fn a_replica_claiming_a_future_seq_is_forced_to_snapshot() {
        let hub = test_hub("ahead", HubOpts::default());
        // A deposed leader's uncommitted tail: claims seq 999 while this
        // hub's log is empty.
        let mut conn = TcpStream::connect(hub.local_addr()).expect("connect");
        conn.write_all(&Frame::Hello { last_seq: 999, need_snapshot: false }.encode())
            .expect("hello");
        let mut reader = BufReader::new(conn.try_clone().expect("clone"));
        match Frame::read_from(&mut reader).expect("read") {
            Some(Frame::Snapshot { snapshot_seq, .. }) => assert_eq!(snapshot_seq, 0),
            other => panic!("expected forced snapshot, got {other:?}"),
        }
        wait_slots(&hub, 1);
        let st = hub.status().remove(0);
        assert_eq!(st.acked, 0, "stale claim must not count as durable");
        assert!(st.enqueued < 999, "watermark must be the hub's own, not the claim");
        hub.shutdown();
    }

    #[test]
    fn a_hello_claim_past_the_durable_prefix_is_not_pre_counted() {
        let data = Arc::new(Matrix::zeros(2, 3));
        let index = BruteForce::new(data);
        let dir = tmp_dir("durablecap");
        let wal =
            Arc::new(Wal::bootstrap(&dir, &index, FsyncPolicy::Never).expect("bootstrap"));
        // Two appended ops, none of them fsynced (policy `never`).
        wal.writer().append(&WalOp::SetThreshold { frac: 0.5 }).expect("append");
        wal.writer().append(&WalOp::SetThreshold { frac: 0.6 }).expect("append");
        assert_eq!(wal.writer().synced_seq(), 0, "nothing durable yet");
        let hub = ReplHub::start("127.0.0.1:0", wal, HubOpts::default()).expect("bind hub");

        let mut conn = TcpStream::connect(hub.local_addr()).expect("connect");
        conn.write_all(&Frame::Hello { last_seq: 2, need_snapshot: false }.encode())
            .expect("hello");
        wait_slots(&hub, 1);
        let st = hub.status().remove(0);
        assert_eq!(st.acked, 0, "an appended-but-unsynced claim must not pre-count as a vote");
        assert_eq!(st.enqueued, 2, "the stream still resumes from the claim, not a snapshot");
        // The catch-up sends no duplicates: straight to caught-up.
        let mut reader = BufReader::new(conn.try_clone().expect("clone"));
        match Frame::read_from(&mut reader).expect("read") {
            Some(Frame::CaughtUp { seq }) => assert_eq!(seq, 2),
            other => panic!("expected caught-up at the claim, got {other:?}"),
        }
        hub.shutdown();
    }

    #[test]
    fn quorum_fails_fast_without_a_majority_connected() {
        let hub = test_hub(
            "noquorum",
            HubOpts {
                level: AckLevel::Quorum,
                expect: 3,
                ack_timeout: Duration::from_secs(30),
                ..HubOpts::default()
            },
        );
        // 0 replicas connected: 1/3 nodes reachable, majority is 2.
        let t0 = Instant::now();
        let err = hub.wait_acked(1).expect_err("no quorum available");
        assert!(t0.elapsed() < Duration::from_secs(5), "must fail fast, not wait the timeout");
        assert!(err.contains("no-quorum"), "structured error, got: {err}");
        assert!(err.contains("1/3"), "should report reachable count, got: {err}");
        hub.shutdown();
    }

    #[test]
    fn quorum_is_satisfied_by_leader_plus_one_of_two_replicas() {
        let hub = test_hub(
            "quorum2",
            HubOpts {
                level: AckLevel::Quorum,
                expect: 3,
                ack_timeout: Duration::from_secs(10),
                ..HubOpts::default()
            },
        );
        let mut a = TcpStream::connect(hub.local_addr()).expect("connect a");
        a.write_all(&Frame::Hello { last_seq: 0, need_snapshot: false }.encode()).expect("hello");
        let mut b = TcpStream::connect(hub.local_addr()).expect("connect b");
        b.write_all(&Frame::Hello { last_seq: 0, need_snapshot: false }.encode()).expect("hello");
        wait_slots(&hub, 2);

        let op = WalOp::SetThreshold { frac: 0.5 };
        hub.publish(1, &op);
        // One replica acks; the leader's own fsync is the second vote of
        // the 2-of-3 majority.
        a.write_all(&Frame::Ack { seq: 1 }.encode()).expect("ack");
        hub.wait_acked(1).expect("leader + one replica is a majority of three");
        hub.shutdown();
    }
}
