//! Dense linear algebra for the FINGER basis: covariance + orthogonal
//! (block power) iteration. No LAPACK in the offline environment, so the
//! top-r eigenbasis of the residual second-moment matrix is computed with
//! a from-scratch subspace iteration — deterministic, and fast enough for
//! m up to ~1000 and r up to ~64 (one-time index-build cost).
//!
//! Paper hook: Proposition 3.1 — the optimal rank-r projection P for the
//! pairwise distance-distortion objective (Eq. 3) is the top-r left
//! singular basis of D_res, i.e. the top-r eigenvectors of
//! D_res D_resᵀ = Σᵢ x_i x_iᵀ over sampled residual vectors x_i.

use crate::core::distance::{dot, norm};
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;

/// Second-moment matrix  C = (1/N) Σ rows[i] rows[i]ᵀ  (m × m, symmetric).
/// Residual vectors are already mean-free by construction in FINGER, so
/// this is the covariance up to the usual centering nuance.
pub fn second_moment(rows: &Matrix) -> Matrix {
    let n = rows.rows();
    let m = rows.cols();
    let mut c = Matrix::zeros(m, m);
    if n == 0 {
        return c;
    }
    // Rank-1 accumulation; upper triangle then mirror.
    for i in 0..n {
        let x = rows.row(i);
        for a in 0..m {
            let xa = x[a];
            if xa == 0.0 {
                continue;
            }
            let crow = c.row_mut(a);
            for b in a..m {
                crow[b] += xa * x[b];
            }
        }
    }
    let inv = 1.0 / n as f32;
    for a in 0..m {
        for b in a..m {
            let v = c.row(a)[b] * inv;
            c.row_mut(a)[b] = v;
            c.row_mut(b)[a] = v;
        }
    }
    c
}

/// Modified Gram–Schmidt on the rows of `q` (in place). Returns per-row
/// norms before normalization (useful as Ritz-value estimates).
fn mgs_rows(q: &mut Matrix) -> Vec<f32> {
    let r = q.rows();
    let m = q.cols();
    let mut norms = vec![0.0f32; r];
    for i in 0..r {
        // Orthogonalize against previous rows.
        for j in 0..i {
            let (head, tail) = rows_split_mut(q, j, i);
            let coef = dot(tail, head);
            for k in 0..m {
                tail[k] -= coef * head[k];
            }
        }
        let ni = norm(q.row(i));
        norms[i] = ni;
        if ni > 1e-12 {
            let inv = 1.0 / ni;
            for v in q.row_mut(i) {
                *v *= inv;
            }
        } else {
            // Degenerate direction: re-randomize deterministically.
            let mut rng = Pcg32::with_stream(0xC0FFEE ^ i as u64, 17);
            for v in q.row_mut(i) {
                *v = rng.next_gaussian();
            }
            let ni2 = norm(q.row(i));
            let inv = 1.0 / ni2.max(1e-12);
            for v in q.row_mut(i) {
                *v *= inv;
            }
        }
    }
    norms
}

/// Split-borrow helper: returns (&row j, &mut row i), j < i.
fn rows_split_mut(m: &mut Matrix, j: usize, i: usize) -> (&[f32], &mut [f32]) {
    assert!(j < i);
    let cols = m.cols();
    let (lo, hi) = m.as_mut_slice().split_at_mut(i * cols);
    (&lo[j * cols..(j + 1) * cols], &mut hi[..cols])
}

/// Result of the eigen solve: rows of `basis` are orthonormal eigenvectors
/// (descending eigenvalue), `eigenvalues[i]` the matching Ritz values.
pub struct EigenBasis {
    pub basis: Matrix,
    pub eigenvalues: Vec<f32>,
}

/// Top-`r` eigenpairs of the symmetric matrix `c` via orthogonal iteration.
pub fn top_eigenvectors(c: &Matrix, r: usize, iters: usize, seed: u64) -> EigenBasis {
    let m = c.rows();
    assert_eq!(c.rows(), c.cols(), "symmetric matrix expected");
    let r = r.min(m);
    let mut q = Matrix::zeros(r, m);
    let mut rng = Pcg32::new(seed);
    for i in 0..r {
        for v in q.row_mut(i) {
            *v = rng.next_gaussian();
        }
    }
    mgs_rows(&mut q);
    let mut norms = vec![0.0f32; r];
    for _ in 0..iters {
        // Y = Q Cᵀ (rows of Q times symmetric C) — row-major friendly.
        let mut y = Matrix::zeros(r, m);
        for i in 0..r {
            let qi = q.row(i);
            let yi = y.row_mut(i);
            for a in 0..m {
                yi[a] = dot(qi, c.row(a));
            }
        }
        q = y;
        norms = mgs_rows(&mut q);
    }
    EigenBasis {
        basis: q,
        eigenvalues: norms,
    }
}

/// The FINGER projection (Prop. 3.1): rows of the returned matrix are the
/// top-r left singular directions of the residual collection (given as
/// rows of `residuals`, i.e. N × m). `P` has shape r × m; apply as P·x.
pub fn finger_projection(residuals: &Matrix, r: usize, seed: u64) -> EigenBasis {
    let c = second_moment(residuals);
    top_eigenvectors(&c, r, 40, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a matrix with known spectrum: C = Σ λ_i v_i v_iᵀ over an
    /// orthonormal set {v_i}.
    fn known_spectrum(m: usize, lambdas: &[f32], seed: u64) -> (Matrix, Matrix) {
        let mut q = Matrix::zeros(lambdas.len(), m);
        let mut rng = Pcg32::new(seed);
        for i in 0..lambdas.len() {
            for v in q.row_mut(i) {
                *v = rng.next_gaussian();
            }
        }
        mgs_rows(&mut q);
        let mut c = Matrix::zeros(m, m);
        for (i, &l) in lambdas.iter().enumerate() {
            let v = q.row(i).to_vec();
            for a in 0..m {
                for b in 0..m {
                    c.row_mut(a)[b] += l * v[a] * v[b];
                }
            }
        }
        (c, q)
    }

    #[test]
    fn recovers_dominant_eigenvectors() {
        let (c, q) = known_spectrum(24, &[10.0, 5.0, 1.0], 3);
        let eb = top_eigenvectors(&c, 2, 60, 7);
        for i in 0..2 {
            let overlap = dot(eb.basis.row(i), q.row(i)).abs();
            assert!(overlap > 0.99, "eigvec {i} overlap {overlap}");
        }
        assert!((eb.eigenvalues[0] - 10.0).abs() < 0.1);
        assert!((eb.eigenvalues[1] - 5.0).abs() < 0.1);
    }

    #[test]
    fn basis_is_orthonormal() {
        let (c, _) = known_spectrum(16, &[4.0, 3.0, 2.0, 1.0], 11);
        let eb = top_eigenvectors(&c, 4, 60, 5);
        for i in 0..4 {
            for j in 0..4 {
                let d = dot(eb.basis.row(i), eb.basis.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-3, "({i},{j}) = {d}");
            }
        }
    }

    #[test]
    fn second_moment_of_identity_rows() {
        // Rows e_0, e_1 -> C = diag(0.5, 0.5)
        let rows = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let c = second_moment(&rows);
        assert!((c.row(0)[0] - 0.5).abs() < 1e-6);
        assert!((c.row(1)[1] - 0.5).abs() < 1e-6);
        assert!(c.row(0)[1].abs() < 1e-6);
    }

    #[test]
    fn projection_captures_low_rank_structure() {
        // Residuals concentrated in a 2-D subspace of R^12 + small noise.
        let mut rng = Pcg32::new(42);
        let dir1: Vec<f32> = (0..12).map(|_| rng.next_gaussian()).collect();
        let dir2: Vec<f32> = (0..12).map(|_| rng.next_gaussian()).collect();
        let mut rows = Vec::new();
        for _ in 0..400 {
            let a = rng.next_gaussian() * 3.0;
            let b = rng.next_gaussian() * 2.0;
            let row: Vec<f32> = (0..12)
                .map(|k| a * dir1[k] + b * dir2[k] + 0.01 * rng.next_gaussian())
                .collect();
            rows.push(row);
        }
        let m = Matrix::from_rows(&rows);
        let eb = finger_projection(&m, 2, 1);
        // Projected energy should capture nearly all variance.
        let total: f32 = rows
            .iter()
            .map(|r| crate::core::distance::norm_sq(r))
            .sum::<f32>();
        let mut captured = 0.0f32;
        for row in &rows {
            for i in 0..2 {
                let c = dot(row, eb.basis.row(i));
                captured += c * c;
            }
        }
        assert!(captured / total > 0.995, "captured {}", captured / total);
    }
}
