//! Small, fast, dependency-free PRNGs.
//!
//! The offline build environment carries no `rand` crate, so we ship a
//! SplitMix64 (seeding / cheap streams) and a Pcg32 (the workhorse). Both
//! are deterministic across platforms, which every test and benchmark in
//! this repo relies on.

/// SplitMix64 — used to expand a single `u64` seed into streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — the main RNG.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire's rejection-free-ish method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached spare omitted for simplicity).
    pub fn next_gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k << n assumed; rejection).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            return idx;
        }
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.gen_range(n);
            if !out.contains(&v) {
                out.push(v);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::new(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(7);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Pcg32::new(3);
        for _ in 0..50 {
            let s = r.sample_distinct(100, 10);
            let mut u = s.clone();
            u.sort_unstable();
            u.dedup();
            assert_eq!(u.len(), 10);
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Pcg32::new(9);
        for n in [1usize, 2, 7, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn splitmix_streams_differ() {
        let mut s = SplitMix64::new(5);
        let a = s.next_u64();
        let b = s.next_u64();
        assert_ne!(a, b);
    }
}
