//! Cache-conscious query-time vector storage.
//!
//! [`Matrix`] stays the build/IO container; [`VectorStore`] is what the
//! search paths hold. It owns a copy of the dataset rows in 64-byte-aligned
//! storage with the dimension padded up to the 8-lane chunk width of the
//! distance kernels, plus precomputed per-row squared norms. Padding is
//! *numerically invisible*: the kernels in [`crate::core::distance`] fold
//! their tail elements into the same lane accumulators a zero-padded row
//! would use, so `l2_sq(q, m.row(i)) == l2_sq(qp, store.row(i))` bitwise
//! for a zero-padded query `qp`. The padded rows exist purely so the hot
//! loops see fixed-width, tail-free, aligned streams.

use crate::core::distance::{norm_sq, LANES};
use crate::core::matrix::Matrix;

/// Target start alignment in bytes (one x86 cache line).
const ALIGN_BYTES: usize = 64;
/// Worst-case leading f32 slots needed to reach [`ALIGN_BYTES`].
const ALIGN_SLACK: usize = ALIGN_BYTES / std::mem::size_of::<f32>();

/// Aligned, lane-padded, read-optimized row storage with per-row squared
/// norms. Append-only (online inserts push rows); rebuilt wholesale on
/// compaction.
///
/// Each search-bearing index owns its store (the mutable families extend
/// it in place on insert), so holding many wrappers over one dataset —
/// the conformance-suite shape — duplicates the padded rows per wrapper.
/// The L2 hot loop does not read `sq_norms` (L2 admission compares raw
/// squared distances); the norms are kept per the store's design for
/// norm-composed kernels (inner-product / cosine serving, where
/// `q·r` + `||r||²` combine) and are maintained in lockstep so that path
/// never needs a rescan.
pub struct VectorStore {
    /// `off` leading alignment slots, then `rows * padded` payload floats.
    buf: Vec<f32>,
    off: usize,
    rows: usize,
    cols: usize,
    /// `cols` rounded up to a multiple of [`LANES`].
    padded: usize,
    sq_norms: Vec<f32>,
}

fn pad_up(cols: usize) -> usize {
    cols.div_ceil(LANES.max(1)) * LANES
}

impl VectorStore {
    /// Copy `m`'s rows into padded aligned storage.
    pub fn from_matrix(m: &Matrix) -> VectorStore {
        let mut s = VectorStore::with_dims(m.rows(), m.cols());
        for i in 0..m.rows() {
            s.append_padded(m.row(i));
        }
        s
    }

    /// Empty store pre-sized for `rows` rows of `cols` columns.
    pub fn with_dims(rows: usize, cols: usize) -> VectorStore {
        let padded = pad_up(cols);
        let mut s = VectorStore {
            buf: Vec::new(),
            off: 0,
            rows: 0,
            cols,
            padded,
            sq_norms: Vec::with_capacity(rows),
        };
        s.reserve_rows(rows);
        s
    }

    /// Make room for `extra` more rows, re-aligning the payload start if
    /// the buffer had to move. Growth is amortized doubling, so the
    /// realignment copy costs O(1) per appended element.
    fn reserve_rows(&mut self, extra: usize) {
        let body = (self.rows + extra) * self.padded;
        if self.off + body <= self.buf.capacity() {
            return;
        }
        let cap = (body + ALIGN_SLACK).max(self.buf.capacity() * 2 + ALIGN_SLACK);
        let mut nb: Vec<f32> = Vec::with_capacity(cap);
        // Best-effort 64-byte start; `align_offset` may decline (then the
        // rows are still 32-byte aligned relative to each other because the
        // stride is a multiple of LANES floats).
        let noff = nb.as_ptr().align_offset(ALIGN_BYTES).min(ALIGN_SLACK);
        nb.resize(noff, 0.0);
        nb.extend_from_slice(&self.buf[self.off..self.off + self.rows * self.padded]);
        self.buf = nb;
        self.off = noff;
    }

    fn append_padded(&mut self, row: &[f32]) {
        self.buf.extend_from_slice(row);
        self.buf
            .resize(self.off + (self.rows + 1) * self.padded, 0.0);
        self.rows += 1;
        self.sq_norms.push(norm_sq(row));
    }

    /// Append one row (online insertion mirror of `Matrix::push_row`).
    /// An empty store adopts the first row's width.
    pub fn push_row(&mut self, row: &[f32]) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
            self.padded = pad_up(row.len());
        }
        assert_eq!(row.len(), self.cols, "row width mismatch");
        self.reserve_rows(1);
        self.append_padded(row);
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row stride in floats (`cols` padded to the kernel lane width).
    #[inline]
    pub fn padded_cols(&self) -> usize {
        self.padded
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Padded row `i` (length [`VectorStore::padded_cols`], zero tail).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        let s = self.off + i * self.padded;
        &self.buf[s..s + self.padded]
    }

    /// Logical row `i` (length [`VectorStore::cols`]).
    #[inline]
    pub fn row_logical(&self, i: usize) -> &[f32] {
        &self.row(i)[..self.cols]
    }

    /// Precomputed `||row_i||^2`.
    #[inline]
    pub fn sq_norm(&self, i: usize) -> f32 {
        self.sq_norms[i]
    }

    /// Zero-pad a query into `out` so it can be scored against padded rows
    /// (callers reuse a pooled buffer; see `SearchContext::qbuf`).
    #[inline]
    pub fn pad_query(&self, q: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(q.len(), self.cols, "query dim mismatch");
        out.clear();
        out.extend_from_slice(q);
        out.resize(self.padded, 0.0);
    }

    /// Payload bytes (padding included).
    pub fn nbytes(&self) -> usize {
        (self.rows * self.padded + self.sq_norms.len()) * std::mem::size_of::<f32>()
    }
}

/// u8 sibling of [`VectorStore`]: 64-byte-aligned, lane-padded SQ8 code
/// rows the quantized beam search traverses instead of the f32 rows.
/// Same layout discipline (aligned payload start, stride padded to the
/// kernel lane width, zero tail bytes) so the u8 kernel's hot loop is
/// tail-light and never splits a cache line; zero padding is exact for
/// the integer kernel because both sides pad with the same byte.
pub struct Sq8Store {
    buf: Vec<u8>,
    off: usize,
    rows: usize,
    cols: usize,
    padded: usize,
}

impl Sq8Store {
    /// Empty store for `cols`-wide code rows, pre-sized for `rows`.
    pub fn with_dims(rows: usize, cols: usize) -> Sq8Store {
        let mut s = Sq8Store {
            buf: Vec::new(),
            off: 0,
            rows: 0,
            cols,
            padded: pad_up(cols),
        };
        s.reserve_rows(rows);
        s
    }

    fn reserve_rows(&mut self, extra: usize) {
        let body = (self.rows + extra) * self.padded;
        if self.off + body <= self.buf.capacity() {
            return;
        }
        let cap = (body + ALIGN_BYTES).max(self.buf.capacity() * 2 + ALIGN_BYTES);
        let mut nb: Vec<u8> = Vec::with_capacity(cap);
        let noff = nb.as_ptr().align_offset(ALIGN_BYTES).min(ALIGN_BYTES);
        nb.resize(noff, 0);
        nb.extend_from_slice(&self.buf[self.off..self.off + self.rows * self.padded]);
        self.buf = nb;
        self.off = noff;
    }

    /// Append one code row (length `cols`; tail zero-padded to stride).
    pub fn push_row(&mut self, codes: &[u8]) {
        assert_eq!(codes.len(), self.cols, "code row width mismatch");
        self.reserve_rows(1);
        self.buf.extend_from_slice(codes);
        self.buf.resize(self.off + (self.rows + 1) * self.padded, 0);
        self.rows += 1;
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row stride in bytes (`cols` padded to the kernel lane width).
    #[inline]
    pub fn padded_cols(&self) -> usize {
        self.padded
    }

    /// Padded code row `i` (length [`Sq8Store::padded_cols`], zero tail).
    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        debug_assert!(i < self.rows);
        let s = self.off + i * self.padded;
        &self.buf[s..s + self.padded]
    }

    /// Logical code row `i` (length [`Sq8Store::cols`]).
    #[inline]
    pub fn row_logical(&self, i: usize) -> &[u8] {
        &self.row(i)[..self.cols]
    }

    /// Zero-pad query codes into `out` to the row stride.
    #[inline]
    pub fn pad_query(&self, codes: &[u8], out: &mut Vec<u8>) {
        debug_assert_eq!(codes.len(), self.cols, "query code dim mismatch");
        out.clear();
        out.extend_from_slice(codes);
        out.resize(self.padded, 0);
    }

    /// Payload bytes (padding included).
    pub fn nbytes(&self) -> usize {
        self.rows * self.padded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::l2_sq;
    use crate::core::rng::Pcg32;

    fn random_matrix(seed: u64, rows: usize, cols: usize) -> Matrix {
        let mut rng = Pcg32::new(seed);
        let mut m = Matrix::zeros(0, cols);
        for _ in 0..rows {
            let row: Vec<f32> = (0..cols).map(|_| rng.next_gaussian()).collect();
            m.push_row(&row);
        }
        m
    }

    #[test]
    fn rows_roundtrip_with_zero_tails() {
        for cols in [1usize, 7, 8, 9, 17, 100] {
            let m = random_matrix(cols as u64, 5, cols);
            let s = VectorStore::from_matrix(&m);
            assert_eq!(s.rows(), 5);
            assert_eq!(s.cols(), cols);
            assert_eq!(s.padded_cols() % LANES, 0);
            assert!(s.padded_cols() >= cols);
            for i in 0..5 {
                assert_eq!(s.row_logical(i), m.row(i));
                assert!(s.row(i)[cols..].iter().all(|&x| x == 0.0));
            }
        }
    }

    #[test]
    fn padding_is_numerically_invisible() {
        // The contract everything rests on: distances over padded rows and
        // padded queries are bitwise identical to the logical ones.
        let m = random_matrix(9, 6, 13);
        let s = VectorStore::from_matrix(&m);
        let mut rng = Pcg32::new(10);
        let q: Vec<f32> = (0..13).map(|_| rng.next_gaussian()).collect();
        let mut qp = Vec::new();
        s.pad_query(&q, &mut qp);
        for i in 0..6 {
            let logical = l2_sq(&q, m.row(i));
            let padded = l2_sq(&qp, s.row(i));
            assert_eq!(logical.to_bits(), padded.to_bits(), "row {i}");
        }
    }

    #[test]
    fn sq_norms_match_kernel() {
        let m = random_matrix(11, 8, 24);
        let s = VectorStore::from_matrix(&m);
        for i in 0..8 {
            assert_eq!(s.sq_norm(i).to_bits(), norm_sq(m.row(i)).to_bits());
        }
    }

    #[test]
    fn push_row_grows_and_keeps_old_rows() {
        let m = random_matrix(12, 3, 10);
        let mut s = VectorStore::from_matrix(&m);
        let snapshot: Vec<Vec<f32>> = (0..3).map(|i| s.row_logical(i).to_vec()).collect();
        let mut rng = Pcg32::new(13);
        for r in 0..40 {
            let row: Vec<f32> = (0..10).map(|_| rng.next_gaussian()).collect();
            s.push_row(&row);
            assert_eq!(s.rows(), 4 + r);
            assert_eq!(s.row_logical(3 + r), &row[..]);
        }
        for (i, want) in snapshot.iter().enumerate() {
            assert_eq!(s.row_logical(i), &want[..], "row {i} moved by growth");
        }
    }

    #[test]
    fn empty_store_adopts_first_row_width() {
        let mut s = VectorStore::from_matrix(&Matrix::zeros(0, 0));
        assert!(s.is_empty());
        s.push_row(&[1.0, 2.0, 3.0]);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.padded_cols(), LANES);
        assert_eq!(s.row_logical(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn start_is_cacheline_aligned() {
        let m = random_matrix(14, 64, 32);
        let s = VectorStore::from_matrix(&m);
        let addr = s.row(0).as_ptr() as usize;
        // Best-effort: align_offset may decline in exotic environments, but
        // on every real allocator this holds.
        assert_eq!(addr % ALIGN_BYTES, 0, "payload start not 64B-aligned");
    }

    #[test]
    fn nan_rows_survive_padding() {
        let mut m = Matrix::zeros(0, 5);
        m.push_row(&[1.0, f32::NAN, 3.0, 4.0, 5.0]);
        let s = VectorStore::from_matrix(&m);
        assert!(s.row_logical(0)[1].is_nan());
        assert!(s.row(0)[5..].iter().all(|&x| x == 0.0));
        assert!(s.sq_norm(0).is_nan());
    }

    #[test]
    fn sq8_store_rows_roundtrip_padded_and_aligned() {
        use crate::core::distance::u8_l2_sq;
        for cols in [1usize, 7, 8, 9, 17, 100] {
            let mut s = Sq8Store::with_dims(4, cols);
            let mut rng = Pcg32::new(cols as u64);
            let rows: Vec<Vec<u8>> = (0..4)
                .map(|_| (0..cols).map(|_| (rng.next_u32() & 0xFF) as u8).collect())
                .collect();
            for r in &rows {
                s.push_row(r);
            }
            assert_eq!(s.rows(), 4);
            assert_eq!(s.padded_cols() % LANES, 0);
            let mut qp = Vec::new();
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(s.row_logical(i), &r[..]);
                assert!(s.row(i)[cols..].iter().all(|&x| x == 0));
                // Padding invisibility for the integer kernel.
                s.pad_query(&rows[0], &mut qp);
                assert_eq!(u8_l2_sq(&qp, s.row(i)), u8_l2_sq(&rows[0], r), "row {i}");
            }
            assert_eq!(s.nbytes(), 4 * s.padded_cols());
        }
        let s = Sq8Store::with_dims(64, 32);
        let mut s = s;
        s.push_row(&[7u8; 32]);
        assert_eq!(s.row(0).as_ptr() as usize % ALIGN_BYTES, 0);
    }

    #[test]
    fn sq8_store_growth_keeps_old_rows() {
        let mut s = Sq8Store::with_dims(1, 10);
        s.push_row(&[1u8; 10]);
        let snapshot = s.row_logical(0).to_vec();
        for r in 0..40 {
            s.push_row(&[(r as u8).wrapping_mul(3); 10]);
        }
        assert_eq!(s.rows(), 41);
        assert_eq!(s.row_logical(0), &snapshot[..]);
        assert_eq!(s.row_logical(40), &[39u8 * 3; 10]);
    }
}
