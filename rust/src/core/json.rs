//! Minimal JSON — parser + writer.
//!
//! The offline build has no serde, and we need JSON in three places: the
//! AOT `manifest.json`, the router wire protocol, and machine-readable
//! bench output. This is a small, strict-enough recursive-descent parser
//! (UTF-8, no comments, f64 numbers) with a pretty/compact writer.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogates unsupported — replace.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 3.5 ").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":{"x":{"batch":8,"dims":[8,256],"kind":"rerank"}},"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let out = Json::str("tab\there").to_string();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("tab\there"));
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"format":"hlo-text","artifacts":{"rerank_b4_c64_d32_k5":
            {"kind":"rerank","batch":4,"cands":64,"dim":32,"k":5,
             "file":"rerank_b4_c64_d32_k5.hlo.txt",
             "inputs":[{"shape":[4,32],"dtype":"float32"}],
             "outputs":[{"shape":[4,5],"dtype":"f32"}]}}}"#;
        let v = Json::parse(src).unwrap();
        let art = v.get("artifacts").unwrap().get("rerank_b4_c64_d32_k5").unwrap();
        assert_eq!(art.get("batch").unwrap().as_usize(), Some(4));
        assert_eq!(
            art.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
