//! Tiny scoped parallel-for — no rayon offline, so index builds and query
//! sweeps fan out over std::thread::scope with a shared atomic work index.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (capped to keep the container
/// responsive).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(i)` for every i in 0..n across `threads` workers, work-stealing
/// via a shared atomic counter. `f` must be Sync; borrow everything it
/// needs immutably or through interior mutability / disjoint indexing.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map over 0..n in parallel collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_items_ok() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }
}
