//! Tiny scoped parallel-for — no rayon offline, so index builds and query
//! sweeps fan out over std::thread::scope with a shared atomic work index.
//!
//! The build plane relies on three properties of these primitives:
//!
//! * **Result placement is by index, never by completion order** —
//!   [`parallel_map`]/[`parallel_map_with`] write slot `i` for item `i`,
//!   so outputs are deterministic regardless of scheduling.
//! * **Per-worker state** ([`parallel_for_with`]) gives each thread its
//!   own scratch (e.g. a pooled `SearchContext`) without locking.
//! * **Disjoint writes** ([`DisjointSlice`]) let independent items fill
//!   non-overlapping ranges of one output buffer in place.
//!
//! None of them impose an execution order; determinism comes from the
//! callers computing each item as a pure function of frozen inputs.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default: the `FINGER_THREADS`
/// environment variable when set (≥ 1), else the available parallelism
/// capped to keep the container responsive.
pub fn default_threads() -> usize {
    if let Ok(raw) = std::env::var("FINGER_THREADS") {
        if let Ok(t) = raw.trim().parse::<usize>() {
            return t.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// `0` means "auto" everywhere a thread count is configurable.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        default_threads()
    } else {
        threads
    }
}

/// Claim size for the shared work counter: large enough that cheap bodies
/// don't serialize on the atomic (one `fetch_add` per ~8 items per worker
/// round), small enough that stragglers still steal work.
fn chunk_for(n: usize, threads: usize) -> usize {
    (n / (threads * 8)).clamp(1, 1024)
}

/// Run `f(i)` for every i in 0..n across `threads` workers, work-stealing
/// chunks of the index range via a shared atomic counter (a per-item
/// `fetch_add` was a contention hotspot for cheap bodies). `f` must be
/// Sync; borrow everything it needs immutably or through interior
/// mutability / disjoint indexing.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_with(n, threads, || (), |_, i| f(i));
}

/// [`parallel_for`] with per-worker state: each worker calls `init` once
/// and passes the value to every `f` invocation it runs — the pattern the
/// parallel index builds use for pooled per-thread `SearchContext`s.
pub fn parallel_for_with<S, I, F>(n: usize, threads: usize, init: I, f: F)
where
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        let mut state = init();
        for i in 0..n {
            f(&mut state, i);
        }
        return;
    }
    let chunk = chunk_for(n, threads);
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let mut state = init();
                loop {
                    let start = next.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        f(&mut state, i);
                    }
                }
            });
        }
    });
}

/// Map over 0..n in parallel collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_with(n, threads, || (), move |_, i| f(i))
}

/// [`parallel_map`] with per-worker state (see [`parallel_for_with`]).
pub fn parallel_map_with<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    parallel_for_with(n, threads, init, |state, i| {
        *slots[i].lock().unwrap() = Some(f(state, i));
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel_map slot unfilled"))
        .collect()
}

/// Shared-write view over a mutable slice for *provably disjoint* index
/// ranges: the parallel build passes one of these to workers that each
/// own distinct ranges (per-node table rows, per-edge blocks), avoiding
/// a mutex per element.
///
/// Safety contract: concurrent callers must never write overlapping
/// ranges; the type only checks bounds.
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _marker: PhantomData,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element.
    ///
    /// # Safety
    /// No other thread may concurrently access index `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        assert!(i < self.len, "DisjointSlice index out of bounds");
        *self.ptr.add(i) = value;
    }

    /// Mutable view of `start..start + len`.
    ///
    /// # Safety
    /// No other thread may concurrently access any index in the range.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &'a mut [T] {
        assert!(
            start <= self.len && len <= self.len - start,
            "DisjointSlice range out of bounds"
        );
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn covers_non_chunk_multiples() {
        // n deliberately not a multiple of the chunk size.
        for n in [1usize, 7, 97, 1023, 1025] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_for(n, 5, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "n={n}");
        }
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn map_with_worker_state_preserves_order() {
        // Each worker's state is private; results land by index.
        let out = parallel_map_with(
            500,
            8,
            || 0usize,
            |calls, i| {
                *calls += 1;
                i + *calls - *calls // i, but touches the state
            },
        );
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn single_thread_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_items_ok() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn disjoint_slice_parallel_fill() {
        let mut buf = vec![0u64; 4096];
        {
            let view = DisjointSlice::new(&mut buf);
            parallel_for(1024, 8, |i| unsafe {
                let chunk = view.slice_mut(i * 4, 4);
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (i * 4 + k) as u64;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn resolve_threads_zero_is_auto() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }
}
