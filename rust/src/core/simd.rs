//! Runtime-dispatched SIMD distance kernels.
//!
//! The scalar kernels in [`scalar`] are the *reference semantics*: 8 lane
//! accumulators, one fused multiply-add per element, tail elements folded
//! into the lane accumulators (never a separate scalar sum — that is what
//! makes zero-padding bitwise invisible, see [`crate::core::store`]), and
//! a fixed left-to-right horizontal reduction ([`scalar::hsum`]).
//!
//! The explicit-intrinsics backends reproduce *exactly* that accumulator
//! layout and reduction order:
//!
//! * **x86_64 AVX2+FMA** — one 8×f32 register per accumulator set, one
//!   `vfmadd` per chunk. Lane `l` of the register accumulates elements
//!   `base + l`, exactly like `acc[l]` in the scalar kernel, and
//!   `_mm256_fmadd_ps` performs the same single-rounding fused operation
//!   as `f32::mul_add`, so every lane is bitwise identical to the scalar
//!   path. The register is spilled to an array and the scalar tail-fold +
//!   `hsum` finish the job — shared code, so the backends cannot drift.
//! * **aarch64 NEON** — two 4×f32 registers per accumulator set (lanes
//!   0–3 and 4–7), `vfmaq_f32` per half-chunk, folded in the same order.
//!
//! Because the arithmetic is bitwise identical, every strict
//! `(dist, id)`-equality suite in the repo (ann_index, mutation_props,
//! shard_props, persist fixtures) passes unmodified under any backend;
//! `rust/tests/kernel_dispatch.rs` pins the kernels directly.
//!
//! ## Dispatch
//!
//! [`kernels()`] selects a backend **once** per process:
//!
//! | `FINGER_KERNEL` | behavior |
//! |---|---|
//! | unset / `auto`  | `avx2` if AVX2+FMA are detected (x86_64), `neon` on aarch64, else `scalar` |
//! | `scalar`        | force the portable fallback |
//! | `avx2` / `neon` | force that backend *if available*, else fall back to `scalar` |
//! | anything else   | warn on stderr, use `scalar` (fail-safe for typos) |
//!
//! The selected [`Kernels`] value is a table of plain `fn` pointers (the
//! per-call dispatch cost is one indirect call, amortized over an entire
//! row of FMAs). Loads are unaligned-tolerant (`loadu`/`vld1q`): the
//! padded [`VectorStore`](crate::core::store::VectorStore) rows start
//! 64-byte-aligned with a lane-multiple stride, so its loads never split
//! a cache line, while the unpadded `Matrix` path stays legal at any
//! address.

/// SIMD chunk width of every kernel; the padded row stride of
/// [`VectorStore`](crate::core::store::VectorStore) is a multiple of this.
pub const LANES: usize = 8;

/// Which kernel implementation [`kernels()`] selected at startup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable Rust (`f32::mul_add` lanes); also the forced fallback.
    Scalar,
    /// x86_64 AVX2 + FMA intrinsics (8×f32 per accumulator set).
    Avx2Fma,
    /// aarch64 NEON intrinsics (2×4×f32 per accumulator set).
    Neon,
}

impl KernelBackend {
    pub fn name(&self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Avx2Fma => "avx2-fma",
            KernelBackend::Neon => "neon",
        }
    }
}

/// The dispatched kernel table. All entries are bitwise-equivalent across
/// backends; `prefetch` is a no-op wherever the architecture has no hint
/// instruction (and under the forced scalar backend, which models the
/// "no intrinsics at all" configuration).
///
/// `u8_l2_sq` is the quantized-tier kernel: squared L2 between two u8
/// code rows as an exact integer sum. Integer addition is associative, so
/// every backend returns the *same* u32 by construction — the bitwise
/// contract costs nothing here. The u32 accumulator is exact for rows up
/// to 66 000 dims (65025 per element); far beyond any supported dim.
pub struct Kernels {
    pub backend: KernelBackend,
    pub l2_sq: fn(&[f32], &[f32]) -> f32,
    pub dot: fn(&[f32], &[f32]) -> f32,
    pub l2_sq_batch4: fn(&[f32], &[f32], &[f32], &[f32], &[f32]) -> [f32; 4],
    pub dot_batch4: fn(&[f32], &[f32], &[f32], &[f32], &[f32]) -> [f32; 4],
    /// Squared L2 between two u8 code rows (SQ8 traversal tier).
    pub u8_l2_sq: fn(&[u8], &[u8]) -> u32,
    /// Best-effort L1 read prefetch of the cache line at `p`.
    pub prefetch: fn(*const f32),
}

fn prefetch_noop(_p: *const f32) {}

const SCALAR_KERNELS: Kernels = Kernels {
    backend: KernelBackend::Scalar,
    l2_sq: scalar::l2_sq,
    dot: scalar::dot,
    l2_sq_batch4: scalar::l2_sq_batch4,
    dot_batch4: scalar::dot_batch4,
    u8_l2_sq: scalar::u8_l2_sq,
    prefetch: prefetch_noop,
};

fn select_backend() -> Kernels {
    let forced = std::env::var("FINGER_KERNEL").unwrap_or_default();
    match forced.as_str() {
        "scalar" => return SCALAR_KERNELS,
        // "auto"/"" = detect; "avx2"/"neon" limit detection to that
        // backend (unavailable ⇒ scalar below).
        "" | "auto" | "avx2" | "neon" => {}
        other => {
            // Fail safe: a typo'd value must not silently run SIMD while
            // the caller (e.g. the forced-scalar CI job) believes it is
            // testing the portable path.
            eprintln!(
                "warning: unrecognized FINGER_KERNEL='{other}' \
                 (expected scalar|avx2|neon|auto); using scalar"
            );
            return SCALAR_KERNELS;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if forced != "neon"
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
        {
            return Kernels {
                backend: KernelBackend::Avx2Fma,
                l2_sq: avx2::l2_sq,
                dot: avx2::dot,
                l2_sq_batch4: avx2::l2_sq_batch4,
                dot_batch4: avx2::dot_batch4,
                u8_l2_sq: avx2::u8_l2_sq,
                prefetch: avx2::prefetch,
            };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is baseline on aarch64.
        if forced != "avx2" {
            return Kernels {
                backend: KernelBackend::Neon,
                l2_sq: neon::l2_sq,
                dot: neon::dot,
                l2_sq_batch4: neon::l2_sq_batch4,
                dot_batch4: neon::dot_batch4,
                u8_l2_sq: neon::u8_l2_sq,
                prefetch: neon::prefetch,
            };
        }
    }
    SCALAR_KERNELS
}

/// The process-wide kernel table, selected on first use (reads
/// `FINGER_KERNEL`, then probes CPU features).
pub fn kernels() -> &'static Kernels {
    static TABLE: std::sync::OnceLock<Kernels> = std::sync::OnceLock::new();
    TABLE.get_or_init(select_backend)
}

/// Portable reference kernels. Every backend reuses [`scalar::hsum`] and
/// the tail-fold helpers below, so the one place that defines "which lane
/// does element `i` land in, and in what order do lanes reduce" is shared
/// — the scalar and SIMD paths cannot drift apart.
pub mod scalar {
    use super::LANES;

    /// Fold one full chunk of squared differences into the accumulators:
    /// `acc[l] += (a[base+l] - b[base+l])^2`, fused.
    #[inline(always)]
    fn fold_l2(acc: &mut [f32; LANES], a: &[f32], b: &[f32], base: usize) {
        // Indexed with constant offsets so the bounds checks hoist and the
        // body auto-vectorizes to packed sub+FMA even in this fallback.
        for l in 0..LANES {
            let d = a[base + l] - b[base + l];
            acc[l] = d.mul_add(d, acc[l]);
        }
    }

    /// Fold one full chunk of products: `acc[l] += a[base+l] * b[base+l]`.
    #[inline(always)]
    fn fold_dot(acc: &mut [f32; LANES], a: &[f32], b: &[f32], base: usize) {
        for l in 0..LANES {
            acc[l] = a[base + l].mul_add(b[base + l], acc[l]);
        }
    }

    /// Fold the tail `start..n` into the *lane accumulators* (element
    /// `start + l` lands in `acc[l]`) — the contract that makes
    /// zero-padding bitwise invisible. Shared by every backend.
    #[inline(always)]
    pub fn fold_l2_tail(acc: &mut [f32; LANES], a: &[f32], b: &[f32], start: usize, n: usize) {
        for (l, i) in (start..n).enumerate() {
            let d = a[i] - b[i];
            acc[l] = d.mul_add(d, acc[l]);
        }
    }

    /// Inner-product counterpart of [`fold_l2_tail`].
    #[inline(always)]
    pub fn fold_dot_tail(acc: &mut [f32; LANES], a: &[f32], b: &[f32], start: usize, n: usize) {
        for (l, i) in (start..n).enumerate() {
            acc[l] = a[i].mul_add(b[i], acc[l]);
        }
    }

    /// The horizontal reduction every kernel ends with: strict
    /// left-to-right lane order, so backends agree on the final bits.
    #[inline(always)]
    pub fn hsum(acc: &[f32; LANES]) -> f32 {
        acc.iter().sum()
    }

    /// Squared L2 distance (reference semantics; see module docs).
    #[inline]
    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = [0.0f32; LANES];
        for c in 0..chunks {
            fold_l2(&mut acc, a, b, c * LANES);
        }
        fold_l2_tail(&mut acc, a, b, chunks * LANES, n);
        hsum(&acc)
    }

    /// Inner product (reference semantics).
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = [0.0f32; LANES];
        for c in 0..chunks {
            fold_dot(&mut acc, a, b, c * LANES);
        }
        fold_dot_tail(&mut acc, a, b, chunks * LANES, n);
        hsum(&acc)
    }

    /// Squared L2 from one query to 4 rows. Each row runs through the
    /// *same* chunk/tail/hsum sequence as [`l2_sq`] against its own
    /// accumulator set, so every output lane is bitwise identical to the
    /// single-row kernel (the four hand-unrolled accumulator blocks this
    /// replaces are now one shared fold per row).
    pub fn l2_sq_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
        let n = q.len();
        debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
        let chunks = n / LANES;
        let mut acc = [[0.0f32; LANES]; 4];
        let rows = [r0, r1, r2, r3];
        for c in 0..chunks {
            let base = c * LANES;
            for (a, r) in acc.iter_mut().zip(rows) {
                fold_l2(a, q, r, base);
            }
        }
        let start = chunks * LANES;
        for (a, r) in acc.iter_mut().zip(rows) {
            fold_l2_tail(a, q, r, start, n);
        }
        [hsum(&acc[0]), hsum(&acc[1]), hsum(&acc[2]), hsum(&acc[3])]
    }

    /// Squared L2 between u8 code rows, exact in u32. Unlike the f32
    /// kernels there is no lane-order contract to uphold: integer sums
    /// are associative, so any evaluation order yields the same bits.
    /// Zero-padded tail lanes contribute 0 exactly (both rows pad with
    /// the same byte), mirroring the f32 padding invariant.
    #[inline]
    pub fn u8_l2_sq(a: &[u8], b: &[u8]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let mut sum = 0u32;
        for i in 0..a.len() {
            let d = a[i] as i32 - b[i] as i32;
            sum = sum.wrapping_add((d * d) as u32);
        }
        sum
    }

    /// Inner product from one query to 4 rows; per-row bitwise identical
    /// to [`dot`].
    pub fn dot_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
        let n = q.len();
        debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
        let chunks = n / LANES;
        let mut acc = [[0.0f32; LANES]; 4];
        let rows = [r0, r1, r2, r3];
        for c in 0..chunks {
            let base = c * LANES;
            for (a, r) in acc.iter_mut().zip(rows) {
                fold_dot(a, q, r, base);
            }
        }
        let start = chunks * LANES;
        for (a, r) in acc.iter_mut().zip(rows) {
            fold_dot_tail(a, q, r, start, n);
        }
        [hsum(&acc[0]), hsum(&acc[1]), hsum(&acc[2]), hsum(&acc[3])]
    }
}

/// AVX2+FMA backend. Safe wrappers around `#[target_feature]` inner
/// functions; only installed by [`kernels()`] after
/// `is_x86_feature_detected!` confirmed both features.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::scalar;
    use super::LANES;
    use std::arch::x86_64::*;

    /// Spill an 8-lane register to the scalar accumulator layout (lane 0
    /// at index 0), then finish with the shared tail-fold + `hsum`.
    /// Carries the same `target_feature` as its callers so the by-value
    /// `__m256` argument has a consistent ABI.
    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn finish_l2(v: __m256, a: &[f32], b: &[f32], start: usize, n: usize) -> f32 {
        let mut acc = [0.0f32; LANES];
        _mm256_storeu_ps(acc.as_mut_ptr(), v);
        scalar::fold_l2_tail(&mut acc, a, b, start, n);
        scalar::hsum(&acc)
    }

    #[target_feature(enable = "avx2,fma")]
    #[inline]
    unsafe fn finish_dot(v: __m256, a: &[f32], b: &[f32], start: usize, n: usize) -> f32 {
        let mut acc = [0.0f32; LANES];
        _mm256_storeu_ps(acc.as_mut_ptr(), v);
        scalar::fold_dot_tail(&mut acc, a, b, start, n);
        scalar::hsum(&acc)
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn l2_sq_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let base = c * LANES;
            let va = _mm256_loadu_ps(a.as_ptr().add(base));
            let vb = _mm256_loadu_ps(b.as_ptr().add(base));
            let d = _mm256_sub_ps(va, vb);
            acc = _mm256_fmadd_ps(d, d, acc);
        }
        finish_l2(acc, a, b, chunks * LANES, n)
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let base = c * LANES;
            let va = _mm256_loadu_ps(a.as_ptr().add(base));
            let vb = _mm256_loadu_ps(b.as_ptr().add(base));
            acc = _mm256_fmadd_ps(va, vb, acc);
        }
        finish_dot(acc, a, b, chunks * LANES, n)
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn l2_sq_batch4_impl(
        q: &[f32],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
    ) -> [f32; 4] {
        let n = q.len();
        debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
        let chunks = n / LANES;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        for c in 0..chunks {
            let base = c * LANES;
            // The query chunk is loaded once and amortized across four
            // independent accumulator sets (same ILP shape as the scalar
            // batch kernel, one register per row).
            let vq = _mm256_loadu_ps(q.as_ptr().add(base));
            let d0 = _mm256_sub_ps(vq, _mm256_loadu_ps(r0.as_ptr().add(base)));
            a0 = _mm256_fmadd_ps(d0, d0, a0);
            let d1 = _mm256_sub_ps(vq, _mm256_loadu_ps(r1.as_ptr().add(base)));
            a1 = _mm256_fmadd_ps(d1, d1, a1);
            let d2 = _mm256_sub_ps(vq, _mm256_loadu_ps(r2.as_ptr().add(base)));
            a2 = _mm256_fmadd_ps(d2, d2, a2);
            let d3 = _mm256_sub_ps(vq, _mm256_loadu_ps(r3.as_ptr().add(base)));
            a3 = _mm256_fmadd_ps(d3, d3, a3);
        }
        let start = chunks * LANES;
        [
            finish_l2(a0, q, r0, start, n),
            finish_l2(a1, q, r1, start, n),
            finish_l2(a2, q, r2, start, n),
            finish_l2(a3, q, r3, start, n),
        ]
    }

    #[target_feature(enable = "avx2,fma")]
    unsafe fn dot_batch4_impl(
        q: &[f32],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
    ) -> [f32; 4] {
        let n = q.len();
        debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
        let chunks = n / LANES;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        for c in 0..chunks {
            let base = c * LANES;
            let vq = _mm256_loadu_ps(q.as_ptr().add(base));
            a0 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r0.as_ptr().add(base)), a0);
            a1 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r1.as_ptr().add(base)), a1);
            a2 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r2.as_ptr().add(base)), a2);
            a3 = _mm256_fmadd_ps(vq, _mm256_loadu_ps(r3.as_ptr().add(base)), a3);
        }
        let start = chunks * LANES;
        [
            finish_dot(a0, q, r0, start, n),
            finish_dot(a1, q, r1, start, n),
            finish_dot(a2, q, r2, start, n),
            finish_dot(a3, q, r3, start, n),
        ]
    }

    // Safe dispatch shims: sound because kernels() only installs them
    // after runtime detection of avx2+fma.
    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        unsafe { l2_sq_impl(a, b) }
    }
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl(a, b) }
    }
    pub fn l2_sq_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
        unsafe { l2_sq_batch4_impl(q, r0, r1, r2, r3) }
    }
    pub fn dot_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
        unsafe { dot_batch4_impl(q, r0, r1, r2, r3) }
    }

    /// L1 read prefetch (`prefetcht0`); SSE-baseline, no detection needed.
    pub fn prefetch(p: *const f32) {
        unsafe { _mm_prefetch::<_MM_HINT_T0>(p as *const i8) }
    }

    /// u8 squared L2, 16 codes per iteration. `maddubs` would saturate
    /// (i16 products cap at 32767 < 255² = 65025), so each 16-byte half
    /// is widened to 16×i16 with `cvtepu8_epi16` and squared-accumulated
    /// via `madd_epi16` into 8 i32 lanes — exact integer arithmetic, so
    /// the result matches the scalar reference bit-for-bit.
    #[target_feature(enable = "avx2")]
    unsafe fn u8_l2_sq_impl(a: &[u8], b: &[u8]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 16;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let base = c * 16;
            let va = _mm256_cvtepu8_epi16(_mm_loadu_si128(a.as_ptr().add(base) as *const __m128i));
            let vb = _mm256_cvtepu8_epi16(_mm_loadu_si128(b.as_ptr().add(base) as *const __m128i));
            let d = _mm256_sub_epi16(va, vb);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(d, d));
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut sum = 0u32;
        for l in lanes {
            sum = sum.wrapping_add(l as u32);
        }
        for i in chunks * 16..n {
            let d = a[i] as i32 - b[i] as i32;
            sum = sum.wrapping_add((d * d) as u32);
        }
        sum
    }

    pub fn u8_l2_sq(a: &[u8], b: &[u8]) -> u32 {
        unsafe { u8_l2_sq_impl(a, b) }
    }
}

/// NEON backend (baseline on aarch64): two 4-lane registers stand in for
/// the 8-lane accumulator, spilled lanes 0–3 then 4–7 so the shared
/// tail-fold and `hsum` see the exact scalar layout.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::scalar;
    use super::LANES;
    use std::arch::aarch64::*;

    #[inline(always)]
    unsafe fn spill(lo: float32x4_t, hi: float32x4_t) -> [f32; LANES] {
        let mut acc = [0.0f32; LANES];
        vst1q_f32(acc.as_mut_ptr(), lo);
        vst1q_f32(acc.as_mut_ptr().add(4), hi);
        acc
    }

    pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        unsafe {
            let mut lo = vdupq_n_f32(0.0);
            let mut hi = vdupq_n_f32(0.0);
            for c in 0..chunks {
                let base = c * LANES;
                let d0 = vsubq_f32(
                    vld1q_f32(a.as_ptr().add(base)),
                    vld1q_f32(b.as_ptr().add(base)),
                );
                lo = vfmaq_f32(lo, d0, d0);
                let d1 = vsubq_f32(
                    vld1q_f32(a.as_ptr().add(base + 4)),
                    vld1q_f32(b.as_ptr().add(base + 4)),
                );
                hi = vfmaq_f32(hi, d1, d1);
            }
            let mut acc = spill(lo, hi);
            scalar::fold_l2_tail(&mut acc, a, b, chunks * LANES, n);
            scalar::hsum(&acc)
        }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / LANES;
        unsafe {
            let mut lo = vdupq_n_f32(0.0);
            let mut hi = vdupq_n_f32(0.0);
            for c in 0..chunks {
                let base = c * LANES;
                lo = vfmaq_f32(
                    lo,
                    vld1q_f32(a.as_ptr().add(base)),
                    vld1q_f32(b.as_ptr().add(base)),
                );
                hi = vfmaq_f32(
                    hi,
                    vld1q_f32(a.as_ptr().add(base + 4)),
                    vld1q_f32(b.as_ptr().add(base + 4)),
                );
            }
            let mut acc = spill(lo, hi);
            scalar::fold_dot_tail(&mut acc, a, b, chunks * LANES, n);
            scalar::hsum(&acc)
        }
    }

    pub fn l2_sq_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
        let n = q.len();
        debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
        let chunks = n / LANES;
        let rows = [r0, r1, r2, r3];
        unsafe {
            let mut lo = [vdupq_n_f32(0.0); 4];
            let mut hi = [vdupq_n_f32(0.0); 4];
            for c in 0..chunks {
                let base = c * LANES;
                let qlo = vld1q_f32(q.as_ptr().add(base));
                let qhi = vld1q_f32(q.as_ptr().add(base + 4));
                for t in 0..4 {
                    let dlo = vsubq_f32(qlo, vld1q_f32(rows[t].as_ptr().add(base)));
                    lo[t] = vfmaq_f32(lo[t], dlo, dlo);
                    let dhi = vsubq_f32(qhi, vld1q_f32(rows[t].as_ptr().add(base + 4)));
                    hi[t] = vfmaq_f32(hi[t], dhi, dhi);
                }
            }
            let start = chunks * LANES;
            let mut out = [0.0f32; 4];
            for t in 0..4 {
                let mut acc = spill(lo[t], hi[t]);
                scalar::fold_l2_tail(&mut acc, q, rows[t], start, n);
                out[t] = scalar::hsum(&acc);
            }
            out
        }
    }

    pub fn dot_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
        let n = q.len();
        debug_assert!(r0.len() == n && r1.len() == n && r2.len() == n && r3.len() == n);
        let chunks = n / LANES;
        let rows = [r0, r1, r2, r3];
        unsafe {
            let mut lo = [vdupq_n_f32(0.0); 4];
            let mut hi = [vdupq_n_f32(0.0); 4];
            for c in 0..chunks {
                let base = c * LANES;
                let qlo = vld1q_f32(q.as_ptr().add(base));
                let qhi = vld1q_f32(q.as_ptr().add(base + 4));
                for t in 0..4 {
                    lo[t] = vfmaq_f32(lo[t], qlo, vld1q_f32(rows[t].as_ptr().add(base)));
                    hi[t] = vfmaq_f32(hi[t], qhi, vld1q_f32(rows[t].as_ptr().add(base + 4)));
                }
            }
            let start = chunks * LANES;
            let mut out = [0.0f32; 4];
            for t in 0..4 {
                let mut acc = spill(lo[t], hi[t]);
                scalar::fold_dot_tail(&mut acc, q, rows[t], start, n);
                out[t] = scalar::hsum(&acc);
            }
            out
        }
    }

    /// u8 squared L2, 16 codes per iteration: absolute byte difference
    /// (`vabdq_u8`), widening square of each half (`vmull_u8` — products
    /// fit u16 since 255² = 65025), pairwise-accumulated into 4 u32
    /// lanes (`vpadalq_u16`). Exact integers ⇒ bitwise equal to scalar.
    pub fn u8_l2_sq(a: &[u8], b: &[u8]) -> u32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let chunks = n / 16;
        unsafe {
            let mut acc = vdupq_n_u32(0);
            for c in 0..chunks {
                let base = c * 16;
                let d = vabdq_u8(
                    vld1q_u8(a.as_ptr().add(base)),
                    vld1q_u8(b.as_ptr().add(base)),
                );
                let dlo = vget_low_u8(d);
                let dhi = vget_high_u8(d);
                acc = vpadalq_u16(acc, vmull_u8(dlo, dlo));
                acc = vpadalq_u16(acc, vmull_u8(dhi, dhi));
            }
            let mut sum = vaddvq_u32(acc);
            for i in chunks * 16..n {
                let d = a[i] as i32 - b[i] as i32;
                sum = sum.wrapping_add((d * d) as u32);
            }
            sum
        }
    }

    /// L1 read prefetch via `prfm pldl1keep` (no stable intrinsic yet).
    pub fn prefetch(p: *const f32) {
        unsafe {
            std::arch::asm!(
                "prfm pldl1keep, [{0}]",
                in(reg) p,
                options(nostack, readonly, preserves_flags)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    const LENS: &[usize] = &[0, 1, 7, 8, 9, 17, 100, 784];

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    /// Whatever backend got selected must agree with the scalar reference
    /// bit-for-bit on every length class (trivially true when the backend
    /// *is* scalar; the real check runs wherever AVX2/NEON exist — and in
    /// the dedicated `kernel_dispatch` integration suite).
    #[test]
    fn dispatched_kernels_bitwise_equal_scalar() {
        let ks = kernels();
        let mut rng = Pcg32::new(0xD15);
        for &n in LENS {
            let a = randv(&mut rng, n);
            let b = randv(&mut rng, n);
            assert_eq!(
                (ks.l2_sq)(&a, &b).to_bits(),
                scalar::l2_sq(&a, &b).to_bits(),
                "l2 n={n} backend={}",
                ks.backend.name()
            );
            assert_eq!(
                (ks.dot)(&a, &b).to_bits(),
                scalar::dot(&a, &b).to_bits(),
                "dot n={n} backend={}",
                ks.backend.name()
            );
            let rows: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut rng, n)).collect();
            let gl = (ks.l2_sq_batch4)(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            let sl = scalar::l2_sq_batch4(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            let gd = (ks.dot_batch4)(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            let sd = scalar::dot_batch4(&a, &rows[0], &rows[1], &rows[2], &rows[3]);
            for t in 0..4 {
                assert_eq!(gl[t].to_bits(), sl[t].to_bits(), "l2b4 n={n} row {t}");
                assert_eq!(gd[t].to_bits(), sd[t].to_bits(), "dotb4 n={n} row {t}");
            }
        }
    }

    /// u8 kernel parity across backends, including the saturation edge
    /// (all-255 vs all-0: a `maddubs`-style i16 path would clip 65025 to
    /// 32767 and fail here) and lengths straddling the 16-byte chunk.
    #[test]
    fn dispatched_u8_kernel_bitwise_equal_scalar() {
        let ks = kernels();
        let mut rng = Pcg32::new(0xC0DE5);
        for &n in LENS {
            let a: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xFF) as u8).collect();
            assert_eq!(
                (ks.u8_l2_sq)(&a, &b),
                scalar::u8_l2_sq(&a, &b),
                "u8 l2 n={n} backend={}",
                ks.backend.name()
            );
            let hi = vec![255u8; n];
            let lo = vec![0u8; n];
            let want = (n as u32).wrapping_mul(255 * 255);
            assert_eq!((ks.u8_l2_sq)(&hi, &lo), want, "saturation n={n}");
            assert_eq!(scalar::u8_l2_sq(&hi, &lo), want, "scalar saturation n={n}");
        }
    }

    #[test]
    fn backend_selection_is_stable() {
        let a = kernels().backend;
        let b = kernels().backend;
        assert_eq!(a, b);
        assert!(!a.name().is_empty());
    }

    #[test]
    fn prefetch_is_safe_on_any_address() {
        let v = vec![1.0f32; 64];
        (kernels().prefetch)(v.as_ptr());
        (kernels().prefetch)(unsafe { v.as_ptr().add(63) });
    }
}
