//! Scalar statistics used by FINGER's distribution matching (Algorithm 2)
//! and by the Figure 3/4 distribution analyses.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
}

/// Population variance (the paper's Algorithm 2 uses 1/N).
pub fn variance(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs) as f64;
    (xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64) as f32
}

pub fn stddev(xs: &[f32]) -> f32 {
    variance(xs).sqrt()
}

/// Fisher skewness g1 = m3 / m2^{3/2}.
pub fn skewness(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let n = xs.len() as f64;
    let m2 = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
    let m3 = xs.iter().map(|&x| (x as f64 - m).powi(3)).sum::<f64>() / n;
    if m2 <= 1e-18 {
        0.0
    } else {
        (m3 / m2.powf(1.5)) as f32
    }
}

/// Excess kurtosis g2 = m4 / m2^2 - 3.
pub fn excess_kurtosis(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs) as f64;
    let n = xs.len() as f64;
    let m2 = xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / n;
    let m4 = xs.iter().map(|&x| (x as f64 - m).powi(4)).sum::<f64>() / n;
    if m2 <= 1e-18 {
        0.0
    } else {
        (m4 / (m2 * m2) - 3.0) as f32
    }
}

/// Jarque–Bera normality statistic: JB = n/6 · (g1² + g2²/4).
/// Under normality JB ~ χ²(2); JB < ~6 means "not rejected at 5%".
/// Used by the Figure 3 analysis to quantify "distributes like a
/// Gaussian" beyond eyeballing the histogram.
pub fn jarque_bera(xs: &[f32]) -> f64 {
    if xs.len() < 8 {
        return 0.0;
    }
    let n = xs.len() as f64;
    let g1 = skewness(xs) as f64;
    let g2 = excess_kurtosis(xs) as f64;
    n / 6.0 * (g1 * g1 + g2 * g2 / 4.0)
}

/// Pearson correlation coefficient; 0 when either side is constant.
pub fn pearson(xs: &[f32], ys: &[f32]) -> f32 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs) as f64;
    let my = mean(ys) as f64;
    let mut sxy = 0.0f64;
    let mut sxx = 0.0f64;
    let mut syy = 0.0f64;
    for (&x, &y) in xs.iter().zip(ys) {
        let dx = x as f64 - mx;
        let dy = y as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    let denom = (sxx * syy).sqrt();
    if denom <= 1e-18 {
        0.0
    } else {
        (sxy / denom) as f32
    }
}

/// Equal-width histogram over [lo, hi]; values outside are clamped into the
/// edge bins. Returns bin counts.
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut out = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &x in xs {
        let mut b = ((x - lo) / w) as isize;
        if b < 0 {
            b = 0;
        }
        if b as usize >= bins {
            b = bins as isize - 1;
        }
        out[b as usize] += 1;
    }
    out
}

/// Percentile (nearest-rank) of an unsorted slice; p in [0, 100].
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    #[test]
    fn mean_var_basics() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-6);
        assert!((variance(&xs) - 1.25).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn gaussian_sample_moments() {
        let mut r = Pcg32::new(5);
        let xs: Vec<f32> = (0..60_000).map(|_| 2.0 + 0.5 * r.next_gaussian()).collect();
        assert!((mean(&xs) - 2.0).abs() < 0.02);
        assert!((stddev(&xs) - 0.5).abs() < 0.02);
        assert!(skewness(&xs).abs() < 0.05);
        assert!(excess_kurtosis(&xs).abs() < 0.1);
    }

    #[test]
    fn skewed_distribution_detected() {
        let mut r = Pcg32::new(6);
        // Exponential-ish: skewness ~ 2
        let xs: Vec<f32> = (0..40_000).map(|_| -(1.0 - r.next_f32()).ln()).collect();
        assert!(skewness(&xs) > 1.5);
    }

    #[test]
    fn jarque_bera_accepts_gaussian_rejects_uniform() {
        let mut r = Pcg32::new(8);
        let gauss: Vec<f32> = (0..20_000).map(|_| r.next_gaussian()).collect();
        let unif: Vec<f32> = (0..20_000).map(|_| r.next_f32()).collect();
        let jb_g = jarque_bera(&gauss);
        let jb_u = jarque_bera(&unif);
        assert!(jb_g < 10.0, "gaussian JB = {jb_g}");
        assert!(jb_u > 100.0, "uniform JB = {jb_u}"); // platykurtic: huge JB
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let ys: Vec<f32> = xs.iter().map(|&x| 3.0 * x + 1.0).collect();
        let zs: Vec<f32> = xs.iter().map(|&x| -x).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-5);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-5);
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let h = histogram(&[-5.0, 0.1, 0.2, 0.9, 5.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![3, 2]);
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
