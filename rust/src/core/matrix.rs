//! Row-major dense f32 matrix — the in-memory dataset container.

/// Dense row-major matrix of f32. Rows are data points.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            data: vec![0.0; rows * cols],
            rows,
            cols,
        }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { data, rows, cols }
    }

    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        if rows.is_empty() {
            return Self::zeros(0, 0);
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Self {
            data,
            rows: rows.len(),
            cols,
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn push_row(&mut self, row: &[f32]) {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// `self * v` for a dense vector (rows x cols) * (cols) -> (rows).
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| crate::core::distance::dot(self.row(i), v))
            .collect()
    }

    /// Memory footprint in bytes (data only).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_rows() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn matvec_correct() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let y = m.matvec(&[1.0, 1.0]);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn push_row_grows() {
        let mut m = Matrix::zeros(0, 0);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row(&[4.0, 5.0, 6.0]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_push_panics() {
        let mut m = Matrix::zeros(1, 2);
        m.push_row(&[1.0]);
    }
}
