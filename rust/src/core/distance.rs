//! Distance kernels — the innermost loops of the whole system.
//!
//! Hardware adaptation (DESIGN.md §4): the paper's AVX2 C++ uses explicit
//! 8-lane f32 intrinsics. Here the loops are written over fixed-width
//! chunks so LLVM reliably auto-vectorizes them; `l2_sq` and `dot` compile
//! to the same packed-FMA bodies on x86-64 and aarch64. Measured in
//! `rust/benches/distance.rs`.

/// Distance measure of a dataset. Angular datasets are normalized at load
/// time, after which L2 ordering equals cosine ordering (the paper does the
/// same: "angle measure can be obtained by firstly normalizing data
/// vectors").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    L2,
    /// Cosine / angular — vectors are pre-normalized; search uses L2.
    Angular,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "l2" | "L2" => Some(Metric::L2),
            "angular" | "cosine" | "ip" => Some(Metric::Angular),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Angular => "angular",
        }
    }
}

const LANES: usize = 8;

/// Squared L2 distance.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        // Indexed with constant offsets so the bounds checks hoist and the
        // body vectorizes to packed sub+FMA.
        for l in 0..LANES {
            let d = a[base + l] - b[base + l];
            acc[l] = d.mul_add(d, acc[l]);
        }
    }
    let mut sum = acc.iter().sum::<f32>();
    for i in chunks * LANES..n {
        let d = a[i] - b[i];
        sum = d.mul_add(d, sum);
    }
    sum
}

/// Inner product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut acc = [0.0f32; LANES];
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            acc[l] = a[base + l].mul_add(b[base + l], acc[l]);
        }
    }
    let mut sum = acc.iter().sum::<f32>();
    for i in chunks * LANES..n {
        sum = a[i].mul_add(b[i], sum);
    }
    sum
}

/// Squared norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// L2 norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    norm_sq(a).sqrt()
}

/// Cosine similarity; 0 for zero vectors.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    let denom = na * nb;
    if denom <= 1e-12 {
        0.0
    } else {
        dot(a, b) / denom
    }
}

/// Normalize in place to unit L2 norm; leaves zero vectors untouched.
pub fn normalize(a: &mut [f32]) {
    let n = norm(a);
    if n > 1e-12 {
        let inv = 1.0 / n;
        for x in a.iter_mut() {
            *x *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    fn naive_l2(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    fn naive_dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn l2_matches_naive_across_lengths() {
        let mut r = Pcg32::new(1);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 100, 784, 960] {
            let a: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
            let b: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
            let got = l2_sq(&a, &b);
            let want = naive_l2(&a, &b);
            assert!(
                (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "n={n} got={got} want={want}"
            );
        }
    }

    #[test]
    fn dot_matches_naive_across_lengths() {
        let mut r = Pcg32::new(2);
        for n in [0usize, 1, 5, 8, 13, 64, 100, 128] {
            let a: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
            let b: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
            let got = dot(&a, &b);
            let want = naive_dot(&a, &b);
            assert!(
                (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                "n={n} got={got} want={want}"
            );
        }
    }

    #[test]
    fn l2_identity_and_symmetry() {
        let mut r = Pcg32::new(3);
        let a: Vec<f32> = (0..96).map(|_| r.next_gaussian()).collect();
        let b: Vec<f32> = (0..96).map(|_| r.next_gaussian()).collect();
        assert_eq!(l2_sq(&a, &a), 0.0);
        assert!((l2_sq(&a, &b) - l2_sq(&b, &a)).abs() < 1e-6);
        assert!(l2_sq(&a, &b) > 0.0);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut r = Pcg32::new(4);
        let mut a: Vec<f32> = (0..50).map(|_| r.next_gaussian()).collect();
        normalize(&mut a);
        assert!((norm(&a) - 1.0).abs() < 1e-5);
        let mut z = vec![0.0f32; 10];
        normalize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cosine_bounds() {
        let mut r = Pcg32::new(5);
        for _ in 0..100 {
            let a: Vec<f32> = (0..32).map(|_| r.next_gaussian()).collect();
            let b: Vec<f32> = (0..32).map(|_| r.next_gaussian()).collect();
            let c = cosine(&a, &b);
            assert!((-1.0001..=1.0001).contains(&c));
        }
        let a = vec![1.0f32, 0.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn metric_parse() {
        assert_eq!(Metric::parse("l2"), Some(Metric::L2));
        assert_eq!(Metric::parse("angular"), Some(Metric::Angular));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Angular));
        assert_eq!(Metric::parse("nope"), None);
    }
}
