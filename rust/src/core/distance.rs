//! Distance kernels — the innermost loops of the whole system.
//!
//! Hardware adaptation (DESIGN.md §4): the paper's AVX2 C++ uses explicit
//! 8-lane f32 intrinsics. Since this PR the same is true here: every entry
//! point below dispatches through [`crate::core::simd::kernels`] to an
//! explicit-intrinsics backend selected once at startup (x86_64 AVX2+FMA,
//! aarch64 NEON, or the portable scalar reference — `FINGER_KERNEL=scalar`
//! forces the fallback). All backends share the same accumulator layout
//! and horizontal-reduction order, so the choice is **bitwise invisible**:
//! every strict `(dist, id)`-equality suite passes under any backend.
//!
//! ## The padded-store fast path
//!
//! Every kernel folds its tail elements (length not a multiple of
//! [`LANES`]) into the *lane accumulators* rather than a scalar follow-up
//! sum. That makes the result bitwise identical to running the kernel on
//! zero-padded inputs, which is exactly what
//! [`VectorStore`](crate::core::store::VectorStore) holds: rows padded to
//! the lane width in aligned storage. Search paths score padded queries
//! against padded rows, so the hot loop has no tail branch at all (and
//! the SIMD loads, unaligned-tolerant for the raw `Matrix` path, never
//! split a cache line on the 64-byte-aligned lane-multiple store rows).
//! The batched kernels ([`l2_sq_batch4`], [`dot_batch4`]) compute one
//! query against 4 rows per pass — the query chunk is loaded once and the
//! four independent accumulator sets keep the FMA ports busy. Each row of
//! a batch goes through the identical per-lane operation order as the
//! single-row kernel, so batched and scalar scoring produce bitwise-equal
//! distances (ties, NaNs and all) — pinned by tests here, in
//! `rust/tests/kernel_dispatch.rs`, and in `rust/tests/ann_index.rs`.
//! Measured in `rust/benches/distance.rs` and `finger bench hotpath`.

use crate::core::simd::kernels;

pub use crate::core::simd::{KernelBackend, LANES};

/// Distance measure of a dataset. Angular datasets are normalized at load
/// time, after which L2 ordering equals cosine ordering (the paper does the
/// same: "angle measure can be obtained by firstly normalizing data
/// vectors").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    L2,
    /// Cosine / angular — vectors are pre-normalized; search uses L2.
    Angular,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "l2" | "L2" => Some(Metric::L2),
            "angular" | "cosine" | "ip" => Some(Metric::Angular),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::L2 => "l2",
            Metric::Angular => "angular",
        }
    }
}

/// The kernel backend this process dispatched to (for logs/benchmarks).
pub fn kernel_backend() -> KernelBackend {
    kernels().backend
}

/// Squared L2 distance. Tail elements fold into the lane accumulators, so
/// zero-padding either input to a lane multiple does not change the result
/// bit (see the module docs).
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    (kernels().l2_sq)(a, b)
}

/// Inner product; same lane-folded tail contract as [`l2_sq`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (kernels().dot)(a, b)
}

/// Squared L2 from one query to 4 rows in one pass: each query chunk is
/// loaded once and amortized across four independent accumulator sets
/// (ILP), the win the graph beam search batches neighbor blocks for.
/// Each lane of the output is bitwise identical to
/// `l2_sq(q, r_i)` — same operations in the same order per row.
#[inline]
pub fn l2_sq_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
    (kernels().l2_sq_batch4)(q, r0, r1, r2, r3)
}

/// Inner product from one query to 4 rows in one pass; per-row bitwise
/// identical to [`dot`].
#[inline]
pub fn dot_batch4(q: &[f32], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) -> [f32; 4] {
    (kernels().dot_batch4)(q, r0, r1, r2, r3)
}

/// Squared L2 between two u8 code rows (SQ8 traversal tier). Exact
/// integer arithmetic, so — unlike the f32 kernels, where bitwise
/// equality is engineered — every backend agrees by construction.
#[inline]
pub fn u8_l2_sq(a: &[u8], b: &[u8]) -> u32 {
    (kernels().u8_l2_sq)(a, b)
}

/// Portable-reference u8 squared L2 (bypasses dispatch); bitwise
/// identical to [`u8_l2_sq`].
#[inline]
pub fn u8_l2_sq_scalar(a: &[u8], b: &[u8]) -> u32 {
    crate::core::simd::scalar::u8_l2_sq(a, b)
}

/// Portable-reference squared L2 (bypasses dispatch). Bitwise identical to
/// [`l2_sq`]; the `SearchParams::with_scalar_kernels` search paths call
/// this directly so "scalar mode" really runs the fallback kernels.
#[inline]
pub fn l2_sq_scalar(a: &[f32], b: &[f32]) -> f32 {
    crate::core::simd::scalar::l2_sq(a, b)
}

/// Portable-reference inner product (bypasses dispatch).
#[inline]
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    crate::core::simd::scalar::dot(a, b)
}

/// Best-effort L1 read-prefetch of the cache line holding `p`
/// (`prefetcht0` / `prfm pldl1keep` behind the same backend dispatch as
/// the kernels; a no-op under the forced scalar backend).
#[inline]
pub fn prefetch_l1(p: *const f32) {
    (kernels().prefetch)(p)
}

/// Squared norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f32 {
    dot(a, a)
}

/// L2 norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    norm_sq(a).sqrt()
}

/// Cosine similarity; 0 for zero vectors.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    let denom = na * nb;
    if denom <= 1e-12 {
        0.0
    } else {
        dot(a, b) / denom
    }
}

/// Normalize in place to unit L2 norm; leaves zero vectors untouched.
pub fn normalize(a: &mut [f32]) {
    let n = norm(a);
    if n > 1e-12 {
        let inv = 1.0 / n;
        for x in a.iter_mut() {
            *x *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    /// The lengths the batching/padding properties must survive: empty,
    /// sub-lane, exact-lane, lane+1, odd multi-chunk, and real data dims.
    const LENS: &[usize] = &[0, 1, 7, 8, 9, 17, 100, 784];

    fn naive_l2_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
            .sum()
    }

    fn naive_dot_f64(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    fn randv(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian()).collect()
    }

    fn pad(v: &[f32]) -> Vec<f32> {
        let mut p = v.to_vec();
        p.resize(v.len().div_ceil(LANES) * LANES, 0.0);
        p
    }

    #[test]
    fn l2_matches_f64_reference_across_lengths() {
        let mut r = Pcg32::new(1);
        for &n in LENS {
            let a = randv(&mut r, n);
            let b = randv(&mut r, n);
            let got = l2_sq(&a, &b) as f64;
            let want = naive_l2_f64(&a, &b);
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "n={n} got={got} want={want}"
            );
        }
    }

    #[test]
    fn dot_matches_f64_reference_across_lengths() {
        let mut r = Pcg32::new(2);
        for &n in LENS {
            let a = randv(&mut r, n);
            let b = randv(&mut r, n);
            let got = dot(&a, &b) as f64;
            let want = naive_dot_f64(&a, &b);
            assert!(
                (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "n={n} got={got} want={want}"
            );
        }
    }

    #[test]
    fn zero_padding_is_bitwise_invisible() {
        // The VectorStore contract: kernels on zero-padded inputs equal
        // the unpadded results bit-for-bit.
        let mut r = Pcg32::new(3);
        for &n in LENS {
            let a = randv(&mut r, n);
            let b = randv(&mut r, n);
            assert_eq!(
                l2_sq(&a, &b).to_bits(),
                l2_sq(&pad(&a), &pad(&b)).to_bits(),
                "l2 n={n}"
            );
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot(&pad(&a), &pad(&b)).to_bits(),
                "dot n={n}"
            );
        }
    }

    #[test]
    fn dispatched_equals_scalar_reference() {
        // The cross-backend contract in one line: whatever kernels() chose
        // is bit-for-bit the scalar fallback.
        let mut r = Pcg32::new(6);
        for &n in LENS {
            let a = randv(&mut r, n);
            let b = randv(&mut r, n);
            assert_eq!(l2_sq(&a, &b).to_bits(), l2_sq_scalar(&a, &b).to_bits(), "n={n}");
            assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn batch4_bitwise_equals_single_row_kernels() {
        let mut r = Pcg32::new(4);
        for &n in LENS {
            let q = randv(&mut r, n);
            let rows: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut r, n)).collect();
            let l2 = l2_sq_batch4(&q, &rows[0], &rows[1], &rows[2], &rows[3]);
            let ip = dot_batch4(&q, &rows[0], &rows[1], &rows[2], &rows[3]);
            for i in 0..4 {
                assert_eq!(l2[i].to_bits(), l2_sq(&q, &rows[i]).to_bits(), "l2 n={n} row {i}");
                assert_eq!(ip[i].to_bits(), dot(&q, &rows[i]).to_bits(), "dot n={n} row {i}");
            }
            // Padded-tail variant: score against padded rows with a padded
            // query — the combination the beam search actually runs.
            let qp = pad(&q);
            let rp: Vec<Vec<f32>> = rows.iter().map(|v| pad(v)).collect();
            let l2p = l2_sq_batch4(&qp, &rp[0], &rp[1], &rp[2], &rp[3]);
            for i in 0..4 {
                assert_eq!(l2p[i].to_bits(), l2_sq(&q, &rows[i]).to_bits(), "pad n={n} row {i}");
            }
        }
    }

    #[test]
    fn batch4_propagates_nan_rows_identically() {
        let mut r = Pcg32::new(5);
        let n = 17;
        let q = randv(&mut r, n);
        let mut rows: Vec<Vec<f32>> = (0..4).map(|_| randv(&mut r, n)).collect();
        rows[1][3] = f32::NAN; // one corrupt row must not poison its batchmates
        rows[3][16] = f32::NAN; // NaN in the lane-folded tail
        let got = l2_sq_batch4(&q, &rows[0], &rows[1], &rows[2], &rows[3]);
        for i in 0..4 {
            let single = l2_sq(&q, &rows[i]);
            assert_eq!(got[i].to_bits(), single.to_bits(), "row {i}");
        }
        assert!(got[1].is_nan() && got[3].is_nan());
        assert!(!got[0].is_nan() && !got[2].is_nan());
    }

    #[test]
    fn l2_identity_and_symmetry() {
        let mut r = Pcg32::new(3);
        let a: Vec<f32> = (0..96).map(|_| r.next_gaussian()).collect();
        let b: Vec<f32> = (0..96).map(|_| r.next_gaussian()).collect();
        assert_eq!(l2_sq(&a, &a), 0.0);
        assert!((l2_sq(&a, &b) - l2_sq(&b, &a)).abs() < 1e-6);
        assert!(l2_sq(&a, &b) > 0.0);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut r = Pcg32::new(4);
        let mut a: Vec<f32> = (0..50).map(|_| r.next_gaussian()).collect();
        normalize(&mut a);
        assert!((norm(&a) - 1.0).abs() < 1e-5);
        let mut z = vec![0.0f32; 10];
        normalize(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn cosine_bounds() {
        let mut r = Pcg32::new(5);
        for _ in 0..100 {
            let a: Vec<f32> = (0..32).map(|_| r.next_gaussian()).collect();
            let b: Vec<f32> = (0..32).map(|_| r.next_gaussian()).collect();
            let c = cosine(&a, &b);
            assert!((-1.0001..=1.0001).contains(&c));
        }
        let a = vec![1.0f32, 0.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn metric_parse() {
        assert_eq!(Metric::parse("l2"), Some(Metric::L2));
        assert_eq!(Metric::parse("angular"), Some(Metric::Angular));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Angular));
        assert_eq!(Metric::parse("nope"), None);
    }
}
