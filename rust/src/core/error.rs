//! Minimal error plumbing standing in for the `anyhow` crate — the build
//! environment is fully offline, so the crate carries no external
//! dependencies. Provides the same surface the runtime layer uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`ensure!`] macros, and the
//! [`Context`] extension trait.

use std::fmt;

/// A string-backed error with an optional chain of context frames.
/// Context added later wraps earlier messages, so `Display` prints
/// outermost-first, `: `-separated — matching `anyhow`'s `{:#}` format.
pub struct Error {
    msg: String,
    /// Context frames, innermost first.
    chain: Vec<String>,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            msg: m.to_string(),
            chain: Vec::new(),
        }
    }

    /// Wrap with an outer context frame.
    pub fn wrap(mut self, c: impl fmt::Display) -> Error {
        self.chain.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.chain.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to any displayable error (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

/// Build an [`Error`] from a format string (mirrors `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::core::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`] unless the condition holds (mirrors
/// `anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::core::error::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::core::error::Error::msg(format!($($arg)+)));
        }
    };
}

pub use crate::{anyhow, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        ensure!(1 + 1 == 3, "math is broken: {}", 1 + 1);
        Ok(7)
    }

    #[test]
    fn macro_and_context_chain() {
        let e = anyhow!("inner {}", 42);
        let e = Result::<()>::Err(e).context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner 42");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(format!("{e:?}"), "outer: inner 42");
    }

    #[test]
    fn ensure_returns_error() {
        let e = fails().unwrap_err();
        assert!(format!("{e}").contains("math is broken: 2"));
    }

    #[test]
    fn with_context_on_io() {
        let r: std::io::Result<()> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.with_context(|| format!("reading {}", "x.json")).unwrap_err();
        assert_eq!(format!("{e}"), "reading x.json: gone");
    }
}
