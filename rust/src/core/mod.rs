//! Core substrate: distances, RNG, dense matrices, eigen solves, scalar
//! statistics, and a minimal JSON codec.

pub mod distance;
pub mod error;
pub mod json;
pub mod linalg;
pub mod matrix;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod store;
pub mod threads;
