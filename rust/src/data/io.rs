//! Dataset and matrix IO: the classic `fvecs`/`ivecs` formats used by the
//! ANN-benchmarks ecosystem (SIFT/GIST distributions), plus a simple raw
//! binary matrix format for index persistence.
//!
//! fvecs layout: per row, a little-endian i32 dimension followed by `dim`
//! little-endian f32 values. ivecs is the same with i32 payloads.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::core::matrix::Matrix;

pub fn write_fvecs(path: &Path, m: &Matrix) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for i in 0..m.rows() {
        w.write_all(&(m.cols() as i32).to_le_bytes())?;
        for &v in m.row(i) {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn read_fvecs(path: &Path) -> io::Result<Matrix> {
    let mut r = BufReader::new(File::open(path)?);
    let mut out = Matrix::zeros(0, 0);
    let mut dim_buf = [0u8; 4];
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let dim = i32::from_le_bytes(dim_buf);
        if dim <= 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad fvecs dim"));
        }
        let mut row = vec![0f32; dim as usize];
        let mut buf = vec![0u8; dim as usize * 4];
        r.read_exact(&mut buf)?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            row[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        out.push_row(&row);
    }
    Ok(out)
}

pub fn write_ivecs(path: &Path, rows: &[Vec<u32>]) -> io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes())?;
        for &v in row {
            w.write_all(&(v as i32).to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn read_ivecs(path: &Path) -> io::Result<Vec<Vec<u32>>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut out = Vec::new();
    let mut dim_buf = [0u8; 4];
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let dim = i32::from_le_bytes(dim_buf);
        if dim < 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad ivecs dim"));
        }
        let mut buf = vec![0u8; dim as usize * 4];
        r.read_exact(&mut buf)?;
        let row: Vec<u32> = buf
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()) as u32)
            .collect();
        out.push(row);
    }
    Ok(out)
}

// -------------------------- raw binary writer/reader for persistence ----

/// Simple length-prefixed binary writer (little endian).
pub struct BinWriter<W: Write> {
    w: W,
}

impl<W: Write> BinWriter<W> {
    pub fn new(w: W) -> Self {
        Self { w }
    }

    pub fn u64(&mut self, v: u64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }

    pub fn f32_slice(&mut self, v: &[f32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn u32_slice(&mut self, v: &[u32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn u8_slice(&mut self, v: &[u8]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        self.w.write_all(v)
    }

    pub fn matrix(&mut self, m: &Matrix) -> io::Result<()> {
        self.u64(m.rows() as u64)?;
        self.u64(m.cols() as u64)?;
        self.f32_slice(m.as_slice())
    }
}

/// Matching reader.
pub struct BinReader<R: Read> {
    r: R,
}

/// Read exactly `len` untrusted bytes, growing the buffer in bounded
/// chunks: a corrupt length prefix (e.g. u64::MAX in a damaged index
/// file) then fails with `UnexpectedEof` once the stream runs out,
/// instead of aborting the process on a terabyte-sized up-front
/// allocation.
fn read_exact_len<R: Read>(r: &mut R, len: usize) -> io::Result<Vec<u8>> {
    const CHUNK: usize = 1 << 20;
    let mut buf = Vec::with_capacity(len.min(CHUNK));
    let mut remaining = len;
    while remaining > 0 {
        let take = remaining.min(CHUNK);
        let old = buf.len();
        buf.resize(old + take, 0);
        r.read_exact(&mut buf[old..])?;
        remaining -= take;
    }
    Ok(buf)
}

fn bad_len() -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, "implausible slice length")
}

impl<R: Read> BinReader<R> {
    pub fn new(r: R) -> Self {
        Self { r }
    }

    pub fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn f32_slice(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = n.checked_mul(4).ok_or_else(bad_len)?;
        let buf = read_exact_len(&mut self.r, bytes)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn u32_slice(&mut self) -> io::Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let bytes = n.checked_mul(4).ok_or_else(bad_len)?;
        let buf = read_exact_len(&mut self.r, bytes)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn u8_slice(&mut self) -> io::Result<Vec<u8>> {
        let n = self.u64()? as usize;
        read_exact_len(&mut self.r, n)
    }

    pub fn matrix(&mut self) -> io::Result<Matrix> {
        let rows = self.u64()? as usize;
        let cols = self.u64()? as usize;
        let numel = rows.checked_mul(cols).ok_or_else(bad_len)?;
        let data = self.f32_slice()?;
        if data.len() != numel {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "matrix shape"));
        }
        Ok(Matrix::from_vec(data, rows, cols))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("finger_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn fvecs_roundtrip() {
        let mut rng = Pcg32::new(1);
        let mut m = Matrix::zeros(0, 0);
        for _ in 0..17 {
            let row: Vec<f32> = (0..9).map(|_| rng.next_gaussian()).collect();
            m.push_row(&row);
        }
        let p = tmp("a.fvecs");
        write_fvecs(&p, &m).unwrap();
        let back = read_fvecs(&p).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1u32, 2, 3], vec![7, 8, 9]];
        let p = tmp("b.ivecs");
        write_ivecs(&p, &rows).unwrap();
        let back = read_ivecs(&p).unwrap();
        assert_eq!(rows, back);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_roundtrip() {
        let p = tmp("c.bin");
        {
            let mut w = BinWriter::new(std::fs::File::create(&p).unwrap());
            w.u64(42).unwrap();
            w.f32_slice(&[1.5, -2.5]).unwrap();
            w.u32_slice(&[9, 10, 11]).unwrap();
            w.u8_slice(&[1, 2, 255]).unwrap();
            w.matrix(&Matrix::from_rows(&[vec![1.0, 2.0]])).unwrap();
        }
        {
            let mut r = BinReader::new(std::fs::File::open(&p).unwrap());
            assert_eq!(r.u64().unwrap(), 42);
            assert_eq!(r.f32_slice().unwrap(), vec![1.5, -2.5]);
            assert_eq!(r.u32_slice().unwrap(), vec![9, 10, 11]);
            assert_eq!(r.u8_slice().unwrap(), vec![1, 2, 255]);
            assert_eq!(r.matrix().unwrap().row(0), &[1.0, 2.0]);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn huge_length_prefix_errors_instead_of_allocating() {
        // A corrupt length prefix must fail with an io::Error (EOF or
        // InvalidData), never attempt the multi-terabyte allocation.
        let mut evil = Vec::new();
        evil.extend_from_slice(&u64::MAX.to_le_bytes());
        evil.extend_from_slice(&[1, 2, 3, 4]);
        let mut r = BinReader::new(&evil[..]);
        assert!(r.u32_slice().is_err());
        let mut r = BinReader::new(&evil[..]);
        assert!(r.f32_slice().is_err());
        let mut r = BinReader::new(&evil[..]);
        assert!(r.u8_slice().is_err());
        // Matrix with overflowing rows*cols.
        let mut evil = Vec::new();
        evil.extend_from_slice(&(u64::MAX / 2).to_le_bytes()); // rows
        evil.extend_from_slice(&8u64.to_le_bytes()); // cols
        let mut r = BinReader::new(&evil[..]);
        assert!(r.matrix().is_err());
    }

    #[test]
    fn fvecs_rejects_corrupt() {
        let p = tmp("d.fvecs");
        std::fs::write(&p, [255u8, 255, 255, 255, 0, 0]).unwrap();
        assert!(read_fvecs(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
