//! Datasets: synthetic generators (paper-dataset stand-ins), fvecs/ivecs
//! IO, binary persistence, and exact ground truth.

pub mod groundtruth;
pub mod io;
pub mod persist;
pub mod synth;

pub use synth::{registry, spec_by_name, tiny, Dataset, SynthSpec};
