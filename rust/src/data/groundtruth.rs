//! Exact ground-truth K-nearest neighbors by threaded brute force.
//! Used for recall evaluation in every figure harness.

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::core::threads::{default_threads, parallel_map};

/// Exact top-k neighbors of each query (ascending distance). O(nq · n · m).
pub fn exact_knn(data: &Matrix, queries: &Matrix, k: usize) -> Vec<Vec<u32>> {
    let k = k.min(data.rows());
    parallel_map(queries.rows(), default_threads(), |qi| {
        let q = queries.row(qi);
        // Bounded max-heap of (dist, id).
        let mut heap: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
        for i in 0..data.rows() {
            let d = l2_sq(q, data.row(i));
            if heap.len() < k {
                heap.push((d, i as u32));
                if heap.len() == k {
                    heap.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                }
            } else if d < heap[0].0 {
                // Replace current worst, restore descending-by-dist order.
                heap[0] = (d, i as u32);
                let mut j = 0;
                while j + 1 < heap.len() && heap[j].0 < heap[j + 1].0 {
                    heap.swap(j, j + 1);
                    j += 1;
                }
            }
        }
        heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        heap.into_iter().map(|(_, id)| id).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::rng::Pcg32;

    fn naive_knn(data: &Matrix, q: &[f32], k: usize) -> Vec<u32> {
        let mut d: Vec<(f32, u32)> = (0..data.rows())
            .map(|i| (l2_sq(q, data.row(i)), i as u32))
            .collect();
        d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        d.into_iter().take(k).map(|(_, i)| i).collect()
    }

    #[test]
    fn matches_naive_sort() {
        let mut rng = Pcg32::new(2);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..300 {
            let row: Vec<f32> = (0..8).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let mut queries = Matrix::zeros(0, 0);
        for _ in 0..10 {
            let row: Vec<f32> = (0..8).map(|_| rng.next_gaussian()).collect();
            queries.push_row(&row);
        }
        let gt = exact_knn(&data, &queries, 10);
        for qi in 0..queries.rows() {
            assert_eq!(gt[qi], naive_knn(&data, queries.row(qi), 10), "query {qi}");
        }
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let data = Matrix::from_rows(&[vec![0.0, 0.0], vec![1.0, 1.0]]);
        let queries = Matrix::from_rows(&[vec![0.1, 0.0]]);
        let gt = exact_knn(&data, &queries, 10);
        assert_eq!(gt[0], vec![0, 1]);
    }

    #[test]
    fn self_query_returns_self_first() {
        let mut rng = Pcg32::new(3);
        let mut data = Matrix::zeros(0, 0);
        for _ in 0..50 {
            let row: Vec<f32> = (0..4).map(|_| rng.next_gaussian()).collect();
            data.push_row(&row);
        }
        let q = Matrix::from_rows(&[data.row(7).to_vec()]);
        let gt = exact_knn(&data, &q, 3);
        assert_eq!(gt[0][0], 7);
    }
}
