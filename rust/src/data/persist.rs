//! Index persistence: save/load the HNSW graph and the FINGER side-index
//! to a single binary file, so serving restarts skip the build (a
//! production requirement; Table 1 builds are minutes at full scale).
//!
//! Format (little-endian, length-prefixed; see `data::io::BinWriter`):
//!   magic "FNGR" u32 | version u64 | section tags.

use std::io;
use std::path::Path;

use crate::core::matrix::Matrix;
use crate::data::io::{BinReader, BinWriter};
use crate::finger::construct::{FingerIndex, FingerParams, MatchParams};
use crate::finger::search::FingerHnsw;
use crate::graph::adjacency::FlatAdj;
use crate::graph::hnsw::{Hnsw, HnswParams};

const MAGIC: u64 = 0x464E_4752; // "FNGR"
const VERSION: u64 = 2;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn write_adj<W: io::Write>(w: &mut BinWriter<W>, a: &FlatAdj) -> io::Result<()> {
    w.u64(a.n() as u64)?;
    w.u64(a.cap() as u64)?;
    // Store as (len, neighbor list) rows; dense copy keeps slot stability.
    for u in 0..a.n() as u32 {
        w.u32_slice(a.neighbors(u))?;
    }
    Ok(())
}

fn read_adj<R: io::Read>(r: &mut BinReader<R>) -> io::Result<FlatAdj> {
    let n = r.u64()? as usize;
    let cap = r.u64()? as usize;
    if cap > 1 << 20 || n > 1 << 32 {
        return Err(bad("implausible adjacency header"));
    }
    let mut a = FlatAdj::new(n, cap);
    for u in 0..n as u32 {
        let list = r.u32_slice()?;
        if list.len() > cap {
            return Err(bad("row exceeds capacity"));
        }
        a.set(u, &list);
    }
    Ok(a)
}

pub fn save_hnsw<W: io::Write>(w: &mut BinWriter<W>, h: &Hnsw) -> io::Result<()> {
    w.u64(h.params.m as u64)?;
    w.u64(h.params.ef_construction as u64)?;
    w.u64(h.params.seed)?;
    w.u64(h.params.heuristic as u64)?;
    w.u64(h.entry as u64)?;
    w.u64(h.max_level as u64)?;
    w.u32_slice(&h.levels.iter().map(|&l| l as u32).collect::<Vec<_>>())?;
    write_adj(w, &h.base)?;
    w.u64(h.upper.len() as u64)?;
    for l in &h.upper {
        write_adj(w, l)?;
    }
    Ok(())
}

pub fn load_hnsw<R: io::Read>(r: &mut BinReader<R>) -> io::Result<Hnsw> {
    let m = r.u64()? as usize;
    let ef_construction = r.u64()? as usize;
    let seed = r.u64()?;
    let heuristic = r.u64()? != 0;
    let entry = r.u64()? as u32;
    let max_level = r.u64()? as usize;
    let levels: Vec<u8> = r.u32_slice()?.into_iter().map(|v| v as u8).collect();
    let base = read_adj(r)?;
    let n_upper = r.u64()? as usize;
    let mut upper = Vec::with_capacity(n_upper);
    for _ in 0..n_upper {
        upper.push(read_adj(r)?);
    }
    Ok(Hnsw {
        params: HnswParams {
            m,
            ef_construction,
            seed,
            heuristic,
        },
        base,
        upper,
        levels,
        entry,
        max_level,
    })
}

pub fn save_finger<W: io::Write>(w: &mut BinWriter<W>, f: &FingerIndex) -> io::Result<()> {
    w.u64(f.rank as u64)?;
    w.matrix(&f.proj)?;
    let mp = &f.matching;
    w.f32_slice(&[mp.mu, mp.sigma, mp.mu_hat, mp.sigma_hat, mp.eps, mp.correlation])?;
    w.u64(f.params.max_svd_samples as u64)?;
    w.u64(f.params.distribution_matching as u64)?;
    w.u64(f.params.error_correction as u64)?;
    w.u64(f.params.seed)?;
    w.f32_slice(&f.c_norm)?;
    w.f32_slice(&f.c_sqnorm)?;
    w.f32_slice(&f.pc)?;
    w.f32_slice(&f.edge_proj)?;
    w.f32_slice(&f.edge_res_norm)?;
    w.f32_slice(&f.edge_pres_norm)?;
    w.f32_slice(&f.edge_pres)?;
    Ok(())
}

pub fn load_finger<R: io::Read>(r: &mut BinReader<R>) -> io::Result<FingerIndex> {
    let rank = r.u64()? as usize;
    let proj = r.matrix()?;
    let mv = r.f32_slice()?;
    if mv.len() != 6 {
        return Err(bad("matching params"));
    }
    let matching = MatchParams {
        mu: mv[0],
        sigma: mv[1],
        mu_hat: mv[2],
        sigma_hat: mv[3],
        eps: mv[4],
        correlation: mv[5],
    };
    let max_svd_samples = r.u64()? as usize;
    let distribution_matching = r.u64()? != 0;
    let error_correction = r.u64()? != 0;
    let seed = r.u64()?;
    Ok(FingerIndex {
        rank,
        proj,
        matching,
        params: FingerParams {
            rank,
            max_svd_samples,
            distribution_matching,
            error_correction,
            seed,
        },
        c_norm: r.f32_slice()?,
        c_sqnorm: r.f32_slice()?,
        pc: r.f32_slice()?,
        edge_proj: r.f32_slice()?,
        edge_res_norm: r.f32_slice()?,
        edge_pres_norm: r.f32_slice()?,
        edge_pres: r.f32_slice()?,
    })
}

/// Save a complete serving bundle: data matrix + HNSW + FINGER.
pub fn save_bundle(path: &Path, data: &Matrix, fh: &FingerHnsw) -> io::Result<()> {
    let mut w = BinWriter::new(io::BufWriter::new(std::fs::File::create(path)?));
    w.u64(MAGIC)?;
    w.u64(VERSION)?;
    w.matrix(data)?;
    save_hnsw(&mut w, &fh.hnsw)?;
    save_finger(&mut w, &fh.index)
}

/// Load a serving bundle saved by `save_bundle`.
pub fn load_bundle(path: &Path) -> io::Result<(Matrix, FingerHnsw)> {
    let mut r = BinReader::new(io::BufReader::new(std::fs::File::open(path)?));
    if r.u64()? != MAGIC {
        return Err(bad("not a finger-ann bundle"));
    }
    let version = r.u64()?;
    if version != VERSION {
        return Err(bad("unsupported bundle version"));
    }
    let data = r.matrix()?;
    let hnsw = load_hnsw(&mut r)?;
    let index = load_finger(&mut r)?;
    Ok((data, FingerHnsw { hnsw, index }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::synth::tiny;
    use crate::graph::visited::VisitedSet;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("finger_persist_{}_{name}", std::process::id()))
    }

    #[test]
    fn bundle_roundtrip_preserves_search_results() {
        let ds = tiny(401, 400, 24, Metric::L2);
        let fh = FingerHnsw::build(
            &ds.data,
            HnswParams { m: 8, ef_construction: 60, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let path = tmp("bundle.bin");
        save_bundle(&path, &ds.data, &fh).unwrap();
        let (data2, fh2) = load_bundle(&path).unwrap();
        assert_eq!(ds.data, data2);

        let mut vis = VisitedSet::new(ds.data.rows());
        for qi in 0..ds.queries.rows() {
            let q = ds.queries.row(qi);
            let a = fh.search(&ds.data, q, 10, 60, &mut vis, None);
            let b = fh2.search(&data2, q, 10, 60, &mut vis, None);
            let ai: Vec<u32> = a.iter().map(|n| n.id).collect();
            let bi: Vec<u32> = b.iter().map(|n| n.id).collect();
            assert_eq!(ai, bi, "query {qi}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("junk.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_bundle(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn adjacency_roundtrip_preserves_slots() {
        let ds = tiny(402, 100, 8, Metric::L2);
        let fh = FingerHnsw::build(
            &ds.data,
            HnswParams { m: 6, ef_construction: 30, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let path = tmp("adj.bin");
        save_bundle(&path, &ds.data, &fh).unwrap();
        let (_, fh2) = load_bundle(&path).unwrap();
        for u in 0..100u32 {
            assert_eq!(fh.hnsw.base.neighbors(u), fh2.hnsw.base.neighbors(u));
            for j in 0..fh.hnsw.base.degree(u) {
                let s = fh.hnsw.base.edge_slot(u, j);
                assert_eq!(fh.index.edge_proj[s], fh2.index.edge_proj[s]);
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
