//! Index persistence: tagged `save_index`/`load_index` for every
//! [`AnnIndex`](crate::index::AnnIndex) implementor, so serving restarts
//! skip the build (a production requirement; Table 1 builds are minutes at
//! full scale).
//!
//! Format (little-endian, length-prefixed; see `data::io::BinWriter`):
//!   magic "FNGR" u64 | version u64 | kind tag u64 | data matrix |
//!   family payload (written by the implementor's `save_payload`).
//!
//! Version history: v3 added the tagged single-index bundle; v4 adds the
//! sharded bundle (`TAG_SHARDED`): the payload is a shard manifest
//! (strategy, probe fraction, per-shard global-id maps + centroids)
//! followed by one nested tagged sub-index bundle per shard, each with
//! its own data matrix. v5 adds the **mutation section** for the mutable
//! families (bruteforce, hnsw, hnsw-finger, and the sharded parent): the
//! next-id watermark, the row→external-id map, and the tombstone list —
//! so a churned index serves the same live set after a restart. v3 and v4
//! files still load (their mutation state is the identity); sharded
//! bundles require v4+. v6 adds the **quantized-tier section** for the
//! families that can traverse on SQ8/PQ codes (bruteforce, hnsw,
//! hnsw-finger): a precision tag followed by the codec parameters and the
//! code rows, written *before* the mutation section so the live state
//! stays at the payload tail. v3–v5 files still load (no tier → F32).
//! Everything is fully validated at load — live-set coverage (every live
//! point in exactly one shard), ascending id maps, shard rows
//! bitwise-equal to the parent matrix, watermark/tombstone consistency —
//! so a corrupt or truncated file fails with `InvalidData` instead of
//! serving wrong ids.

use std::io;
use std::path::Path;
use std::sync::Arc;

use crate::data::io::{BinReader, BinWriter};
use crate::finger::construct::{FingerIndex, FingerParams, MatchParams};
use crate::finger::search::FingerHnsw;
use crate::graph::adjacency::FlatAdj;
use crate::graph::hnsw::{Hnsw, HnswParams};
use crate::graph::nndescent::{NnDescent, NnDescentParams};
use crate::graph::vamana::{Vamana, VamanaParams};
use crate::index::impls::{
    BruteForce, FingerHnswIndex, HnswIndex, IvfPqIndex, NnDescentIndex, VamanaIndex,
};
use crate::index::mutable::LiveIds;
use crate::index::sharded::{ShardParts, ShardStrategy, ShardedIndex};
use crate::index::AnnIndex;
use crate::core::store::Sq8Store;
use crate::quant::ivfpq::{IvfPq, IvfPqParams};
use crate::quant::kmeans::KMeans;
use crate::quant::pq::{Pq, PqParams};
use crate::quant::sq8::{Precision, QuantTier, Sq8Codec};

const MAGIC: u64 = 0x464E_4752; // "FNGR"
const VERSION: u64 = 6;
/// Oldest format still readable (v3 single-index bundles).
const MIN_VERSION: u64 = 3;

/// Stable family tags (never renumber).
pub const TAG_HNSW: u64 = 1;
pub const TAG_FINGER: u64 = 2;
pub const TAG_VAMANA: u64 = 3;
pub const TAG_NNDESCENT: u64 = 4;
pub const TAG_IVFPQ: u64 = 5;
pub const TAG_BRUTEFORCE: u64 = 6;
pub const TAG_SHARDED: u64 = 7;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn write_adj<W: io::Write>(w: &mut BinWriter<W>, a: &FlatAdj) -> io::Result<()> {
    w.u64(a.n() as u64)?;
    w.u64(a.cap() as u64)?;
    // Store as (len, neighbor list) rows; dense copy keeps slot stability.
    for u in 0..a.n() as u32 {
        w.u32_slice(a.neighbors(u))?;
    }
    Ok(())
}

fn read_adj<R: io::Read>(r: &mut BinReader<R>) -> io::Result<FlatAdj> {
    let n = r.u64()? as usize;
    let cap = r.u64()? as usize;
    if cap > 1 << 20 || n > 1 << 32 {
        return Err(bad("implausible adjacency header"));
    }
    let mut a = FlatAdj::new(n, cap);
    for u in 0..n as u32 {
        let list = r.u32_slice()?;
        if list.len() > cap {
            return Err(bad("row exceeds capacity"));
        }
        a.set(u, &list);
    }
    Ok(a)
}

// ------------------------------------------------------ family payloads

pub fn save_hnsw<W: io::Write>(w: &mut BinWriter<W>, h: &Hnsw) -> io::Result<()> {
    w.u64(h.params.m as u64)?;
    w.u64(h.params.ef_construction as u64)?;
    w.u64(h.params.seed)?;
    w.u64(h.params.heuristic as u64)?;
    w.u64(h.entry as u64)?;
    w.u64(h.max_level as u64)?;
    w.u32_slice(&h.levels.iter().map(|&l| l as u32).collect::<Vec<_>>())?;
    write_adj(w, &h.base)?;
    w.u64(h.upper.len() as u64)?;
    for l in &h.upper {
        write_adj(w, l)?;
    }
    Ok(())
}

pub fn load_hnsw<R: io::Read>(r: &mut BinReader<R>) -> io::Result<Hnsw> {
    let m = r.u64()? as usize;
    let ef_construction = r.u64()? as usize;
    let seed = r.u64()?;
    let heuristic = r.u64()? != 0;
    let entry = r.u64()? as u32;
    let max_level = r.u64()? as usize;
    let levels: Vec<u8> = r.u32_slice()?.into_iter().map(|v| v as u8).collect();
    let base = read_adj(r)?;
    let n_upper = r.u64()? as usize;
    let mut upper = Vec::with_capacity(n_upper);
    for _ in 0..n_upper {
        upper.push(read_adj(r)?);
    }
    Ok(Hnsw {
        params: HnswParams {
            m,
            ef_construction,
            seed,
            heuristic,
            threads: 0,
        },
        base,
        upper,
        levels,
        entry,
        max_level,
    })
}

pub fn save_finger<W: io::Write>(w: &mut BinWriter<W>, f: &FingerIndex) -> io::Result<()> {
    use crate::finger::construct::EDGE_SCALARS;
    w.u64(f.rank as u64)?;
    w.matrix(&f.proj)?;
    let mp = &f.matching;
    w.f32_slice(&[mp.mu, mp.sigma, mp.mu_hat, mp.sigma_hat, mp.eps, mp.correlation])?;
    w.u64(f.params.max_svd_samples as u64)?;
    w.u64(f.params.distribution_matching as u64)?;
    w.u64(f.params.error_correction as u64)?;
    w.u64(f.params.seed)?;
    w.f32_slice(&f.c_norm)?;
    w.f32_slice(&f.c_sqnorm)?;
    w.f32_slice(&f.pc)?;
    // The on-disk format (stable since v3) stores the four per-edge arrays
    // separately; in memory they live interleaved as SoA blocks.
    // De-interleave on write so old files and new files stay identical.
    let slots = f.edge_slots();
    let mut proj = Vec::with_capacity(slots);
    let mut res_norm = Vec::with_capacity(slots);
    let mut pres_norm = Vec::with_capacity(slots);
    let mut pres = Vec::with_capacity(slots * f.rank);
    for s in 0..slots {
        let b = f.edge_block(s);
        proj.push(b[0]);
        res_norm.push(b[1]);
        pres_norm.push(b[2]);
        pres.extend_from_slice(&b[EDGE_SCALARS..]);
    }
    w.f32_slice(&proj)?;
    w.f32_slice(&res_norm)?;
    w.f32_slice(&pres_norm)?;
    w.f32_slice(&pres)?;
    Ok(())
}

pub fn load_finger<R: io::Read>(r: &mut BinReader<R>) -> io::Result<FingerIndex> {
    use crate::finger::construct::EDGE_SCALARS;
    let rank = r.u64()? as usize;
    if rank == 0 || rank > crate::finger::approx::MAX_RANK {
        return Err(bad("implausible finger rank"));
    }
    let proj = r.matrix()?;
    let mv = r.f32_slice()?;
    if mv.len() != 6 {
        return Err(bad("matching params"));
    }
    let matching = MatchParams {
        mu: mv[0],
        sigma: mv[1],
        mu_hat: mv[2],
        sigma_hat: mv[3],
        eps: mv[4],
        correlation: mv[5],
    };
    let max_svd_samples = r.u64()? as usize;
    let distribution_matching = r.u64()? != 0;
    let error_correction = r.u64()? != 0;
    let seed = r.u64()?;
    let c_norm = r.f32_slice()?;
    let c_sqnorm = r.f32_slice()?;
    let pc = r.f32_slice()?;
    let edge_proj = r.f32_slice()?;
    let edge_res_norm = r.f32_slice()?;
    let edge_pres_norm = r.f32_slice()?;
    let edge_pres = r.f32_slice()?;
    let slots = edge_proj.len();
    if edge_res_norm.len() != slots
        || edge_pres_norm.len() != slots
        || edge_pres.len() != slots * rank
    {
        return Err(bad("finger per-edge arrays mismatch"));
    }
    // Interleave the legacy arrays into the in-memory SoA blocks.
    let stride = rank + EDGE_SCALARS;
    let mut edge = vec![0.0f32; slots * stride];
    for s in 0..slots {
        let b = &mut edge[s * stride..(s + 1) * stride];
        b[0] = edge_proj[s];
        b[1] = edge_res_norm[s];
        b[2] = edge_pres_norm[s];
        b[EDGE_SCALARS..].copy_from_slice(&edge_pres[s * rank..(s + 1) * rank]);
    }
    Ok(FingerIndex {
        rank,
        proj,
        matching,
        params: FingerParams {
            rank,
            max_svd_samples,
            distribution_matching,
            error_correction,
            seed,
            threads: 0,
        },
        c_norm,
        c_sqnorm,
        pc,
        edge,
    })
}

pub fn save_vamana<W: io::Write>(w: &mut BinWriter<W>, v: &Vamana) -> io::Result<()> {
    w.u64(v.params.r as u64)?;
    w.u64(v.params.l as u64)?;
    w.f32_slice(&[v.params.alpha])?;
    w.u64(v.params.seed)?;
    w.u64(v.params.passes as u64)?;
    w.u64(v.medoid as u64)?;
    write_adj(w, &v.adj)
}

pub fn load_vamana<R: io::Read>(r: &mut BinReader<R>) -> io::Result<Vamana> {
    let rr = r.u64()? as usize;
    let l = r.u64()? as usize;
    let av = r.f32_slice()?;
    if av.len() != 1 {
        return Err(bad("vamana alpha"));
    }
    let seed = r.u64()?;
    let passes = r.u64()? as usize;
    let medoid = r.u64()? as u32;
    let adj = read_adj(r)?;
    Ok(Vamana {
        params: VamanaParams {
            r: rr,
            l,
            alpha: av[0],
            seed,
            passes,
            threads: 0,
        },
        adj,
        medoid,
    })
}

pub fn save_nndescent<W: io::Write>(w: &mut BinWriter<W>, g: &NnDescent) -> io::Result<()> {
    w.u64(g.params.k as u64)?;
    w.u64(g.params.sample as u64)?;
    w.u64(g.params.iters as u64)?;
    w.u64(g.params.degree as u64)?;
    w.u64(g.params.seed)?;
    w.u64(g.params.prune as u64)?;
    w.u32_slice(&g.entry_probes)?;
    write_adj(w, &g.adj)
}

pub fn load_nndescent<R: io::Read>(r: &mut BinReader<R>) -> io::Result<NnDescent> {
    let k = r.u64()? as usize;
    let sample = r.u64()? as usize;
    let iters = r.u64()? as usize;
    let degree = r.u64()? as usize;
    let seed = r.u64()?;
    let prune = r.u64()? != 0;
    let entry_probes = r.u32_slice()?;
    if entry_probes.is_empty() {
        return Err(bad("nndescent entry probes"));
    }
    let adj = read_adj(r)?;
    Ok(NnDescent {
        params: NnDescentParams {
            k,
            sample,
            iters,
            degree,
            seed,
            prune,
            threads: 0,
        },
        adj,
        entry_probes,
    })
}

pub fn save_ivfpq<W: io::Write>(w: &mut BinWriter<W>, q: &IvfPq) -> io::Result<()> {
    w.u64(q.params.n_list as u64)?;
    w.u64(q.params.kmeans_iters as u64)?;
    w.u64(q.params.seed)?;
    w.matrix(&q.coarse.centroids)?;
    w.u64(q.lists.len() as u64)?;
    for list in &q.lists {
        w.u32_slice(list)?;
    }
    // PQ: params, per-subspace codebooks, column ranges, codes.
    w.u64(q.pq.params.n_sub as u64)?;
    w.u64(q.pq.params.nbits as u64)?;
    w.u64(q.pq.params.kmeans_iters as u64)?;
    w.u64(q.pq.params.seed)?;
    w.u64(q.pq.books.len() as u64)?;
    for b in &q.pq.books {
        w.matrix(&b.centroids)?;
    }
    let ranges: Vec<u32> = q
        .pq
        .ranges
        .iter()
        .flat_map(|&(lo, hi)| [lo as u32, hi as u32])
        .collect();
    w.u32_slice(&ranges)?;
    w.u8_slice(&q.pq.codes)?;
    w.u64(q.pq.n as u64)
}

pub fn load_ivfpq<R: io::Read>(r: &mut BinReader<R>) -> io::Result<IvfPq> {
    let n_list = r.u64()? as usize;
    let kmeans_iters = r.u64()? as usize;
    let seed = r.u64()?;
    let centroids = r.matrix()?;
    let n_lists = r.u64()? as usize;
    if n_lists != centroids.rows() {
        return Err(bad("ivfpq list/centroid mismatch"));
    }
    let mut lists = Vec::with_capacity(n_lists);
    for _ in 0..n_lists {
        lists.push(r.u32_slice()?);
    }
    let n_sub = r.u64()? as usize;
    let nbits = r.u64()? as usize;
    let pq_iters = r.u64()? as usize;
    let pq_seed = r.u64()?;
    let n_books = r.u64()? as usize;
    let mut books = Vec::with_capacity(n_books);
    for _ in 0..n_books {
        books.push(KMeans {
            centroids: r.matrix()?,
        });
    }
    let flat = r.u32_slice()?;
    if flat.len() != 2 * n_books {
        return Err(bad("ivfpq ranges"));
    }
    let ranges: Vec<(usize, usize)> = flat
        .chunks_exact(2)
        .map(|c| (c[0] as usize, c[1] as usize))
        .collect();
    let codes = r.u8_slice()?;
    let n = r.u64()? as usize;
    if codes.len() != n * n_books {
        return Err(bad("ivfpq code shape"));
    }
    let pq_params = PqParams {
        n_sub,
        nbits,
        kmeans_iters: pq_iters,
        seed: pq_seed,
    };
    Ok(IvfPq {
        params: IvfPqParams {
            n_list,
            pq: pq_params.clone(),
            kmeans_iters,
            seed,
        },
        coarse: KMeans { centroids },
        lists,
        pq: Pq {
            params: pq_params,
            books,
            ranges,
            codes,
            n,
        },
    })
}

/// Write a family's quantized-tier section (format v6). `None` writes
/// just the F32 precision tag. Callers emit this section *before* the
/// live section so the mutation state stays at the payload tail (the
/// corruption tests and external tooling compute offsets from the end).
pub fn save_quant<W: io::Write>(
    w: &mut BinWriter<W>,
    tier: Option<&QuantTier>,
) -> io::Result<()> {
    match tier {
        None => w.u64(Precision::F32.tag()),
        Some(QuantTier::Sq8 { codec, store }) => {
            w.u64(Precision::Sq8.tag())?;
            w.f32_slice(&codec.mins)?;
            w.f32_slice(&codec.maxs)?;
            w.f32_slice(&[codec.delta])?;
            // Logical (unpadded) codes, row-major; padding is rebuilt on
            // load so the on-disk bytes are lane-width independent.
            let mut codes = Vec::with_capacity(store.rows() * store.cols());
            for i in 0..store.rows() {
                codes.extend_from_slice(store.row_logical(i));
            }
            w.u8_slice(&codes)
        }
        Some(QuantTier::Pq { pq }) => {
            w.u64(Precision::Pq.tag())?;
            // Same layout as the PQ half of `save_ivfpq`.
            w.u64(pq.params.n_sub as u64)?;
            w.u64(pq.params.nbits as u64)?;
            w.u64(pq.params.kmeans_iters as u64)?;
            w.u64(pq.params.seed)?;
            w.u64(pq.books.len() as u64)?;
            for b in &pq.books {
                w.matrix(&b.centroids)?;
            }
            let ranges: Vec<u32> = pq
                .ranges
                .iter()
                .flat_map(|&(lo, hi)| [lo as u32, hi as u32])
                .collect();
            w.u32_slice(&ranges)?;
            w.u8_slice(&pq.codes)?;
            w.u64(pq.n as u64)
        }
    }
}

/// Read a family's v6 quantized-tier section; older versions have none
/// (every pre-v6 bundle is full-precision). Validates shapes against the
/// family's row count and dimensionality.
pub fn load_quant<R: io::Read>(
    r: &mut BinReader<R>,
    version: u64,
    n: usize,
    dim: usize,
) -> io::Result<Option<QuantTier>> {
    if version < 6 {
        return Ok(None);
    }
    let p = Precision::from_tag(r.u64()?).ok_or_else(|| bad("unknown precision tag"))?;
    match p {
        Precision::F32 => Ok(None),
        Precision::Sq8 => {
            let mins = r.f32_slice()?;
            let maxs = r.f32_slice()?;
            let dv = r.f32_slice()?;
            if mins.len() != dim || maxs.len() != dim || dv.len() != 1 {
                return Err(bad("sq8 codec shape mismatch"));
            }
            // `delta` is re-derived from the ranges; the stored copy is a
            // belt-and-braces consistency check, not a second source.
            let codec = Sq8Codec::from_ranges(mins, maxs);
            if codec.delta.to_bits() != dv[0].to_bits() {
                return Err(bad("sq8 delta disagrees with stored ranges"));
            }
            let codes = r.u8_slice()?;
            if codes.len() != n * dim {
                return Err(bad("sq8 code shape mismatch"));
            }
            let mut store = Sq8Store::with_dims(n, dim);
            for i in 0..n {
                store.push_row(&codes[i * dim..(i + 1) * dim]);
            }
            Ok(Some(QuantTier::Sq8 { codec, store }))
        }
        Precision::Pq => {
            let n_sub = r.u64()? as usize;
            let nbits = r.u64()? as usize;
            let kmeans_iters = r.u64()? as usize;
            let seed = r.u64()?;
            let n_books = r.u64()? as usize;
            let mut books = Vec::with_capacity(n_books);
            for _ in 0..n_books {
                books.push(KMeans { centroids: r.matrix()? });
            }
            let flat = r.u32_slice()?;
            if flat.len() != 2 * n_books {
                return Err(bad("pq tier ranges"));
            }
            let ranges: Vec<(usize, usize)> = flat
                .chunks_exact(2)
                .map(|c| (c[0] as usize, c[1] as usize))
                .collect();
            for &(lo, hi) in &ranges {
                if lo > hi || hi > dim {
                    return Err(bad("pq tier subspace range out of bounds"));
                }
            }
            let codes = r.u8_slice()?;
            let pn = r.u64()? as usize;
            if pn != n || codes.len() != n * n_books {
                return Err(bad("pq tier code shape mismatch"));
            }
            Ok(Some(QuantTier::Pq {
                pq: Pq {
                    params: PqParams { n_sub, nbits, kmeans_iters, seed },
                    books,
                    ranges,
                    codes,
                    n,
                },
            }))
        }
    }
}

// ---------------------------------------------------- load-time validation
//
// Family loaders only check shapes they can see locally; `load_index`
// additionally validates every stored node id against the data matrix, so
// a corrupt file fails with `InvalidData` at load instead of panicking
// out-of-bounds on the first query.

fn check_id(id: u32, n: usize) -> io::Result<()> {
    if id as usize >= n {
        return Err(bad("node id out of range"));
    }
    Ok(())
}

fn check_adj(a: &FlatAdj, n: usize) -> io::Result<()> {
    if a.n() != n {
        return Err(bad("adjacency size mismatch"));
    }
    for u in 0..n as u32 {
        if a.neighbors(u).iter().any(|&v| v as usize >= n) {
            return Err(bad("edge id out of range"));
        }
    }
    Ok(())
}

fn validate_hnsw(h: &Hnsw, n: usize) -> io::Result<()> {
    check_id(h.entry, n)?;
    if h.levels.len() != n {
        return Err(bad("levels length mismatch"));
    }
    check_adj(&h.base, n)?;
    for l in &h.upper {
        check_adj(l, n)?;
    }
    Ok(())
}

fn validate_finger(f: &FingerIndex, h: &Hnsw, n: usize) -> io::Result<()> {
    if f.rank == 0 || f.rank > crate::finger::approx::MAX_RANK {
        return Err(bad("implausible finger rank"));
    }
    if f.c_norm.len() != n || f.c_sqnorm.len() != n || f.pc.len() != n * f.rank {
        return Err(bad("finger per-node arrays mismatch"));
    }
    let slots = h.base.total_slots();
    if f.edge.len() != slots * f.edge_stride() {
        return Err(bad("finger per-edge table mismatch"));
    }
    Ok(())
}

fn validate_ivfpq(q: &IvfPq, n: usize, dim: usize) -> io::Result<()> {
    for list in &q.lists {
        for &id in list {
            check_id(id, n)?;
        }
    }
    if q.pq.n != n {
        return Err(bad("pq row count mismatch"));
    }
    for &(lo, hi) in &q.pq.ranges {
        if lo > hi || hi > dim {
            return Err(bad("pq subspace range out of bounds"));
        }
    }
    Ok(())
}

// ------------------------------------------------------- tagged bundles

/// Fsync a directory so a just-renamed entry survives power loss.
/// Best-effort: some filesystems refuse directory fsync, and the rename
/// itself is already atomic, so failures are swallowed.
pub fn sync_dir(dir: &Path) {
    let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
    if let Ok(d) = std::fs::File::open(dir) {
        d.sync_all().ok();
    }
}

/// Save any `AnnIndex` implementor: header + data matrix + family payload.
///
/// Crash-safe: the bundle is written to `<path>.tmp`, fsynced, then
/// atomically renamed over `path` (and the parent directory fsynced), so
/// a crash at any point leaves either the old complete bundle or the new
/// one — never a torn mix, and never a destroyed previous copy.
pub fn save_index(path: &Path, index: &dyn AnnIndex) -> io::Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    let written = write_bundle(&tmp, index).and_then(|()| std::fs::rename(&tmp, path));
    if let Err(e) = written {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    sync_dir(path.parent().unwrap_or_else(|| Path::new(".")));
    Ok(())
}

/// Write the bundle bytes to `tmp` and fsync them (the first half of the
/// crash-safe save; the atomic rename happens in [`save_index`]).
fn write_bundle(tmp: &Path, index: &dyn AnnIndex) -> io::Result<()> {
    let mut file = io::BufWriter::new(std::fs::File::create(tmp)?);
    {
        let sink: &mut dyn io::Write = &mut file;
        write_bundle_into(sink, index)?;
    }
    let file = file.into_inner().map_err(|e| e.into_error())?;
    file.sync_all()
}

/// One bundle serialization, shared by the on-disk and in-memory paths
/// so the bytes cannot drift between them.
fn write_bundle_into(sink: &mut dyn io::Write, index: &dyn AnnIndex) -> io::Result<()> {
    let mut w = BinWriter::new(sink);
    w.u64(MAGIC)?;
    w.u64(VERSION)?;
    w.u64(index.kind_tag())?;
    w.matrix(index.data())?;
    index.save_payload(&mut w)
}

/// Serialize a bundle into memory: exactly the bytes [`save_index`]
/// would write. Replication snapshots ship these verbatim, and the
/// `FINGERPRINT` verb hashes them — byte-identity of this serialization
/// is the divergence check.
pub fn bundle_to_vec(index: &dyn AnnIndex) -> io::Result<Vec<u8>> {
    let mut out: Vec<u8> = Vec::new();
    {
        let sink: &mut dyn io::Write = &mut out;
        write_bundle_into(sink, index)?;
    }
    Ok(out)
}

/// Load an index saved by [`save_index`], dispatching on the kind tag.
pub fn load_index(path: &Path) -> io::Result<Box<dyn AnnIndex>> {
    load_bundle(&mut BinReader::new(io::BufReader::new(std::fs::File::open(path)?)))
}

/// Load an index from in-memory bundle bytes (a received replication
/// snapshot) with the same validation as [`load_index`].
pub fn load_index_from_slice(bytes: &[u8]) -> io::Result<Box<dyn AnnIndex>> {
    load_bundle(&mut BinReader::new(bytes))
}

fn load_bundle<R: io::Read>(r: &mut BinReader<R>) -> io::Result<Box<dyn AnnIndex>> {
    if r.u64()? != MAGIC {
        return Err(bad("not a finger-ann index file"));
    }
    let version = r.u64()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        return Err(bad("unsupported index version"));
    }
    let tag = r.u64()?;
    let data = Arc::new(r.matrix()?);
    if tag == TAG_SHARDED {
        if version < 4 {
            return Err(bad("sharded bundles require format v4"));
        }
        return Ok(Box::new(load_sharded(r, data, version)?));
    }
    load_family(tag, data, r, version).map(|(index, _)| index)
}

/// Read a family's v5 mutation section; older versions get the identity
/// mapping (everything live, watermark == row count).
fn load_live<R: io::Read>(r: &mut BinReader<R>, version: u64, n: usize) -> io::Result<LiveIds> {
    if version >= 5 {
        LiveIds::load(r, n)
    } else {
        Ok(LiveIds::fresh(n))
    }
}

/// Load + validate one non-sharded family payload (the body shared by the
/// top-level loader and each nested shard bundle). Also returns the
/// family's mutation state so the sharded loader can cross-check its
/// manifest against each shard's live set.
fn load_family<R: io::Read>(
    tag: u64,
    data: Arc<crate::core::matrix::Matrix>,
    r: &mut BinReader<R>,
    version: u64,
) -> io::Result<(Box<dyn AnnIndex>, LiveIds)> {
    let n = data.rows();
    Ok(match tag {
        TAG_HNSW => {
            let hnsw = load_hnsw(r)?;
            validate_hnsw(&hnsw, n)?;
            let quant = load_quant(r, version, n, data.cols())?;
            let live = load_live(r, version, n)?;
            (
                Box::new(
                    HnswIndex::from_parts(data, hnsw)
                        .with_quant(quant)
                        .with_live(live.clone()),
                ),
                live,
            )
        }
        TAG_FINGER => {
            let hnsw = load_hnsw(r)?;
            let index = load_finger(r)?;
            validate_hnsw(&hnsw, n)?;
            validate_finger(&index, &hnsw, n)?;
            let quant = load_quant(r, version, n, data.cols())?;
            let live = load_live(r, version, n)?;
            (
                Box::new(
                    FingerHnswIndex::from_parts(data, FingerHnsw { hnsw, index })
                        .with_quant(quant)
                        .with_live(live.clone()),
                ),
                live,
            )
        }
        TAG_VAMANA => {
            let v = load_vamana(r)?;
            check_id(v.medoid, n)?;
            check_adj(&v.adj, n)?;
            (Box::new(VamanaIndex::from_parts(data, v)), LiveIds::fresh(n))
        }
        TAG_NNDESCENT => {
            let g = load_nndescent(r)?;
            for &p in &g.entry_probes {
                check_id(p, n)?;
            }
            check_adj(&g.adj, n)?;
            (
                Box::new(NnDescentIndex::from_parts(data, g)),
                LiveIds::fresh(n),
            )
        }
        TAG_IVFPQ => {
            let q = load_ivfpq(r)?;
            validate_ivfpq(&q, n, data.cols())?;
            (Box::new(IvfPqIndex::from_parts(data, q)), LiveIds::fresh(n))
        }
        TAG_BRUTEFORCE => {
            let quant = load_quant(r, version, n, data.cols())?;
            let live = load_live(r, version, n)?;
            (
                Box::new(BruteForce::new(data).with_quant(quant).with_live(live.clone())),
                live,
            )
        }
        _ => return Err(bad("unknown index kind tag")),
    })
}

/// Load + validate a sharded bundle: manifest first, then one nested
/// tagged sub-index per shard. Rejects anything short of a full, exact
/// partition of the parent's **live** set: every live parent row claimed
/// by exactly one shard, bitwise-equal to that shard's copy, and every
/// shard tombstone mirrored by the parent.
fn load_sharded<R: io::Read>(
    r: &mut BinReader<R>,
    data: Arc<crate::core::matrix::Matrix>,
    version: u64,
) -> io::Result<ShardedIndex> {
    let n = data.rows();
    let dim = data.cols();
    let strategy =
        ShardStrategy::from_tag(r.u64()?).ok_or_else(|| bad("unknown shard strategy"))?;
    let fv = r.f32_slice()?;
    if fv.len() != 1 || !fv[0].is_finite() || fv[0] <= 0.0 || fv[0] > 1.0 {
        return Err(bad("implausible min_shard_frac"));
    }
    let parent_live = load_live(r, version, n)?;
    let s = r.u64()? as usize;
    // The id universe (watermark) bounds the shard count; for unmutated
    // bundles it equals the row count, preserving the v4 check.
    if s == 0 || s > (parent_live.next_id() as usize).max(1) {
        return Err(bad("implausible shard count"));
    }
    let mut seen = vec![false; n];
    // Every global id is owned by exactly one shard for its whole life —
    // tombstoned and reclaimed entries included. Without this, a crafted
    // file could alias a dead row in one shard onto a live id in another
    // and mis-route deletes.
    let mut claimed: std::collections::HashSet<u32> = std::collections::HashSet::new();
    let mut parts: Vec<ShardParts> = Vec::with_capacity(s);
    for _ in 0..s {
        let global_ids = r.u32_slice()?;
        if global_ids.is_empty() {
            return Err(bad("empty shard in manifest"));
        }
        if global_ids.windows(2).any(|w| w[0] >= w[1]) {
            return Err(bad("shard id map not ascending"));
        }
        for &g in &global_ids {
            if g >= parent_live.next_id() {
                return Err(bad("shard id above the parent watermark"));
            }
            if !claimed.insert(g) {
                return Err(bad("global id claimed by two shards"));
            }
        }
        let centroid = r.f32_slice()?;
        if centroid.len() != dim {
            return Err(bad("shard centroid shape mismatch"));
        }
        let sub_tag = r.u64()?;
        if sub_tag == TAG_SHARDED {
            return Err(bad("nested sharded index"));
        }
        let sub = Arc::new(r.matrix()?);
        if sub.cols() != dim {
            return Err(bad("shard data shape mismatch"));
        }
        let (sub_index, sub_live) = load_family(sub_tag, Arc::clone(&sub), r, version)?;
        // The manifest row is indexed by the sub-index's local external
        // ids, so it must cover exactly that id universe.
        if global_ids.len() != sub_live.next_id() as usize {
            return Err(bad("shard id map does not cover the sub-index id space"));
        }
        for row in 0..sub.rows() {
            let e = sub_live.external_of(row) as usize; // < next_id, validated
            let g = global_ids[e];
            let p = parent_live.row_of(g);
            if sub_live.is_dead_row(row) {
                // A shard tombstone must be dead (or already reclaimed)
                // in the parent too.
                if let Some(p) = p {
                    if !parent_live.is_dead_row(p) {
                        return Err(bad("shard tombstone disagrees with parent"));
                    }
                }
                continue;
            }
            let Some(p) = p else {
                return Err(bad("live shard row missing from parent"));
            };
            if parent_live.is_dead_row(p) {
                return Err(bad("parent tombstone disagrees with shard"));
            }
            if seen[p] {
                return Err(bad("point assigned to two shards"));
            }
            seen[p] = true;
            let same = sub
                .row(row)
                .iter()
                .zip(data.row(p))
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if !same {
                return Err(bad("shard rows diverge from parent matrix"));
            }
        }
        parts.push((sub_index, global_ids, centroid));
    }
    for row in 0..n {
        if !parent_live.is_dead_row(row) && !seen[row] {
            return Err(bad("shard manifest does not cover every live point"));
        }
    }
    Ok(ShardedIndex::from_parts(data, parts, strategy, fv[0], 0).with_live(parent_live))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::data::synth::tiny;
    use crate::graph::hnsw::HnswParams;
    use crate::index::{SearchContext, SearchParams};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("finger_persist_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_search_results_for_every_family() {
        let ds = tiny(401, 300, 16, Metric::L2);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10).with_ef(40);
        for index in crate::index::build_all_families(Arc::clone(&ds.data)) {
            let path = tmp(&format!("{}.idx", index.name()));
            save_index(&path, index.as_ref()).unwrap();
            let loaded = load_index(&path).unwrap();
            assert_eq!(loaded.name(), index.name());
            assert_eq!(loaded.len(), index.len());
            assert_eq!(loaded.dim(), index.dim());
            for qi in 0..ds.queries.rows() {
                let q = ds.queries.row(qi);
                let a: Vec<u32> =
                    index.search(q, &params, &mut ctx).iter().map(|n| n.id).collect();
                let b: Vec<u32> =
                    loaded.search(q, &params, &mut ctx).iter().map(|n| n.id).collect();
                assert_eq!(a, b, "{} query {qi}", index.name());
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn sharded_roundtrip_preserves_results_for_every_family() {
        let ds = tiny(405, 240, 12, Metric::L2);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10).with_ef(40);
        for index in crate::index::build_all_families_sharded(Arc::clone(&ds.data), 3) {
            let path = tmp(&format!("{}.idx", index.name()));
            save_index(&path, index.as_ref()).unwrap();
            let loaded = load_index(&path).unwrap();
            assert_eq!(loaded.name(), index.name());
            assert_eq!(loaded.kind_tag(), TAG_SHARDED);
            assert_eq!(loaded.len(), index.len());
            for qi in 0..ds.queries.rows() {
                let q = ds.queries.row(qi);
                let a: Vec<u32> =
                    index.search(q, &params, &mut ctx).iter().map(|n| n.id).collect();
                let b: Vec<u32> =
                    loaded.search(q, &params, &mut ctx).iter().map(|n| n.id).collect();
                assert_eq!(a, b, "{} query {qi}", index.name());
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn sharded_rejects_corrupt_and_truncated_manifests() {
        use crate::index::sharded::{ShardSpec, ShardedIndex};
        let ds = tiny(406, 60, 6, Metric::L2);
        let spec = ShardSpec { n_shards: 3, ..Default::default() };
        let idx = ShardedIndex::build(Arc::clone(&ds.data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(crate::index::impls::BruteForce::new(sub))
        });
        let path = tmp("sharded_ok.idx");
        save_index(&path, &idx).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();

        // Truncation anywhere in the manifest/sub-bundles must fail cleanly.
        for cut in [bytes.len() / 4, bytes.len() / 2, bytes.len() - 3] {
            let p = tmp(&format!("sharded_trunc_{cut}.idx"));
            std::fs::write(&p, &bytes[..cut]).unwrap();
            assert!(load_index(&p).is_err(), "truncated at {cut} still loaded");
            std::fs::remove_file(&p).ok();
        }

        // Flip the shard count (first manifest word after strategy, frac,
        // and the v5 parent live section): header = 3 u64 + matrix
        // (2 u64 + len u64 + n*dim f32), then strategy u64 + frac (len
        // u64 + 1 f32) + live section (watermark u64 + row-id slice (len
        // u64 + n u32) + empty dead slice (len u64)) + n_shards u64.
        let n_shards_off = 8 * 3 + (8 * 2 + 8 + 60 * 6 * 4) + 8 + (8 + 4) + (8 + (8 + 60 * 4) + 8);
        let mut corrupt = bytes.clone();
        corrupt[n_shards_off..n_shards_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let p = tmp("sharded_badcount.idx");
        std::fs::write(&p, &corrupt).unwrap();
        let err = load_index(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        std::fs::remove_file(&p).ok();

        // Corrupt a global id inside the first shard's id map so the
        // partition no longer covers every point.
        let ids_off = n_shards_off + 8 + 8; // + n_shards u64 + id-slice len u64
        let mut corrupt = bytes.clone();
        corrupt[ids_off..ids_off + 4].copy_from_slice(&3u32.to_le_bytes());
        let p = tmp("sharded_badids.idx");
        std::fs::write(&p, &corrupt).unwrap();
        let err = load_index(&p).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v5_mutation_state_roundtrips() {
        use crate::index::mutable::MutableAnnIndex;
        let ds = tiny(407, 120, 8, Metric::L2);
        let mut idx = HnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 8, ef_construction: 40, ..Default::default() },
        );
        let mut ctx = SearchContext::new();
        let v: Vec<f32> = ds.queries.row(0).to_vec();
        let id = idx.insert(&v, &mut ctx).unwrap();
        assert_eq!(id, 120);
        idx.remove(3).unwrap();
        idx.remove(77).unwrap();

        let path = tmp("v5_mut.idx");
        save_index(&path, &idx).unwrap();
        let mut loaded = load_index(&path).unwrap();
        let view = loaded.as_mutable_view().expect("hnsw stays mutable after load");
        assert_eq!(view.live_len(), idx.live_len());
        assert!(!view.is_live(3) && !view.is_live(77) && view.is_live(id));
        assert_eq!(view.live_ids(), idx.live_ids());

        let params = SearchParams::new(10).with_ef(200);
        for qi in 0..ds.queries.rows() {
            let a = idx.search(ds.queries.row(qi), &params, &mut ctx);
            let b = loaded.search(ds.queries.row(qi), &params, &mut ctx);
            assert_eq!(a, b, "query {qi}");
        }

        // The watermark survives: the next insert allocates the same id
        // on both sides and never reuses the tombstoned ones.
        let m = loaded.as_mutable().unwrap();
        let a = idx.insert(&v, &mut ctx).unwrap();
        let b = m.insert(&v, &mut ctx).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, 121);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_file() {
        let ds = tiny(408, 40, 4, Metric::L2);
        let idx = crate::index::impls::BruteForce::new(Arc::clone(&ds.data));
        let path = tmp("atomic.idx");
        let tmp_path = {
            let mut t = path.as_os_str().to_os_string();
            t.push(".tmp");
            std::path::PathBuf::from(t)
        };
        save_index(&path, &idx).unwrap();
        assert!(path.exists());
        assert!(!tmp_path.exists(), "temp file must be renamed away");
        let before = std::fs::read(&path).unwrap();
        // Saving over an existing bundle replaces it whole.
        save_index(&path, &idx).unwrap();
        assert!(!tmp_path.exists());
        assert_eq!(std::fs::read(&path).unwrap(), before, "deterministic resave");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("junk.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        assert!(load_index(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_out_of_range_node_ids() {
        let ds = tiny(403, 50, 4, Metric::L2);
        let mut v = VamanaIndex::build(
            Arc::clone(&ds.data),
            VamanaParams { r: 8, ..Default::default() },
        );
        v.graph.medoid = 1000; // corrupt: points past the data matrix
        let path = tmp("corrupt.idx");
        save_index(&path, &v).unwrap();
        let err = load_index(&path).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn finger_roundtrip_preserves_edge_slots() {
        let ds = tiny(402, 100, 8, Metric::L2);
        let fh = FingerHnswIndex::build(
            Arc::clone(&ds.data),
            HnswParams { m: 6, ef_construction: 30, ..Default::default() },
            FingerParams { rank: 8, ..Default::default() },
        );
        let path = tmp("adj.idx");
        save_index(&path, &fh).unwrap();
        let loaded = load_index(&path).unwrap();
        assert_eq!(loaded.kind_tag(), TAG_FINGER);
        // Downcast-free check: re-load through the family loader.
        let mut r = BinReader::new(io::BufReader::new(std::fs::File::open(&path).unwrap()));
        r.u64().unwrap(); // magic
        r.u64().unwrap(); // version
        r.u64().unwrap(); // tag
        r.matrix().unwrap();
        let hnsw2 = load_hnsw(&mut r).unwrap();
        let index2 = load_finger(&mut r).unwrap();
        for u in 0..100u32 {
            assert_eq!(fh.inner.hnsw.base.neighbors(u), hnsw2.base.neighbors(u));
            for j in 0..fh.inner.hnsw.base.degree(u) {
                let s = fh.inner.hnsw.base.edge_slot(u, j);
                assert_eq!(fh.inner.index.edge_proj(s), index2.edge_proj(s));
                assert_eq!(fh.inner.index.edge_block(s), index2.edge_block(s));
            }
        }
        std::fs::remove_file(&path).ok();
    }
}
