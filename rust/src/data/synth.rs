//! Synthetic dataset generation — the substitution for the paper's
//! benchmark datasets (DESIGN.md §5).
//!
//! FINGER's mechanics rely on two geometric properties of real embedding
//! data: (a) residual vectors around a graph node concentrate in a
//! low-dimensional subspace, and (b) angles between neighboring residuals
//! distribute approximately as a Gaussian. Both are properties of clustered
//! data with low intrinsic dimension, which this generator controls
//! explicitly: each cluster is `center + A·z + σ·noise` with `A` an
//! (ambient × intrinsic) random map and `z` standard normal.

use std::sync::Arc;

use crate::core::distance::{normalize, Metric};
use crate::core::matrix::Matrix;
use crate::core::rng::Pcg32;

/// A fully materialized benchmark dataset. The base matrix is behind an
/// `Arc` so every index built over it shares one copy (the `AnnIndex`
/// implementors hold `Arc<Matrix>` handles).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub metric: Metric,
    pub data: Arc<Matrix>,
    pub queries: Matrix,
}

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub n: usize,
    pub n_queries: usize,
    pub dim: usize,
    pub clusters: usize,
    pub intrinsic_dim: usize,
    /// Ambient isotropic noise level relative to signal.
    pub noise: f32,
    pub metric: Metric,
    pub seed: u64,
}

impl SynthSpec {
    pub fn generate(&self) -> Dataset {
        let mut rng = Pcg32::new(self.seed);
        let m = self.dim;
        let k = self.clusters.max(1);
        let d = self.intrinsic_dim.min(m).max(1);

        // Cluster centers: spread on a sphere of radius 4 so clusters are
        // separated but overlapping tails exist (realistic hard negatives).
        let centers: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                let mut c: Vec<f32> = (0..m).map(|_| rng.next_gaussian()).collect();
                normalize(&mut c);
                c.iter_mut().for_each(|x| *x *= 4.0);
                c
            })
            .collect();

        // Per-cluster low-rank maps A (m × d), mildly anisotropic.
        let maps: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                (0..m * d)
                    .map(|j| {
                        let col = j % d;
                        let scale = 1.0 / (1.0 + 0.3 * col as f32); // decaying spectrum
                        rng.next_gaussian() * scale / (d as f32).sqrt()
                    })
                    .collect()
            })
            .collect();

        let sample = |rng: &mut Pcg32| -> Vec<f32> {
            let c = rng.gen_range(k);
            let z: Vec<f32> = (0..d).map(|_| rng.next_gaussian()).collect();
            let a = &maps[c];
            let mut x = centers[c].clone();
            for row in 0..m {
                let mut acc = 0.0f32;
                for col in 0..d {
                    acc += a[row * d + col] * z[col];
                }
                x[row] += acc + self.noise * rng.next_gaussian();
            }
            if self.metric == Metric::Angular {
                normalize(&mut x);
            }
            x
        };

        let mut data = Matrix::zeros(0, 0);
        for _ in 0..self.n {
            data.push_row(&sample(&mut rng));
        }
        let mut queries = Matrix::zeros(0, 0);
        for _ in 0..self.n_queries {
            queries.push_row(&sample(&mut rng));
        }

        Dataset {
            name: self.name.clone(),
            metric: self.metric,
            data: Arc::new(data),
            queries,
        }
    }
}

/// The six paper datasets as scaled-down synthetic stand-ins, preserving
/// dimension and metric (DESIGN.md §5). `scale` in (0, 1] shrinks n for
/// quick runs; 1.0 is the full benchmark size used in EXPERIMENTS.md.
pub fn registry(scale: f64) -> Vec<SynthSpec> {
    let s = |n: usize| ((n as f64 * scale).round() as usize).max(64);
    vec![
        SynthSpec {
            name: "fashion-sim-784".into(),
            n: s(8_000),
            n_queries: 200,
            dim: 784,
            clusters: 10,
            intrinsic_dim: 12,
            noise: 0.05,
            metric: Metric::L2,
            seed: 101,
        },
        SynthSpec {
            name: "sift-sim-128".into(),
            n: s(20_000),
            n_queries: 200,
            dim: 128,
            clusters: 64,
            intrinsic_dim: 16,
            noise: 0.08,
            metric: Metric::L2,
            seed: 102,
        },
        SynthSpec {
            name: "gist-sim-960".into(),
            n: s(8_000),
            n_queries: 200,
            dim: 960,
            clusters: 20,
            intrinsic_dim: 24,
            noise: 0.05,
            metric: Metric::L2,
            seed: 103,
        },
        SynthSpec {
            name: "nytimes-sim-256".into(),
            n: s(8_000),
            n_queries: 200,
            dim: 256,
            clusters: 30,
            intrinsic_dim: 16,
            noise: 0.08,
            metric: Metric::Angular,
            seed: 104,
        },
        SynthSpec {
            name: "glove-sim-100".into(),
            n: s(20_000),
            n_queries: 200,
            dim: 100,
            clusters: 50,
            intrinsic_dim: 20,
            noise: 0.1,
            metric: Metric::Angular,
            seed: 105,
        },
        SynthSpec {
            name: "deep-sim-96".into(),
            n: s(30_000),
            n_queries: 200,
            dim: 96,
            clusters: 64,
            intrinsic_dim: 24,
            noise: 0.08,
            metric: Metric::Angular,
            seed: 106,
        },
    ]
}

/// Look up a registry entry by name (prefix match allowed).
pub fn spec_by_name(name: &str, scale: f64) -> Option<SynthSpec> {
    registry(scale)
        .into_iter()
        .find(|s| s.name == name || s.name.starts_with(name))
}

/// Small dataset for unit tests: fast to build, still clustered.
pub fn tiny(seed: u64, n: usize, dim: usize, metric: Metric) -> Dataset {
    SynthSpec {
        name: format!("tiny-{n}-{dim}"),
        n,
        n_queries: 16,
        dim,
        clusters: 5,
        intrinsic_dim: (dim / 4).max(2),
        noise: 0.05,
        metric,
        seed,
    }
    .generate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::{l2_sq, norm};

    #[test]
    fn shapes_and_determinism() {
        let spec = SynthSpec {
            name: "t".into(),
            n: 100,
            n_queries: 10,
            dim: 16,
            clusters: 4,
            intrinsic_dim: 4,
            noise: 0.05,
            metric: Metric::L2,
            seed: 7,
        };
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a.data.rows(), 100);
        assert_eq!(a.data.cols(), 16);
        assert_eq!(a.queries.rows(), 10);
        assert_eq!(a.data, b.data, "generation must be deterministic");
    }

    #[test]
    fn angular_datasets_are_normalized() {
        let ds = tiny(3, 200, 24, Metric::Angular);
        for i in 0..ds.data.rows() {
            assert!((norm(ds.data.row(i)) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn clustered_structure_exists() {
        // Nearest neighbor should be much closer than a random point.
        let ds = tiny(5, 500, 32, Metric::L2);
        let q = ds.data.row(0);
        let mut dists: Vec<f32> = (1..ds.data.rows()).map(|i| l2_sq(q, ds.data.row(i))).collect();
        dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let nn = dists[0];
        let median = dists[dists.len() / 2];
        assert!(nn < median * 0.5, "nn {nn} median {median}");
    }

    #[test]
    fn registry_covers_paper_datasets() {
        let r = registry(0.01);
        assert_eq!(r.len(), 6);
        let dims: Vec<usize> = r.iter().map(|s| s.dim).collect();
        assert_eq!(dims, vec![784, 128, 960, 256, 100, 96]);
        let angular = r.iter().filter(|s| s.metric == Metric::Angular).count();
        assert_eq!(angular, 3);
    }

    #[test]
    fn spec_by_name_prefix() {
        assert!(spec_by_name("sift-sim-128", 0.1).is_some());
        assert!(spec_by_name("sift", 0.1).is_some());
        assert!(spec_by_name("nope", 0.1).is_none());
    }
}
