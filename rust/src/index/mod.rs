//! One search API across every index family.
//!
//! [`AnnIndex`] is the uniform interface the sweep harness, the router
//! server, the CLI, and persistence all speak. Implementors own (a shared
//! handle to) their data matrix, so a `&dyn AnnIndex` is self-contained:
//! `search` takes only the query, the [`SearchParams`] knobs, and a pooled
//! [`SearchContext`] for scratch.
//!
//! Implementors (see [`impls`]):
//!
//! | name            | family                         | module            |
//! |-----------------|--------------------------------|-------------------|
//! | `bruteforce`    | exact linear scan              | `graph::bruteforce` |
//! | `hnsw`          | HNSW (Algorithm 1 search)      | `graph::hnsw`     |
//! | `hnsw-finger`   | HNSW + FINGER screening        | `finger::search`  |
//! | `vamana`        | DiskANN flat graph             | `graph::vamana`   |
//! | `nndescent`     | NN-descent KNN graph           | `graph::nndescent`|
//! | `ivfpq`         | IVF-PQ + exact re-rank         | `quant::ivfpq`    |
//! | `sharded-*`     | scatter-gather over any family | `index::sharded`  |
//! | `*-sq8`, `*-pq` | quantized traversal + exact re-rank over the base family | `quant::sq8` |
//!
//! The `-sq8`/`-pq` variants (e.g. `hnsw-sq8`, `hnsw-finger-sq8`) are the
//! same graph with a quantized sibling of the vector store: the beam
//! traverses on approximate distances and the final pool re-ranks with
//! exact f32 kernels (see [`crate::quant::sq8`]). Select at build time
//! with [`crate::quant::Precision`] (CLI: `--precision sq8|pq`).

pub mod context;
pub mod impls;
pub mod merge;
pub mod mutable;
pub mod sharded;

pub use context::{SearchContext, SearchParams};
pub use impls::{
    build_all_families, BruteForce, FingerHnswIndex, FingerView, HnswIndex, IvfPqIndex,
    NnDescentIndex, VamanaIndex,
};
pub use mutable::{LiveIds, MutableAnnIndex, MutateError, DEFAULT_COMPACT_THRESHOLD};
pub use sharded::{build_all_families_sharded, ShardSpec, ShardStrategy, ShardedIndex};

use std::io;

use crate::core::matrix::Matrix;
use crate::data::io::BinWriter;
use crate::graph::search::Neighbor;

/// A searchable ANN index over an owned/shared data matrix.
///
/// `Send + Sync` is a supertrait so a `Box<dyn AnnIndex>` can be shared
/// across the router's worker pool behind an `Arc`.
pub trait AnnIndex: Send + Sync {
    /// Stable family name (used as method label and CLI `--method` value).
    fn name(&self) -> &'static str;

    /// Data dimensionality.
    fn dim(&self) -> usize;

    /// Number of indexed points.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The indexed data matrix (row id == point id).
    fn data(&self) -> &Matrix;

    /// Index memory footprint in bytes (excluding the data matrix).
    fn nbytes(&self) -> usize;

    /// Approximation rank for effective-distance accounting (Figure 6's
    /// `a + b·r/m` x-axis); 0 for families with no approximate scoring.
    fn approx_rank(&self) -> usize {
        0
    }

    /// Top-`params.k` neighbors of `q`, ascending by distance.
    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor>;

    /// Search every row of `queries`; default loops `search` reusing `ctx`.
    fn batch_search(
        &self,
        queries: &Matrix,
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Vec<Neighbor>> {
        (0..queries.rows())
            .map(|qi| self.search(queries.row(qi), params, ctx))
            .collect()
    }

    /// The mutation plane ([`MutableAnnIndex`]), if this family supports
    /// online insert/delete/compact. Families that cannot mutate return
    /// `None` — callers report "unsupported" instead of panicking.
    fn as_mutable(&mut self) -> Option<&mut dyn MutableAnnIndex> {
        None
    }

    /// Read-only view of the mutation plane (live counts, tombstone
    /// fraction). `Some` exactly when [`AnnIndex::as_mutable`] is.
    fn as_mutable_view(&self) -> Option<&dyn MutableAnnIndex> {
        None
    }

    /// Persistence tag (see `data::persist`); stable across versions.
    fn kind_tag(&self) -> u64;

    /// Serialize the family payload (graph/codebooks — everything except
    /// the data matrix, which `data::persist::save_index` writes).
    fn save_payload(&self, w: &mut BinWriter<&mut dyn io::Write>) -> io::Result<()>;
}
