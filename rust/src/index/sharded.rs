//! Sharded scatter-gather index: data parallelism on top of any
//! [`AnnIndex`] family (the orthogonal axis to FINGER's per-query
//! speedup — partitioned deployments are how graph indexes reach
//! billion-scale in practice).
//!
//! A [`ShardedIndex`] partitions the dataset across `S` shards
//! (round-robin or k-means assignment), builds one self-contained
//! sub-index per shard in parallel, and implements [`AnnIndex`] itself:
//! a query scatters to the probed shards, each shard answers from its own
//! local id space, results are remapped local→global and k-way merged
//! (see [`crate::index::merge`]). `batch_search` fans a whole query batch
//! out across shards — one worker per shard, each with its own pooled
//! [`SearchContext`] — which is what the router's dynamic batcher feeds.
//!
//! The `min_shard_frac` knob trades speed for recall: probe only the
//! nearest `ceil(frac·S)` shards by query-to-centroid distance instead of
//! all of them (1.0, the default, probes everything and is exact with a
//! brute-force sub-index).

use std::io;
use std::sync::{Arc, Mutex};

use crate::core::distance::l2_sq;
use crate::core::matrix::Matrix;
use crate::core::threads::{default_threads, parallel_for};
use crate::data::io::BinWriter;
use crate::data::persist;
use crate::graph::search::{Neighbor, SearchStats};
use crate::index::context::{SearchContext, SearchParams};
use crate::index::merge::{merge_topk, remap_to_global};
use crate::index::mutable::{LiveIds, MutableAnnIndex, MutateError, DEFAULT_COMPACT_THRESHOLD};
use crate::index::AnnIndex;
use crate::quant::kmeans::KMeans;

/// How points are assigned to shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Point `i` goes to shard `i % S` — balanced by construction, every
    /// shard sees the full data distribution.
    RoundRobin,
    /// K-means clustering with `S` centroids — locality-preserving, so
    /// low `min_shard_frac` probes lose little recall.
    KMeans,
}

impl ShardStrategy {
    pub fn parse(s: &str) -> Option<ShardStrategy> {
        match s {
            "round-robin" | "roundrobin" | "rr" => Some(ShardStrategy::RoundRobin),
            "kmeans" | "k-means" => Some(ShardStrategy::KMeans),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ShardStrategy::RoundRobin => "round-robin",
            ShardStrategy::KMeans => "kmeans",
        }
    }

    /// Stable persistence tag (never renumber).
    pub fn tag(self) -> u64 {
        match self {
            ShardStrategy::RoundRobin => 0,
            ShardStrategy::KMeans => 1,
        }
    }

    pub fn from_tag(tag: u64) -> Option<ShardStrategy> {
        match tag {
            0 => Some(ShardStrategy::RoundRobin),
            1 => Some(ShardStrategy::KMeans),
            _ => None,
        }
    }
}

/// Build-time sharding configuration.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    /// Number of shards (clamped to `[1, n]` at build).
    pub n_shards: usize,
    pub strategy: ShardStrategy,
    /// Seed for k-means assignment (round-robin ignores it).
    pub seed: u64,
    pub kmeans_iters: usize,
    /// Worker threads for the per-shard builds and batched scatter
    /// (0 = [`default_threads`]).
    pub threads: usize,
}

impl Default for ShardSpec {
    fn default() -> ShardSpec {
        ShardSpec {
            n_shards: 4,
            strategy: ShardStrategy::RoundRobin,
            seed: 42,
            kmeans_iters: 10,
            threads: 0,
        }
    }
}

/// One shard: a self-contained sub-index over a copy of its rows, the
/// local→global id map (ascending, so remapping preserves result order),
/// the shard centroid for probe ranking, and a pooled search context for
/// the parallel batch path.
pub struct Shard {
    pub index: Box<dyn AnnIndex>,
    /// `global_ids[local external id] = global external id`; strictly
    /// ascending (both sides are assigned monotonically). For a freshly
    /// built shard local external ids coincide with local rows, so this
    /// is the classic local-row→global-row map; after online mutation the
    /// sub-index keeps emitting its stable local external ids, so entries
    /// for tombstoned-and-compacted points simply go stale without ever
    /// being looked up.
    pub global_ids: Vec<u32>,
    /// Mean of the shard's rows (probe ordering for `min_shard_frac`).
    pub centroid: Vec<f32>,
    /// Per-shard scratch so the scatter phase of `batch_search` needs no
    /// allocation or sharing across worker threads.
    ctx: Mutex<SearchContext>,
}

/// One shard's parts for [`ShardedIndex::from_parts`]: (sub-index,
/// ascending global ids, centroid).
pub type ShardParts = (Box<dyn AnnIndex>, Vec<u32>, Vec<f32>);

/// A sharded index over any `AnnIndex` family. See the module docs.
pub struct ShardedIndex {
    /// The full (unpartitioned) data matrix; `live` maps its rows to
    /// global external ids (identity until mutated).
    pub data: Arc<Matrix>,
    pub shards: Vec<Shard>,
    pub strategy: ShardStrategy,
    /// Fraction of shards probed per query, in (0, 1]; 1.0 = all.
    min_shard_frac: f32,
    threads: usize,
    label: &'static str,
    /// Global external-id bookkeeping over the parent matrix.
    live: LiveIds,
    compact_threshold: f64,
}

/// Assign every row to a shard under `spec.strategy`, then rebalance so no
/// shard is empty (k-means can starve a centroid; an empty shard cannot
/// host a graph index). Deterministic for a fixed spec.
pub fn assign_shards(data: &Matrix, n_shards: usize, spec: &ShardSpec) -> Vec<u32> {
    let n = data.rows();
    let s = n_shards.max(1);
    let mut assignment: Vec<u32> = match spec.strategy {
        ShardStrategy::RoundRobin => (0..n).map(|i| (i % s) as u32).collect(),
        ShardStrategy::KMeans => {
            let km = KMeans::train(data, s, spec.kmeans_iters, spec.seed);
            (0..n).map(|i| km.assign(data.row(i)) as u32).collect()
        }
    };
    rebalance(&mut assignment, s);
    assignment
}

/// Move points from the largest shard into empty ones until every shard
/// is populated (requires `n >= s`; callers clamp). Deterministic: the
/// donor is the last-largest shard, the moved point its highest id.
fn rebalance(assignment: &mut [u32], s: usize) {
    if assignment.len() < s {
        return;
    }
    loop {
        let mut counts = vec![0usize; s];
        for &a in assignment.iter() {
            counts[a as usize] += 1;
        }
        let Some(empty) = counts.iter().position(|&c| c == 0) else {
            return;
        };
        let donor = counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap();
        let victim = assignment
            .iter()
            .rposition(|&a| a as usize == donor)
            .unwrap();
        assignment[victim] = empty as u32;
    }
}

fn centroid_of(m: &Matrix) -> Vec<f32> {
    let (rows, cols) = (m.rows(), m.cols());
    let mut acc = vec![0.0f64; cols];
    for i in 0..rows {
        for (a, &v) in acc.iter_mut().zip(m.row(i)) {
            *a += v as f64;
        }
    }
    let inv = 1.0 / rows.max(1) as f64;
    acc.into_iter().map(|a| (a * inv) as f32).collect()
}

/// Static display label: "sharded-<family>" for a homogeneous fleet.
fn sharded_label(inner: &str) -> &'static str {
    match inner {
        "bruteforce" => "sharded-bruteforce",
        "hnsw" => "sharded-hnsw",
        "hnsw-finger" => "sharded-hnsw-finger",
        "vamana" => "sharded-vamana",
        "nndescent" => "sharded-nndescent",
        "ivfpq" => "sharded-ivfpq",
        "bruteforce-sq8" => "sharded-bruteforce-sq8",
        "bruteforce-pq" => "sharded-bruteforce-pq",
        "hnsw-sq8" => "sharded-hnsw-sq8",
        "hnsw-pq" => "sharded-hnsw-pq",
        "hnsw-finger-sq8" => "sharded-hnsw-finger-sq8",
        "hnsw-finger-pq" => "sharded-hnsw-finger-pq",
        _ => "sharded",
    }
}

impl ShardedIndex {
    /// Partition `data` per `spec` and build one sub-index per shard with
    /// `build_shard` (called with the shard's own `Arc<Matrix>`), fanning
    /// the builds out over [`parallel_for`].
    pub fn build<F>(data: Arc<Matrix>, spec: &ShardSpec, build_shard: F) -> ShardedIndex
    where
        F: Fn(Arc<Matrix>) -> Box<dyn AnnIndex> + Sync,
    {
        let n = data.rows();
        let s = spec.n_shards.max(1).min(n.max(1));
        let assignment = assign_shards(&data, s, spec);
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); s];
        for (i, &a) in assignment.iter().enumerate() {
            members[a as usize].push(i as u32);
        }
        let dim = data.cols();
        let subdata: Vec<Arc<Matrix>> = members
            .iter()
            .map(|ids| {
                let mut m = Matrix::zeros(0, dim);
                for &id in ids {
                    m.push_row(data.row(id as usize));
                }
                Arc::new(m)
            })
            .collect();

        let threads = if spec.threads == 0 { default_threads() } else { spec.threads };
        let slots: Vec<Mutex<Option<Box<dyn AnnIndex>>>> =
            (0..s).map(|_| Mutex::new(None)).collect();
        parallel_for(s, threads, |si| {
            let built = build_shard(Arc::clone(&subdata[si]));
            *slots[si].lock().unwrap() = Some(built);
        });

        let parts: Vec<ShardParts> = slots
            .into_iter()
            .zip(members)
            .zip(&subdata)
            .map(|((slot, global_ids), sub)| {
                let index = slot.into_inner().unwrap().expect("shard build produced no index");
                (index, global_ids, centroid_of(sub))
            })
            .collect();
        ShardedIndex::from_parts(data, parts, spec.strategy, 1.0, threads)
    }

    /// Assemble from prebuilt shards (the persistence loader's entry).
    /// Each tuple is (sub-index, ascending global ids, centroid).
    pub fn from_parts(
        data: Arc<Matrix>,
        parts: Vec<ShardParts>,
        strategy: ShardStrategy,
        min_shard_frac: f32,
        threads: usize,
    ) -> ShardedIndex {
        assert!(!parts.is_empty(), "sharded index needs at least one shard");
        let first = parts[0].0.name();
        let homogeneous = parts.iter().all(|(ix, _, _)| ix.name() == first);
        let label = if homogeneous { sharded_label(first) } else { "sharded" };
        let shards = parts
            .into_iter()
            .map(|(index, global_ids, centroid)| Shard {
                index,
                global_ids,
                centroid,
                ctx: Mutex::new(SearchContext::new()),
            })
            .collect();
        let live = LiveIds::fresh(data.rows());
        ShardedIndex {
            data,
            shards,
            strategy,
            min_shard_frac: 1.0f32.min(min_shard_frac.max(1e-6)),
            threads: if threads == 0 { default_threads() } else { threads },
            label,
            live,
            compact_threshold: DEFAULT_COMPACT_THRESHOLD,
        }
    }

    /// Restore persisted parent mutation state (the v5 loader's entry).
    pub fn with_live(mut self, live: LiveIds) -> ShardedIndex {
        assert_eq!(live.n_rows(), self.data.rows(), "live map must cover the rows");
        self.live = live;
        self
    }

    pub fn live(&self) -> &LiveIds {
        &self.live
    }

    /// Probe only the nearest `ceil(frac · S)` shards per query.
    pub fn with_min_shard_frac(mut self, frac: f32) -> ShardedIndex {
        self.min_shard_frac = 1.0f32.min(frac.max(1e-6));
        self
    }

    pub fn min_shard_frac(&self) -> f32 {
        self.min_shard_frac
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shards probed per query under the current `min_shard_frac`.
    pub fn probe_count(&self) -> usize {
        let s = self.shards.len();
        (((self.min_shard_frac as f64) * s as f64).ceil() as usize).clamp(1, s)
    }

    /// Reconstruct the row→shard assignment (determinism checks). After
    /// online mutation the manifest may carry stale entries for reclaimed
    /// ids; those are skipped, so the result always covers exactly the
    /// current rows.
    pub fn assignment(&self) -> Vec<u32> {
        let mut out = vec![0u32; self.data.rows()];
        for (si, shard) in self.shards.iter().enumerate() {
            for &gid in &shard.global_ids {
                if let Some(row) = self.live.row_of(gid) {
                    out[row] = si as u32;
                }
            }
        }
        out
    }

    /// Shard indices to probe for `q`, ascending. With a partial probe the
    /// shards are ranked by centroid distance (counted as `dist_calls`).
    fn probe_set(&self, q: &[f32], ctx: &mut SearchContext) -> Vec<usize> {
        let s = self.shards.len();
        let p = self.probe_count();
        if p >= s {
            return (0..s).collect();
        }
        if ctx.stats_enabled {
            ctx.stats.dist_calls += s as u64;
        }
        let mut order: Vec<(f32, usize)> = self
            .shards
            .iter()
            .enumerate()
            .map(|(i, sh)| (l2_sq(q, &sh.centroid), i))
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        order.truncate(p);
        let mut probe: Vec<usize> = order.into_iter().map(|(_, i)| i).collect();
        probe.sort_unstable();
        probe
    }
}

impl AnnIndex for ShardedIndex {
    fn name(&self) -> &'static str {
        self.label
    }

    fn dim(&self) -> usize {
        self.data.cols()
    }

    fn len(&self) -> usize {
        self.data.rows()
    }

    fn data(&self) -> &Matrix {
        &self.data
    }

    fn nbytes(&self) -> usize {
        self.shards
            .iter()
            .map(|sh| {
                sh.index.nbytes()
                    + sh.index.data().nbytes() // per-shard row copy
                    + sh.global_ids.len() * 4
                    + sh.centroid.len() * 4
            })
            .sum()
    }

    fn approx_rank(&self) -> usize {
        self.shards.iter().map(|sh| sh.index.approx_rank()).max().unwrap_or(0)
    }

    /// Scatter to the probed shards sequentially (the caller's pooled
    /// context serves every shard), remap, merge. Parallelism across
    /// shards lives in `batch_search`.
    fn search(&self, q: &[f32], params: &SearchParams, ctx: &mut SearchContext) -> Vec<Neighbor> {
        let probe = self.probe_set(q, ctx);
        let mut lists: Vec<Vec<Neighbor>> = Vec::with_capacity(probe.len());
        for &si in &probe {
            let shard = &self.shards[si];
            let mut res = shard.index.search(q, params, ctx);
            remap_to_global(&mut res, &shard.global_ids);
            lists.push(res);
        }
        merge_topk(&lists, params.k)
    }

    /// Fan the whole batch out across shards: one worker per shard, each
    /// answering every query that probes it with the shard's own pooled
    /// context, then a per-query merge. Identical results to looping
    /// `search` (both run the same per-shard searches and the same
    /// deterministic merge).
    fn batch_search(
        &self,
        queries: &Matrix,
        params: &SearchParams,
        ctx: &mut SearchContext,
    ) -> Vec<Vec<Neighbor>> {
        let nq = queries.rows();
        let s = self.shards.len();
        if nq == 0 {
            return Vec::new();
        }
        // Scoped-thread scatter only pays off when there is real fan-out;
        // a single query or single shard runs sequentially on the caller's
        // context (identical results — same searches, same merge).
        if nq == 1 || s == 1 || self.threads == 1 {
            return (0..nq)
                .map(|qi| self.search(queries.row(qi), params, ctx))
                .collect();
        }
        let probe: Vec<Vec<usize>> = (0..nq)
            .map(|qi| self.probe_set(queries.row(qi), ctx))
            .collect();
        let want_stats = ctx.stats_enabled;
        let slots: Vec<Mutex<Vec<Option<Vec<Neighbor>>>>> =
            (0..s).map(|_| Mutex::new(Vec::new())).collect();
        // Per-call stats accumulator: each worker drains its shard's stats
        // while still holding that shard's context lock, so a concurrent
        // batch_search on the same index can never steal or clobber them.
        // Merge order across shards is scheduling-dependent but `merge`
        // only sums, so the aggregate stays deterministic.
        let agg_stats = Mutex::new(SearchStats::default());
        parallel_for(s, self.threads, |si| {
            let shard = &self.shards[si];
            let mut out: Vec<Option<Vec<Neighbor>>> = vec![None; nq];
            let mut sctx = shard.ctx.lock().unwrap();
            sctx.stats_enabled = want_stats;
            if want_stats {
                sctx.reset_stats();
            }
            for qi in 0..nq {
                if probe[qi].contains(&si) {
                    let mut res = shard.index.search(queries.row(qi), params, &mut sctx);
                    remap_to_global(&mut res, &shard.global_ids);
                    out[qi] = Some(res);
                }
            }
            if want_stats {
                let stats = sctx.take_stats();
                agg_stats.lock().unwrap().merge(&stats);
            }
            *slots[si].lock().unwrap() = out;
        });
        let mut per_shard: Vec<Vec<Option<Vec<Neighbor>>>> =
            slots.into_iter().map(|m| m.into_inner().unwrap()).collect();
        if want_stats {
            ctx.stats.merge(&agg_stats.into_inner().unwrap());
        }
        (0..nq)
            .map(|qi| {
                let lists: Vec<Vec<Neighbor>> = probe[qi]
                    .iter()
                    .map(|&si| per_shard[si][qi].take().expect("probed shard answered"))
                    .collect();
                merge_topk(&lists, params.k)
            })
            .collect()
    }

    fn as_mutable(&mut self) -> Option<&mut dyn MutableAnnIndex> {
        // The fleet mutates as one: every shard family must support it.
        if self.shards.iter().all(|s| s.index.as_mutable_view().is_some()) {
            Some(self)
        } else {
            None
        }
    }

    fn as_mutable_view(&self) -> Option<&dyn MutableAnnIndex> {
        if self.shards.iter().all(|s| s.index.as_mutable_view().is_some()) {
            Some(self)
        } else {
            None
        }
    }

    fn kind_tag(&self) -> u64 {
        persist::TAG_SHARDED
    }

    /// Shard manifest + nested tagged sub-index bundles (format v5):
    /// strategy | min_shard_frac | parent live section | S | per shard:
    /// global_ids, centroid, sub tag, sub data matrix, sub payload (which
    /// for mutable families ends with the shard's own live section).
    ///
    /// Each nested bundle deliberately repeats the shard's rows even
    /// though they duplicate slices of the parent matrix: every sub-bundle
    /// is then exactly the standard `tag | data | payload` family body, so
    /// the loader reuses `persist::load_family` verbatim and a future
    /// multi-process deployment can ship one self-contained bundle per
    /// shard node. The loader cross-checks the copies bitwise against the
    /// parent, so the redundancy also acts as corruption detection. Cost:
    /// the vector payload is stored twice per file.
    fn save_payload(&self, w: &mut BinWriter<&mut dyn io::Write>) -> io::Result<()> {
        w.u64(self.strategy.tag())?;
        w.f32_slice(&[self.min_shard_frac])?;
        self.live.save(w)?;
        w.u64(self.shards.len() as u64)?;
        for shard in &self.shards {
            w.u32_slice(&shard.global_ids)?;
            w.f32_slice(&shard.centroid)?;
            w.u64(shard.index.kind_tag())?;
            w.matrix(shard.index.data())?;
            shard.index.save_payload(w)?;
        }
        Ok(())
    }
}

impl MutableAnnIndex for ShardedIndex {
    /// Route the insert to one shard: nearest centroid under k-means
    /// assignment (locality), least-loaded (by live count, ties to the
    /// lowest shard index) under round-robin (balance). The new point
    /// gets the next global external id; the chosen shard's sub-index
    /// assigns the matching local external id and `global_ids` grows by
    /// one entry — both sides monotone, so the remap stays ascending.
    fn insert(&mut self, v: &[f32], ctx: &mut SearchContext) -> Result<u32, MutateError> {
        if v.len() != self.data.cols() {
            return Err(MutateError::DimMismatch { got: v.len(), want: self.data.cols() });
        }
        let si = match self.strategy {
            ShardStrategy::KMeans => self
                .shards
                .iter()
                .enumerate()
                .map(|(i, sh)| (l2_sq(v, &sh.centroid), i))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
                .map(|(_, i)| i)
                .unwrap(),
            ShardStrategy::RoundRobin => self
                .shards
                .iter()
                .enumerate()
                .map(|(i, sh)| {
                    let load = sh
                        .index
                        .as_mutable_view()
                        .map(|m| m.live_len())
                        .unwrap_or_else(|| sh.index.len());
                    (load, i)
                })
                .min()
                .map(|(_, i)| i)
                .unwrap(),
        };
        {
            let shard = &mut self.shards[si];
            let expected = shard.global_ids.len();
            let sub = shard
                .index
                .as_mutable()
                .ok_or(MutateError::Unsupported("sharded"))?;
            let local = sub.insert(v, ctx)?;
            debug_assert_eq!(local as usize, expected, "shard id spaces are append-only");
        }
        Arc::make_mut(&mut self.data).push_row(v);
        let id = self.live.alloc();
        self.shards[si].global_ids.push(id);
        Ok(id)
    }

    fn remove(&mut self, id: u32) -> Result<(), MutateError> {
        let row = self.live.row_of(id).ok_or(MutateError::UnknownId(id))?;
        if self.live.is_dead_row(row) {
            return Err(MutateError::AlreadyDeleted(id));
        }
        // The owning shard is the one whose (ascending) global-id map
        // contains the id; forward the delete in its local id space.
        let mut owner = None;
        for (si, shard) in self.shards.iter().enumerate() {
            if let Ok(local) = shard.global_ids.binary_search(&id) {
                owner = Some((si, local as u32));
                break;
            }
        }
        let (si, local) = owner.ok_or(MutateError::UnknownId(id))?;
        let sub = self.shards[si]
            .index
            .as_mutable()
            .ok_or(MutateError::Unsupported("sharded"))?;
        sub.remove(local)?;
        self.live.kill_row(row);
        Ok(())
    }

    /// Targeted compaction: every shard decides from its own tombstone
    /// pressure (the threshold is forwarded by
    /// [`MutableAnnIndex::set_compact_threshold`]); the parent matrix
    /// compacts independently once its own fraction crosses the
    /// threshold. Global external ids survive both.
    fn compact(&mut self, ctx: &mut SearchContext) -> Result<bool, MutateError> {
        let mut any = false;
        for shard in &mut self.shards {
            if let Some(sub) = shard.index.as_mutable() {
                any |= sub.compact(ctx)?;
            }
        }
        if self.live.should_compact(self.compact_threshold) {
            self.data = crate::index::impls::gather_rows(&self.data, &self.live.compact_plan());
            self.live.apply_compact();
            any = true;
        }
        Ok(any)
    }

    fn live_len(&self) -> usize {
        self.live.live_len()
    }

    fn is_live(&self, id: u32) -> bool {
        self.live.is_live(id)
    }

    fn live_ids(&self) -> Vec<u32> {
        self.live.live_ids()
    }

    fn tombstone_fraction(&self) -> f64 {
        self.live.tombstone_fraction()
    }

    fn set_compact_threshold(&mut self, frac: f64) {
        self.compact_threshold = frac;
        for shard in &mut self.shards {
            if let Some(sub) = shard.index.as_mutable() {
                sub.set_compact_threshold(frac);
            }
        }
    }

    fn compact_threshold(&self) -> f64 {
        self.compact_threshold
    }
}

/// Sharded twin of [`crate::index::impls::build_all_families`]: every
/// family wrapped in a `ShardedIndex`, same one-registration point for the
/// conformance and persistence suites.
///
/// Kept in sync with the flat registry BY HAND — when a family is added
/// there, add it here and to [`sharded_label`] too. Parameters
/// intentionally differ where shard size demands it (e.g. `n_list: 8`
/// here vs 16 flat: each shard holds ~n/S points, so fewer coarse cells).
pub fn build_all_families_sharded(data: Arc<Matrix>, n_shards: usize) -> Vec<Box<dyn AnnIndex>> {
    use crate::finger::construct::FingerParams;
    use crate::graph::hnsw::HnswParams;
    use crate::graph::nndescent::NnDescentParams;
    use crate::graph::vamana::VamanaParams;
    use crate::index::impls::{
        BruteForce, FingerHnswIndex, HnswIndex, IvfPqIndex, NnDescentIndex, VamanaIndex,
    };
    use crate::quant::ivfpq::IvfPqParams;
    use crate::quant::sq8::Precision;

    let spec = ShardSpec { n_shards, ..Default::default() };
    vec![
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(BruteForce::new(sub))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(HnswIndex::build(
                sub,
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
            ))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(FingerHnswIndex::build(
                sub,
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
                FingerParams { rank: 8, ..Default::default() },
            ))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(VamanaIndex::build(sub, VamanaParams::default()))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(NnDescentIndex::build(sub, NnDescentParams::default()))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(IvfPqIndex::build(
                sub,
                IvfPqParams { n_list: 8, ..Default::default() },
            ))
        })),
        // Quantized-traversal variants, appended at the end to mirror the
        // flat registry. Each shard trains its own codec/codebooks on its
        // own rows (the tier is shard-local state like the graph).
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(BruteForce::with_precision(sub, Precision::Sq8))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(HnswIndex::build_with_precision(
                sub,
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
                Precision::Sq8,
            ))
        })),
        Box::new(ShardedIndex::build(Arc::clone(&data), &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(HnswIndex::build_with_precision(
                sub,
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
                Precision::Pq,
            ))
        })),
        Box::new(ShardedIndex::build(data, &spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(FingerHnswIndex::build_with_precision(
                sub,
                HnswParams { m: 12, ef_construction: 80, ..Default::default() },
                FingerParams { rank: 8, ..Default::default() },
                Precision::Sq8,
            ))
        })),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::distance::Metric;
    use crate::core::store::VectorStore;
    use crate::data::synth::tiny;
    use crate::graph::bruteforce::scan;
    use crate::index::impls::BruteForce;

    fn sharded_bf(ds: &crate::data::Dataset, spec: &ShardSpec) -> ShardedIndex {
        ShardedIndex::build(Arc::clone(&ds.data), spec, |sub| -> Box<dyn AnnIndex> {
            Box::new(BruteForce::new(sub))
        })
    }

    #[test]
    fn round_robin_assignment_is_balanced() {
        let ds = tiny(801, 103, 8, Metric::L2);
        let spec = ShardSpec { n_shards: 4, ..Default::default() };
        let idx = sharded_bf(&ds, &spec);
        assert_eq!(idx.n_shards(), 4);
        let sizes: Vec<usize> = idx.shards.iter().map(|s| s.global_ids.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| s == 25 || s == 26), "{sizes:?}");
        // id i lives in shard i % 4 with ascending global ids.
        for (si, shard) in idx.shards.iter().enumerate() {
            assert!(shard.global_ids.windows(2).all(|w| w[0] < w[1]));
            assert!(shard.global_ids.iter().all(|&g| g as usize % 4 == si));
        }
    }

    #[test]
    fn kmeans_assignment_covers_every_point_nonempty() {
        let ds = tiny(802, 200, 8, Metric::L2);
        let spec = ShardSpec {
            n_shards: 6,
            strategy: ShardStrategy::KMeans,
            ..Default::default()
        };
        let idx = sharded_bf(&ds, &spec);
        let mut seen = vec![false; 200];
        for shard in &idx.shards {
            assert!(!shard.global_ids.is_empty(), "empty shard after rebalance");
            for &g in &shard.global_ids {
                assert!(!seen[g as usize], "point {g} in two shards");
                seen[g as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shard_count_clamped_to_n() {
        let ds = tiny(803, 5, 4, Metric::L2);
        let spec = ShardSpec { n_shards: 64, ..Default::default() };
        let idx = sharded_bf(&ds, &spec);
        assert_eq!(idx.n_shards(), 5);
        assert!(idx.shards.iter().all(|s| s.global_ids.len() == 1));
    }

    #[test]
    fn sharded_bruteforce_is_exact() {
        let ds = tiny(804, 300, 12, Metric::L2);
        let store = VectorStore::from_matrix(&ds.data);
        for s in [1usize, 3, 7] {
            let spec = ShardSpec { n_shards: s, ..Default::default() };
            let idx = sharded_bf(&ds, &spec);
            let mut ctx = SearchContext::new();
            let params = SearchParams::new(10);
            for qi in 0..ds.queries.rows() {
                let q = ds.queries.row(qi);
                let got = idx.search(q, &params, &mut ctx);
                let want = scan(&store, q, 10);
                assert_eq!(got, want, "S={s} query {qi}");
            }
        }
    }

    #[test]
    fn rebalance_fills_empty_shards() {
        let mut a = vec![0u32, 0, 0, 0, 2];
        rebalance(&mut a, 4);
        let mut counts = [0usize; 4];
        for &x in &a {
            counts[x as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn min_shard_frac_controls_probe_count() {
        let ds = tiny(805, 160, 8, Metric::L2);
        let spec = ShardSpec { n_shards: 8, ..Default::default() };
        let idx = sharded_bf(&ds, &spec);
        assert_eq!(idx.probe_count(), 8);
        let idx = idx.with_min_shard_frac(0.25);
        assert_eq!(idx.probe_count(), 2);
        let idx = idx.with_min_shard_frac(0.01);
        assert_eq!(idx.probe_count(), 1);
        // Partial probe still returns k well-formed ascending results.
        let mut ctx = SearchContext::new();
        let res = idx.search(ds.queries.row(0), &SearchParams::new(5), &mut ctx);
        assert_eq!(res.len(), 5);
        assert!(res.windows(2).all(|w| w[0].dist <= w[1].dist));
    }

    #[test]
    fn kmeans_partial_probe_keeps_most_recall() {
        // Clustered data + kmeans shards: probing half the shards should
        // still find most true neighbors (locality), and full probe is exact.
        let ds = tiny(806, 400, 16, Metric::L2);
        let spec = ShardSpec {
            n_shards: 4,
            strategy: ShardStrategy::KMeans,
            ..Default::default()
        };
        let idx = sharded_bf(&ds, &spec).with_min_shard_frac(0.5);
        let store = VectorStore::from_matrix(&ds.data);
        let mut ctx = SearchContext::new();
        let params = SearchParams::new(10);
        let mut total = 0.0;
        for qi in 0..ds.queries.rows() {
            let q = ds.queries.row(qi);
            let got = idx.search(q, &params, &mut ctx);
            let want = scan(&store, q, 10);
            let hits = got.iter().filter(|n| want.iter().any(|w| w.id == n.id)).count();
            total += hits as f64 / 10.0;
        }
        let recall = total / ds.queries.rows() as f64;
        assert!(recall > 0.6, "half-probe recall {recall}");
    }

    #[test]
    fn batch_matches_sequential_and_merges_stats() {
        let ds = tiny(807, 250, 8, Metric::L2);
        let spec = ShardSpec { n_shards: 3, ..Default::default() };
        let idx = sharded_bf(&ds, &spec);
        let params = SearchParams::new(7);
        let mut ctx = SearchContext::new().with_stats();
        let batched = idx.batch_search(&ds.queries, &params, &mut ctx);
        let batch_stats = ctx.take_stats();
        assert_eq!(batch_stats.dist_calls, (250 * ds.queries.rows()) as u64);
        for qi in 0..ds.queries.rows() {
            let single = idx.search(ds.queries.row(qi), &params, &mut ctx);
            assert_eq!(batched[qi], single, "query {qi}");
        }
    }

    #[test]
    fn sharded_mutation_lifecycle() {
        let ds = tiny(808, 120, 8, Metric::L2);
        let spec = ShardSpec { n_shards: 3, ..Default::default() };
        let mut idx = sharded_bf(&ds, &spec);
        let mut ctx = SearchContext::new();

        // Insert a far-away point: gets the watermark id, becomes findable.
        let v: Vec<f32> = (0..8).map(|i| 100.0 + i as f32).collect();
        let id = idx.insert(&v, &mut ctx).unwrap();
        assert_eq!(id, 120);
        assert_eq!(idx.live_len(), 121);
        assert_eq!(idx.len(), 121);
        let got = idx.search(&v, &SearchParams::new(1), &mut ctx);
        assert_eq!(got[0].id, 120);

        // Delete it: never emitted again, structured errors on re-delete.
        idx.remove(120).unwrap();
        assert_eq!(idx.live_len(), 120);
        let got = idx.search(&v, &SearchParams::new(3), &mut ctx);
        assert!(got.iter().all(|n| n.id != 120));
        assert_eq!(idx.remove(120), Err(MutateError::AlreadyDeleted(120)));
        assert_eq!(idx.remove(999), Err(MutateError::UnknownId(999)));
        assert_eq!(
            idx.insert(&[1.0, 2.0], &mut ctx),
            Err(MutateError::DimMismatch { got: 2, want: 8 })
        );

        // Forced compaction reclaims the tombstone; the survivors are the
        // original points and search stays exact.
        idx.set_compact_threshold(0.0);
        assert!(idx.compact(&mut ctx).unwrap());
        assert_eq!(idx.live_len(), 120);
        assert_eq!(idx.len(), 120);
        assert_eq!(idx.remove(120), Err(MutateError::UnknownId(120)), "id reclaimed");
        let store = VectorStore::from_matrix(&ds.data);
        for qi in 0..4 {
            let q = ds.queries.row(qi);
            let got = idx.search(q, &SearchParams::new(5), &mut ctx);
            assert_eq!(got, scan(&store, q, 5), "query {qi}");
        }
    }

    #[test]
    fn round_robin_insert_targets_least_loaded_shard() {
        let ds = tiny(809, 10, 4, Metric::L2);
        let spec = ShardSpec { n_shards: 3, ..Default::default() };
        // 10 points round-robin over 3 shards: loads 4/3/3.
        let mut idx = sharded_bf(&ds, &spec);
        let mut ctx = SearchContext::new();
        let sizes = |idx: &ShardedIndex| -> Vec<usize> {
            idx.shards.iter().map(|s| s.global_ids.len()).collect()
        };
        assert_eq!(sizes(&idx), vec![4, 3, 3]);
        idx.insert(&[0.0; 4], &mut ctx).unwrap(); // shard 1 (least, lowest index)
        assert_eq!(sizes(&idx), vec![4, 4, 3]);
        idx.insert(&[0.0; 4], &mut ctx).unwrap(); // shard 2
        assert_eq!(sizes(&idx), vec![4, 4, 4]);
        // Ascending global-id maps survive the appends.
        for shard in &idx.shards {
            assert!(shard.global_ids.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn strategy_tags_roundtrip() {
        for s in [ShardStrategy::RoundRobin, ShardStrategy::KMeans] {
            assert_eq!(ShardStrategy::from_tag(s.tag()), Some(s));
            assert_eq!(ShardStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(ShardStrategy::from_tag(9), None);
        assert_eq!(ShardStrategy::parse("zipf"), None);
    }
}
