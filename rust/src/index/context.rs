//! Search-time knobs ([`SearchParams`]) and pooled per-thread scratch
//! ([`SearchContext`]) shared by every [`crate::index::AnnIndex`]
//! implementor.
//!
//! The context owns the visited set, both beam-search heaps, a candidate
//! pool, and the stats accumulator. All of them keep their capacity
//! across queries, so after a short warmup the beam-search hot loop does
//! no heap allocation at all — previously every call built two fresh
//! `BinaryHeap`s and every call site hand-threaded `&mut VisitedSet` plus
//! `Option<&mut SearchStats>`.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::graph::search::{MinNeighbor, Neighbor, SearchStats};
use crate::graph::visited::VisitedSet;

/// Builder-style search parameters understood by all index families.
/// Graph families read `ef`/`patience`; IVF-PQ reads `n_probe`/`rerank`;
/// everyone reads `k`. Unknown knobs are ignored by design so one params
/// value can drive a heterogeneous fleet of indexes.
#[derive(Clone, Debug)]
pub struct SearchParams {
    /// Number of neighbors to return.
    pub k: usize,
    /// Beam width for graph search (clamped up to `k` internally).
    pub ef: usize,
    /// Early-termination budget: stop after this many consecutive
    /// non-improving node expansions (`None` = run Algorithm 1 to the
    /// natural termination condition). Graph families only; the
    /// FINGER-screened search ignores it (screening already removes the
    /// work early termination would skip).
    pub patience: Option<usize>,
    /// IVF-PQ: number of coarse cells probed.
    pub n_probe: usize,
    /// IVF-PQ: re-rank the ADC shortlist with exact distances.
    pub rerank: bool,
    /// IVF-PQ: shortlist depth kept for re-ranking (0 = auto, `10 * k`).
    pub rerank_depth: usize,
    /// Force the one-neighbor-at-a-time distance kernels instead of the
    /// default 4-row batched scoring. The two paths return bitwise-identical
    /// result streams (enforced by tests); this knob exists so the hotpath
    /// benchmark and the equality tests can time/compare both.
    pub scalar_kernels: bool,
}

impl SearchParams {
    pub fn new(k: usize) -> SearchParams {
        SearchParams {
            k,
            ef: k,
            patience: None,
            n_probe: 8,
            rerank: true,
            rerank_depth: 0,
            scalar_kernels: false,
        }
    }

    pub fn with_ef(mut self, ef: usize) -> SearchParams {
        self.ef = ef;
        self
    }

    pub fn with_patience(mut self, patience: usize) -> SearchParams {
        self.patience = Some(patience);
        self
    }

    pub fn with_probes(mut self, n_probe: usize) -> SearchParams {
        self.n_probe = n_probe;
        self
    }

    pub fn with_rerank(mut self, rerank: bool) -> SearchParams {
        self.rerank = rerank;
        self
    }

    pub fn with_rerank_depth(mut self, depth: usize) -> SearchParams {
        self.rerank_depth = depth;
        self
    }

    /// Use scalar (unbatched) distance scoring in the graph beam search.
    pub fn with_scalar_kernels(mut self, scalar: bool) -> SearchParams {
        self.scalar_kernels = scalar;
        self
    }

    /// Effective beam width (`ef` never below `k`).
    pub fn beam_width(&self) -> usize {
        self.ef.max(self.k)
    }

    /// Effective IVF-PQ re-rank depth.
    pub fn rerank_width(&self) -> usize {
        let d = if self.rerank_depth == 0 {
            10 * self.k
        } else {
            self.rerank_depth
        };
        d.max(self.k)
    }
}

impl Default for SearchParams {
    fn default() -> SearchParams {
        SearchParams::new(10)
    }
}

/// Reusable per-thread search scratch. Create one per worker/benchmark
/// thread and pass it to every search; it grows to the largest index it
/// has seen and then stops allocating.
pub struct SearchContext {
    /// Epoch-stamped visited marker (grows via [`VisitedSet::ensure_universe`]).
    pub visited: VisitedSet,
    /// Candidate queue (min-heap by distance).
    pub cands: BinaryHeap<MinNeighbor>,
    /// Current top results (max-heap by distance).
    pub top: BinaryHeap<Neighbor>,
    /// Scratch candidate pool (IVF-PQ ADC shortlist, rerank staging).
    pub pool: Vec<Neighbor>,
    /// Lane-padded query scratch (see `VectorStore::pad_query`): padded
    /// once per search, so scoring against padded rows needs no per-call
    /// tail handling or allocation.
    pub qbuf: Vec<f32>,
    /// Gathered unvisited neighbors of the node being expanded (the block
    /// the batched kernels score 4 at a time).
    pub block: Vec<u32>,
    /// FINGER edge slots matching `block` entry-for-entry.
    pub slots: Vec<usize>,
    /// Distances matching `block` entry-for-entry.
    pub dists: Vec<f32>,
    /// Quantized-traversal scratch: SQ8-encoded (and lane-padded) query
    /// codes, rebuilt once per search when the index has an SQ8 tier.
    pub qcodes: Vec<u8>,
    /// Quantized-traversal scratch: PQ ADC table for the current query.
    pub qtable: Vec<f32>,
    /// Accumulated instrumentation; only written when `stats_enabled`.
    pub stats: SearchStats,
    /// Toggle for stats recording (off = zero bookkeeping on the hot path).
    pub stats_enabled: bool,
}

impl SearchContext {
    /// Empty context; grows on first use.
    pub fn new() -> SearchContext {
        SearchContext {
            visited: VisitedSet::new(0),
            cands: BinaryHeap::new(),
            top: BinaryHeap::new(),
            pool: Vec::new(),
            qbuf: Vec::new(),
            block: Vec::new(),
            slots: Vec::new(),
            dists: Vec::new(),
            qcodes: Vec::new(),
            qtable: Vec::new(),
            stats: SearchStats::default(),
            stats_enabled: false,
        }
    }

    /// Context pre-sized for a universe of `n` points.
    pub fn for_universe(n: usize) -> SearchContext {
        let mut ctx = SearchContext::new();
        ctx.reserve(n);
        ctx
    }

    /// Enable stats recording (builder form).
    pub fn with_stats(mut self) -> SearchContext {
        self.stats_enabled = true;
        self
    }

    /// Make sure the visited set covers node ids `< n`.
    pub fn reserve(&mut self, n: usize) {
        self.visited.ensure_universe(n);
    }

    /// Start a query over a universe of `n` points: sizes the visited set
    /// and clears the heaps; retained capacity makes this allocation-free
    /// once warm.
    pub fn begin(&mut self, n: usize) {
        self.reserve(n);
        self.visited.clear();
        self.cands.clear();
        self.top.clear();
    }

    /// Drain `top` into an ascending (dist, id) vector, keeping the heap's
    /// buffer for the next query.
    pub fn drain_top(&mut self) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = Vec::with_capacity(self.top.len());
        while let Some(n) = self.top.pop() {
            out.push(n);
        }
        out.reverse();
        out
    }

    /// Take the accumulated stats, leaving a fresh accumulator.
    pub fn take_stats(&mut self) -> SearchStats {
        std::mem::take(&mut self.stats)
    }

    /// Reset the stats accumulator.
    pub fn reset_stats(&mut self) {
        self.stats = SearchStats::default();
    }
}

impl Default for SearchContext {
    fn default() -> SearchContext {
        SearchContext::new()
    }
}

/// Fixed pool of pooled contexts for the batch-parallel index builds:
/// each batch's workers check one out (`ContextPool::checkout`) instead
/// of allocating a fresh `SearchContext` per batch, so the O(universe)
/// visited set and the heap capacities are paid once per build, not once
/// per batch. At most `workers` guards may be live at a time (that is
/// exactly how many workers a build batch spawns); concurrent checkouts
/// take consecutive counter values, so with `live ≤ workers ≤ slots`
/// every live guard maps to a distinct slot and the locks never contend.
pub struct ContextPool {
    slots: Vec<Mutex<SearchContext>>,
    next: AtomicUsize,
}

impl ContextPool {
    /// Pool of `workers` contexts pre-sized for a universe of `n` points.
    pub fn new(workers: usize, n: usize) -> ContextPool {
        ContextPool {
            slots: (0..workers.max(1))
                .map(|_| Mutex::new(SearchContext::for_universe(n)))
                .collect(),
            next: AtomicUsize::new(0),
        }
    }

    /// Check out a context for the duration of one worker's batch run.
    pub fn checkout(&self) -> MutexGuard<'_, SearchContext> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        self.slots[i].lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_builder_defaults() {
        let p = SearchParams::new(5);
        assert_eq!(p.k, 5);
        assert_eq!(p.beam_width(), 5);
        assert_eq!(p.rerank_width(), 50);
        let p = p.with_ef(80).with_patience(3).with_probes(4).with_rerank_depth(7);
        assert_eq!(p.beam_width(), 80);
        assert_eq!(p.patience, Some(3));
        assert_eq!(p.n_probe, 4);
        assert_eq!(p.rerank_width(), 7);
        let p = p.with_rerank(false);
        assert!(!p.rerank);
        assert!(!p.scalar_kernels);
        let p = p.with_scalar_kernels(true);
        assert!(p.scalar_kernels);
    }

    #[test]
    fn drain_top_ascending_and_reusable() {
        let mut ctx = SearchContext::new();
        for (dist, id) in [(3.0, 1u32), (1.0, 2), (2.0, 3)] {
            ctx.top.push(Neighbor { dist, id });
        }
        let out = ctx.drain_top();
        let ids: Vec<u32> = out.iter().map(|n| n.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
        assert!(ctx.top.is_empty());
        ctx.top.push(Neighbor { dist: 0.5, id: 9 });
        assert_eq!(ctx.drain_top()[0].id, 9);
    }

    #[test]
    fn context_pool_hands_out_distinct_slots() {
        let pool = ContextPool::new(2, 10);
        {
            // Two simultaneous checkouts (the worker count the pool was
            // sized for) must not contend or deadlock.
            let mut a = pool.checkout();
            let mut b = pool.checkout();
            assert!(a.visited.insert(3));
            assert!(b.visited.insert(3));
        }
        // Released guards make every slot available again.
        let _c = pool.checkout();
        let _d = pool.checkout();
    }

    #[test]
    fn begin_clears_and_grows() {
        let mut ctx = SearchContext::new();
        ctx.begin(10);
        assert!(ctx.visited.insert(7));
        ctx.cands.push(MinNeighbor(Neighbor { dist: 1.0, id: 7 }));
        ctx.begin(20);
        assert!(ctx.cands.is_empty());
        assert!(!ctx.visited.contains(7), "fresh epoch after begin");
        assert!(ctx.visited.insert(19));
    }
}
